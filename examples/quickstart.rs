//! Quickstart: the NetSenseML public API in ~60 lines.
//!
//! Simulates an 8-worker DDP job training ResNet18 behind a 200 Mbps
//! bottleneck, once with NetSenseML's adaptive compression and once with
//! plain AllReduce, and prints the comparison.
//!
//! Run: `cargo run --release --example quickstart`

use netsenseml::coordinator::{run_sim_training, SimTrainConfig, SyncStrategy};
use netsenseml::experiments::report::Table;
use netsenseml::experiments::Scenario;
use netsenseml::netsim::schedule::mbps;
use netsenseml::trainer::models::PaperModel;

fn main() {
    let model = PaperModel::by_name("resnet18").unwrap();
    let bandwidth = mbps(200.0);
    let horizon_s = 300.0;

    let mut table = Table::new(
        "ResNet18 @ 200 Mbps, 8 workers, 300 virtual seconds",
        &["Method", "Steps", "Throughput (samples/s)", "Acc (%)", "Mean ratio"],
    );

    for strategy in [
        SyncStrategy::NetSense,
        SyncStrategy::AllReduce,
        SyncStrategy::TopK(0.1),
    ] {
        // 1. Build the network: the paper's star topology (Fig. 4).
        let mut net = Scenario::static_bottleneck(8, bandwidth);

        // 2. Configure the training job.
        let mut config = SimTrainConfig::new(model, strategy.clone());
        config.max_vtime_s = horizon_s;
        config.fidelity_every = 100; // full Algorithm-2 compression every 100 steps

        // 3. Run and read the metrics.
        let log = run_sim_training(&config, &mut net).expect("sim sync decodes its own frames");
        let mean_ratio =
            log.records.iter().map(|r| r.ratio).sum::<f64>() / log.records.len() as f64;
        table.row(vec![
            strategy.label(),
            log.records.len().to_string(),
            format!("{:.1}", log.mean_throughput()),
            format!("{:.2}", log.best_acc()),
            format!("{mean_ratio:.4}"),
        ]);
    }
    table.print();
    println!("NetSenseML sustains throughput by sizing payloads to the sensed BDP;");
    println!("AllReduce pushes 46 MB dense gradients into a 200 Mbps pipe and stalls.");
}
