//! Fig. 2 driver: the BBR-style sensing sweep — RTT and delivery rate vs
//! payload size, with the estimator's recovered BtlBw/RTprop/BDP against
//! simulator ground truth.
//!
//! Run: `cargo run --release --example sense_demo`

use netsenseml::experiments::fig2::fig2;
use netsenseml::experiments::scenario::RunOpts;

fn main() {
    let (table, r) = fig2(&RunOpts::default());
    table.print();
    println!("ground truth : BtlBw {:.1} Mbps, RTprop {:.1} ms", r.true_btlbw_mbps, r.true_rtprop_ms);
    println!(
        "estimator    : BtlBw {:.1} Mbps, RTprop {:.1} ms, BDP {:.0} kB",
        r.est_btlbw_mbps,
        r.est_rtprop_ms,
        r.est_bdp_bytes / 1e3
    );
    println!("\nThe knee sits at the BDP: below it RTT is flat and rate grows");
    println!("(app-limited); above it rate saturates at BtlBw and RTT grows");
    println!("linearly (bandwidth-limited) — Algorithm 1 aims payloads at 0.9×BDP.");
}
