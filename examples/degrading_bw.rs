//! Scenario 2 driver (paper Fig. 7): training throughput while the
//! bottleneck bandwidth degrades 2000 → 200 Mbps in −200 Mbps steps.
//!
//! Run: `cargo run --release --example degrading_bw [-- fast]`

use netsenseml::experiments::degrading::fig7;
use netsenseml::experiments::scenario::RunOpts;
use std::path::PathBuf;

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let opts = RunOpts {
        fast,
        out_dir: Some(PathBuf::from("results")),
        ..Default::default()
    };
    let (table, result) = fig7(&opts);
    table.print();
    println!("curves written to results/fig7.csv");
    // Show adaptation: NetSenseML's ratio trajectory across the run.
    let ns = &result.logs[0];
    println!("\nNetSenseML compression-ratio trajectory (vtime → ratio):");
    for r in ns.records.iter().step_by((ns.records.len() / 12).max(1)) {
        println!("  t={:7.1}s  ratio={:.4}  payload={:>9} B", r.vtime_s, r.ratio, r.payload_bytes);
    }
}
