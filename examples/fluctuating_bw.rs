//! Scenario 3 driver (paper Fig. 8): training throughput with competing
//! iperf-like traffic preempting the links.
//!
//! Run: `cargo run --release --example fluctuating_bw [-- fast]`

use netsenseml::experiments::fluctuating::fig8;
use netsenseml::experiments::scenario::RunOpts;
use std::path::PathBuf;

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let opts = RunOpts {
        fast,
        out_dir: Some(PathBuf::from("results")),
        ..Default::default()
    };
    let (table, result) = fig8(&opts);
    table.print();
    println!("curves written to results/fig8.csv\n");
    println!("windowed throughput (samples/s):");
    println!("{:>10} {:>12} {:>12} {:>12}", "t (s)", "NetSenseML", "AllReduce", "TopK-0.1");
    let n = result.series[0].1.len();
    for i in 0..n {
        let t = result.series[0].1[i].0;
        let get = |j: usize| {
            result.series[j]
                .1
                .get(i)
                .map(|&(_, y)| format!("{y:.0}"))
                .unwrap_or_else(|| "-".into())
        };
        println!("{t:>10.0} {:>12} {:>12} {:>12}", get(0), get(1), get(2));
    }
}
