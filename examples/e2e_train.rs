//! End-to-end validation: REAL training through all three
//! layers — Pallas kernels (L1) inside the JAX model (L2), AOT-compiled to
//! HLO, executed from the rust coordinator (L3) via PJRT, with gradient
//! synchronization compressed by Algorithm 1+2 over the simulated network.
//!
//! Trains the `cifar_cnn` model (1.13 M params, CIFAR-100-shaped synthetic
//! data, 8 simulated workers, batch 32) for a few hundred steps under a
//! 200 Mbps bottleneck, comparing NetSenseML against AllReduce and
//! TopK-0.1, and writes the loss curves to CSV.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example e2e_train [-- steps=300 model=cifar_cnn]`

use netsenseml::coordinator::{RealTrainConfig, RealTrainer, SyncStrategy};
use netsenseml::experiments::report::Table;
use netsenseml::netsim::schedule::mbps;
use netsenseml::netsim::topology::StarTopology;
use netsenseml::netsim::{NetSim, SimTime};
use netsenseml::runtime::ModelRuntime;
use std::path::PathBuf;

fn main() -> netsenseml::util::error::Result<()> {
    // Minimal key=value arg parsing (this is an example, not the CLI).
    let mut steps = 300usize;
    let mut model = "cifar_cnn".to_string();
    let mut workers = 8usize;
    for arg in std::env::args().skip(1) {
        if let Some(v) = arg.strip_prefix("steps=") {
            steps = v.parse()?;
        } else if let Some(v) = arg.strip_prefix("model=") {
            model = v.to_string();
        } else if let Some(v) = arg.strip_prefix("workers=") {
            workers = v.parse()?;
        }
    }
    let artifacts = PathBuf::from("artifacts");
    let rt = ModelRuntime::load(&artifacts, &model)?;
    println!(
        "e2e: {} ({} params) on {}, {} workers, {} steps, 200 Mbps bottleneck",
        model, rt.manifest.total_params, rt.platform(), workers, steps
    );

    let mut table = Table::new(
        "End-to-end real training (three-layer stack)",
        &[
            "Method",
            "Loss (first→last)",
            "Eval acc (%)",
            "vtime (s)",
            "Throughput (samples/s)",
            "Wall (s)",
        ],
    );
    for strategy in [
        SyncStrategy::NetSense,
        SyncStrategy::AllReduce,
        SyncStrategy::TopK(0.1),
    ] {
        let config = RealTrainConfig {
            n_workers: workers,
            strategy: strategy.clone(),
            steps,
            lr: 0.02,
            eval_every: 10,
            seed: 7,
        };
        let mut trainer = RealTrainer::new(&rt, config)?;
        let mut net = NetSim::quiet(StarTopology::constant(
            workers,
            mbps(200.0),
            SimTime::from_millis(10),
        ));
        let t0 = std::time::Instant::now();
        let log = trainer.train(&mut net)?;
        let wall = t0.elapsed().as_secs_f64();
        let first = log.records.first().unwrap().loss;
        let last = log.records.last().unwrap().loss;
        table.row(vec![
            strategy.label(),
            format!("{first:.3} → {last:.3}"),
            format!("{:.1}", log.records.last().unwrap().acc),
            format!("{:.1}", log.total_vtime()),
            format!("{:.1}", log.mean_throughput()),
            format!("{wall:.1}"),
        ]);
        let csv = format!("e2e_{}_{}.csv", model, strategy.label().replace('.', "_"));
        log.write_csv(std::path::Path::new(&csv))?;
        println!("  {} done — trace in {csv}", strategy.label());
    }
    table.print();
    Ok(())
}
