"""Layer-2 JAX models: forward/backward (``grad_step``) and optimizer
(``apply_update``), built on the Layer-1 Pallas kernels and AOT-lowered to
HLO text by ``aot.py``.

Interface contract with the rust runtime (see ``runtime/manifest.rs``):

- Parameters are an *ordered list* of named tensors; HLO parameter order is
  [params..., x, y] for grad_step and [params..., moms..., flat_grad, lr]
  for apply_update (jax flattens pytrees in list order).
- ``grad_step(params, x, y) -> (flat_grad, loss, n_correct)`` where
  ``flat_grad`` is the concatenation of per-tensor gradients in parameter
  order — the single buffer the coordinator compresses and all-reduces.
- ``apply_update(params, moms, flat_grad, lr) -> (new_params…, new_moms…)``
  applies SGD-with-momentum via the fused flat Pallas kernel.

Models are CIFAR-100-shaped (the paper's workload): a small CNN and an MLP.
The paper-scale ResNet18/VGG16 runs use the rust-side surrogate dynamics
(DESIGN.md §2); these HLO models are the end-to-end real-training path.
"""

import jax
import jax.numpy as jnp

from .kernels import dense, sgd_momentum_flat

MOMENTUM = 0.9


# --------------------------------------------------------------------------
# Parameter helpers
# --------------------------------------------------------------------------


def param_sizes(params):
    return [int(p.size) for p in params]


def flatten_grads(grads):
    return jnp.concatenate([g.reshape(-1) for g in grads])


def split_flat(flat, shapes):
    out, off = [], 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        out.append(flat[off : off + n].reshape(s))
        off += n
    return out


# --------------------------------------------------------------------------
# Model zoo
# --------------------------------------------------------------------------


def _conv(x, w, b, stride):
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


class ModelSpec:
    """A named model: ordered parameter spec + forward function."""

    def __init__(self, name, input_shape, n_classes, param_specs, forward):
        self.name = name
        self.input_shape = input_shape  # without batch
        self.n_classes = n_classes
        self.param_specs = param_specs  # list of (name, shape, fan_in)
        self.forward = forward

    def init(self, seed=0):
        key = jax.random.PRNGKey(seed)
        keys = jax.random.split(key, len(self.param_specs))
        params = []
        for k, (pname, shape, fan_in) in zip(keys, self.param_specs):
            if pname.endswith("_b"):
                params.append(jnp.zeros(shape, jnp.float32))
            else:
                params.append(_he(k, shape, fan_in))
        return params

    def total_params(self):
        total = 0
        for _, shape, _ in self.param_specs:
            n = 1
            for d in shape:
                n *= d
            total += n
        return total


def _cifar_cnn_forward(params, x):
    c1w, c1b, c2w, c2b, c3w, c3b, d1w, d1b, d2w, d2b = params
    h = jax.nn.relu(_conv(x, c1w, c1b, 1))          # 32×32×32
    h = jax.nn.relu(_conv(h, c2w, c2b, 2))          # 16×16×64
    h = jax.nn.relu(_conv(h, c3w, c3b, 2))          # 8×8×64
    h = h.reshape(h.shape[0], -1)                   # 4096
    h = jax.nn.relu(dense(h, d1w, d1b))             # Pallas matmul
    return dense(h, d2w, d2b)                       # Pallas matmul


CIFAR_CNN = ModelSpec(
    "cifar_cnn",
    (32, 32, 3),
    100,
    [
        ("conv1_w", (3, 3, 3, 32), 27),
        ("conv1_b", (32,), 0),
        ("conv2_w", (3, 3, 32, 64), 288),
        ("conv2_b", (64,), 0),
        ("conv3_w", (3, 3, 64, 64), 576),
        ("conv3_b", (64,), 0),
        ("dense1_w", (4096, 256), 4096),
        ("dense1_b", (256,), 0),
        ("dense2_w", (256, 100), 256),
        ("dense2_b", (100,), 0),
    ],
    _cifar_cnn_forward,
)


def _mlp_forward(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(dense(h, w1, b1))
    h = jax.nn.relu(dense(h, w2, b2))
    return dense(h, w3, b3)


MLP = ModelSpec(
    "mlp",
    (32, 32, 3),
    100,
    [
        ("fc1_w", (3072, 512), 3072),
        ("fc1_b", (512,), 0),
        ("fc2_w", (512, 256), 512),
        ("fc2_b", (256,), 0),
        ("fc3_w", (256, 100), 256),
        ("fc3_b", (100,), 0),
    ],
    _mlp_forward,
)

MODELS = {m.name: m for m in (CIFAR_CNN, MLP)}


# --------------------------------------------------------------------------
# Training-step functions (the AOT entry points)
# --------------------------------------------------------------------------


def make_grad_step(spec):
    """(params, x, y_f32) -> (flat_grad, loss, n_correct) for `spec`."""

    def loss_fn(params, x, y):
        logits = spec.forward(params, x)
        logp = jax.nn.log_softmax(logits)
        onehot = jax.nn.one_hot(y, spec.n_classes, dtype=jnp.float32)
        loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
        n_correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        )
        return loss, n_correct

    def grad_step(params, x, y_f32):
        y = y_f32.astype(jnp.int32)
        (loss, n_correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, x, y
        )
        return (flatten_grads(grads), loss, n_correct)

    return grad_step


def make_apply_update(spec):
    """(params, moms, flat_grad, lr) -> (new_params…, new_moms…)."""
    shapes = [shape for _, shape, _ in spec.param_specs]

    def apply_update(params, moms, flat_grad, lr):
        flat_p = flatten_grads(params)
        flat_m = flatten_grads(moms)
        new_p, new_m = sgd_momentum_flat(flat_p, flat_m, flat_grad, lr, MOMENTUM)
        return tuple(split_flat(new_p, shapes)) + tuple(split_flat(new_m, shapes))

    return apply_update
