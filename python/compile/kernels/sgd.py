"""Fused SGD-with-momentum Pallas kernel over the flat parameter vector.

The L2 ``apply_update`` step concatenates all parameters into one flat
vector and runs this single elementwise kernel — one HBM pass for the whole
model instead of one dispatch per tensor (the DDP-bucketing trick, applied
to the optimizer).

``m ← µ·m + g``, ``p ← p − lr·m``. VMEM per grid step: 3 input blocks +
2 output blocks of 8192 f32 = 160 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgd_kernel(mu_ref, lr_ref, p_ref, m_ref, g_ref, newp_ref, newm_ref):
    lr = lr_ref[0, 0]
    mu = mu_ref[0, 0]
    nm = mu * m_ref[...] + g_ref[...]
    newm_ref[...] = nm
    newp_ref[...] = p_ref[...] - lr * nm


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_momentum_flat(p, m, g, lr, mu, *, block: int = 8192):
    """Apply one SGD-momentum step to flat vectors ``p``/``m`` given flat
    gradient ``g``; ``lr``/``mu`` are runtime scalars. Returns ``(p', m')``.
    """
    if not (p.shape == m.shape == g.shape) or p.ndim != 1:
        raise ValueError(f"shape mismatch: p{p.shape} m{m.shape} g{g.shape}")
    n = p.shape[0]
    npad = _ceil_to(max(n, 1), block)
    pad = lambda v: jnp.pad(v.astype(jnp.float32), (0, npad - n)).reshape(-1, block)
    nb = npad // block
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    mu2 = jnp.asarray(mu, jnp.float32).reshape(1, 1)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    row_spec = pl.BlockSpec((1, block), lambda i: (i, 0))
    newp, newm = pl.pallas_call(
        _sgd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
        ),
        grid=(nb,),
        in_specs=[scalar_spec, scalar_spec, row_spec, row_spec, row_spec],
        out_specs=(row_spec, row_spec),
        interpret=True,
    )(mu2, lr2, pad(p), pad(m), pad(g))
    return newp.reshape(-1)[:n], newm.reshape(-1)[:n]
