"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path and real-TPU
performance is estimated from the BlockSpec VMEM/MXU geometry (see
DESIGN.md §Hardware-Adaptation and §Perf).
"""

from .matmul import matmul, dense
from .compress_stats import grad_stats, l2_norm_from_stats, threshold_for_topk
from .sgd import sgd_momentum_flat

__all__ = [
    "matmul",
    "dense",
    "grad_stats",
    "l2_norm_from_stats",
    "threshold_for_topk",
    "sgd_momentum_flat",
]
