"""Pure-jnp oracles for every Pallas kernel (the pytest ground truth)."""

import jax.numpy as jnp

NBINS = 32
_EXP_LO = -24


def matmul_ref(x, y):
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32))


def dense_ref(x, w, b):
    return matmul_ref(x, w) + b


def grad_stats_ref(g, block=8192):
    """Same contract as kernels.grad_stats, computed with plain jnp."""
    n = g.shape[0]
    npad = ((max(n, 1) + block - 1) // block) * block
    gp = jnp.pad(g.astype(jnp.float32), (0, npad - n))
    g2 = gp.reshape(-1, block)
    a = jnp.abs(g2)
    absmax = jnp.max(a, axis=1)
    sumsq = jnp.sum(g2 * g2, axis=1)
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-38))) - _EXP_LO
    valid = a >= 2.0**_EXP_LO
    hist = jnp.stack(
        [
            jnp.sum(jnp.where(valid & (e >= b) & (e < b + 1), 1.0, 0.0), axis=1)
            for b in range(NBINS)
        ],
        axis=1,
    )
    return absmax, sumsq, hist


def l2_norm_ref(g):
    return jnp.sqrt(jnp.sum(g.astype(jnp.float32) ** 2))


def topk_threshold_ref(g, k):
    """Exact k-th largest |g| (the quantity the histogram approximates)."""
    a = jnp.abs(g)
    return jnp.sort(a)[-k]


def sgd_momentum_ref(p, m, g, lr, mu):
    nm = mu * m + g
    return p - lr * nm, nm
