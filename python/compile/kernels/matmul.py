"""Tiled Pallas matmul — the dense-layer hot-spot of the L2 model.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output into
``(bm, bn)`` blocks sized for the MXU systolic array (bn = 128 lanes); each
grid step stages an ``(bm, K)`` row-panel of ``x`` and a ``(K, bn)``
column-panel of ``y`` from HBM into VMEM via BlockSpec — the role CUDA
shared-memory staging plays in the paper's GPU setting. Accumulation is
fp32 (``preferred_element_type``) regardless of operand dtype, matching MXU
semantics for bf16 operands.

VMEM budget per tile (documented for the §Perf estimate): with the default
``bm=32, bn=128`` and K ≤ 4096 at f32: 32·4096·4 B (x panel) + 4096·128·4 B
(y panel) + 32·128·4 B (out) ≈ 2.6 MiB — comfortably under the ~16 MiB VMEM
of a TPU core, leaving room for double-buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _matmul_impl(x, y, *, bm: int = 32, bn: int = 128):
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {y.shape}")
    M, K = x.shape
    K2, N = y.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    bm = min(bm, _ceil_to(M, 8))
    bn = min(bn, _ceil_to(N, 8))
    Mp, Np = _ceil_to(M, bm), _ceil_to(N, bn)
    xp = jnp.pad(x, ((0, Mp - M), (0, 0))).astype(jnp.float32)
    yp = jnp.pad(y, ((0, 0), (0, Np - N))).astype(jnp.float32)
    out = pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,  # CPU-PJRT path; real TPU would lower via Mosaic
    )(xp, yp)
    return out[:M, :N]


@jax.custom_vjp
def matmul(x, y):
    """``x @ y`` with a tiled Pallas kernel.

    Arbitrary ``M``/``N``/``K`` are supported: operands are zero-padded to
    tile multiples and the result is sliced back. Output dtype is float32.

    Reverse-mode autodiff is provided via ``custom_vjp`` (``pallas_call`` has
    no built-in transpose rule); the backward matmuls
    ``dx = g @ yᵀ`` and ``dy = xᵀ @ g`` run on the same Pallas kernel, so the
    L2 backward pass stays on the L1 hot path.
    """
    return _matmul_impl(x, y)


def _matmul_fwd(x, y):
    return _matmul_impl(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = _matmul_impl(g, y.T).astype(x.dtype)
    dy = _matmul_impl(x.T, g).astype(y.dtype)
    return dx, dy


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def dense(x, w, b):
    """Fully-connected layer ``x @ w + b`` on the Pallas matmul."""
    return matmul(x, w) + b
