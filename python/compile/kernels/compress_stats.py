"""Fused gradient-statistics Pallas kernel — the compression hot-spot.

NetSenseML's Algorithm 2 needs three per-tensor statistics before it can
compress a gradient: the L2 norm (the ``tr_d`` density test), the magnitude
maximum (quantization scaling), and a magnitude *distribution* (to pick an
approximate Top-K threshold without a full sort). A naive jnp implementation
makes three separate HBM passes; this kernel fuses them into **one** pass.

TPU mapping (DESIGN.md §Hardware-Adaptation): the flat gradient is viewed as
``(n_blocks, BLOCK)`` and the grid walks blocks; each grid step stages one
``BLOCK``-element row into VMEM (BLOCK=8192 → 32 KiB f32, trivially
resident) and reduces it to (absmax, sumsq, 32-bin log2-magnitude
histogram). Partial results are combined on the host-side jnp epilogue —
the same split a CUDA kernel would express with per-threadblock reductions
followed by a second tiny kernel.

Histogram bins: bin ``b`` counts elements with ``floor(log2 |g|) == b - 24``
for b in [0, 32), i.e. magnitudes in [2^-24, 2^8); |g| below 2^-24 (and
exact zeros) land in bin 0's underflow sibling — they are counted in
``n_zeroish`` implicitly as ``n - hist.sum()``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NBINS = 32
_EXP_LO = -24  # bin 0 lower edge = 2^-24


def _stats_kernel(g_ref, absmax_ref, sumsq_ref, hist_ref):
    g = g_ref[...]
    a = jnp.abs(g)
    absmax_ref[...] = jnp.max(a, axis=1, keepdims=True)
    sumsq_ref[...] = jnp.sum(g * g, axis=1, keepdims=True)
    e = jnp.floor(jnp.log2(jnp.maximum(a, 1e-38)))
    e = e - _EXP_LO  # bin index space
    valid = a >= 2.0**_EXP_LO
    for b in range(NBINS):
        hist_ref[0, b] = jnp.sum(
            jnp.where(valid & (e >= b) & (e < b + 1), 1.0, 0.0)
        )


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block",))
def grad_stats(g, *, block: int = 8192):
    """One-pass per-block stats of a flat gradient.

    Returns ``(absmax[nb], sumsq[nb], hist[nb, 32])``; zero-padding added to
    reach a block multiple contributes nothing to any statistic.
    """
    if g.ndim != 1:
        raise ValueError(f"grad_stats expects a flat tensor, got {g.shape}")
    n = g.shape[0]
    npad = _ceil_to(max(n, 1), block)
    gp = jnp.pad(g.astype(jnp.float32), (0, npad - n))
    nb = npad // block
    g2 = gp.reshape(nb, block)
    absmax, sumsq, hist = pl.pallas_call(
        _stats_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, NBINS), jnp.float32),
        ),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=(
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, NBINS), lambda i: (i, 0)),
        ),
        interpret=True,
    )(g2)
    return absmax[:, 0], sumsq[:, 0], hist


def l2_norm_from_stats(sumsq):
    """Tensor L2 norm from the per-block sum-of-squares."""
    return jnp.sqrt(jnp.sum(sumsq))


def threshold_for_topk(hist, k):
    """Approximate Top-K magnitude threshold from the pooled histogram.

    Picks the smallest bin edge ``2^(b-24)`` such that the count of elements
    with magnitude ≥ that edge is still ≥ ``k`` (so thresholding keeps at
    least ~k and at most ~k plus one bin's worth of elements). Returns 0.0
    when even the full histogram holds fewer than ``k`` elements.
    """
    pooled = jnp.sum(hist, axis=0)  # [NBINS]
    # tail[b] = count of elements with bin index >= b
    tail = jnp.cumsum(pooled[::-1])[::-1]
    edges = 2.0 ** (jnp.arange(NBINS) + _EXP_LO)
    feasible = tail >= k
    # Largest b that is still feasible.
    idx = jnp.where(feasible, jnp.arange(NBINS), -1).max()
    return jnp.where(idx >= 0, edges[jnp.maximum(idx, 0)], 0.0)
