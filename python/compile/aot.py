"""AOT lowering: JAX/Pallas (L1+L2) → HLO *text* artifacts for the rust
runtime, plus ``manifest.json`` describing every executable's I/O layout and
``{model}_init.bin`` (the seeded initial parameters, flat little-endian f32).

HLO **text** — not ``HloModuleProto.serialize()`` — is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowering goes stablehlo →
XlaComputation (``return_tuple=True``; the rust side unwraps the tuple).

Run once via ``make artifacts``; never on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import MODELS, MOMENTUM, make_apply_update, make_grad_step

BATCH = 32  # the paper's per-GPU batch size
INIT_SEED = 0
MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(spec, out_dir):
    """Lower grad_step + apply_update for one model; returns manifest entry."""
    params = spec.init(INIT_SEED)
    x_spec = jax.ShapeDtypeStruct((BATCH,) + spec.input_shape, jnp.float32)
    y_spec = jax.ShapeDtypeStruct((BATCH,), jnp.float32)
    flat_spec = jax.ShapeDtypeStruct((spec.total_params(),), jnp.float32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params]

    grad_step = make_grad_step(spec)
    apply_update = make_apply_update(spec)

    gs_path = f"{spec.name}_grad_step.hlo.txt"
    au_path = f"{spec.name}_apply_update.hlo.txt"
    init_path = f"{spec.name}_init.bin"

    lowered = jax.jit(grad_step).lower(p_specs, x_spec, y_spec)
    with open(os.path.join(out_dir, gs_path), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(apply_update).lower(p_specs, p_specs, flat_spec, lr_spec)
    with open(os.path.join(out_dir, au_path), "w") as f:
        f.write(to_hlo_text(lowered))

    flat_init = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
    flat_init.astype("<f4").tofile(os.path.join(out_dir, init_path))

    return {
        "batch": BATCH,
        "input_shape": list(spec.input_shape),
        "n_classes": spec.n_classes,
        "momentum": MOMENTUM,
        "init_seed": INIT_SEED,
        "total_params": spec.total_params(),
        "params": [
            {"name": name, "shape": list(shape)}
            for name, shape, _ in spec.param_specs
        ],
        "grad_step": {
            "file": gs_path,
            "inputs": "[params..., x(f32[B,H,W,C]), y(f32[B])]",
            "outputs": "(flat_grad f32[P], loss f32[], n_correct f32[])",
        },
        "apply_update": {
            "file": au_path,
            "inputs": "[params..., moms..., flat_grad f32[P], lr f32[]]",
            "outputs": "(new_params..., new_moms...)",
        },
        "init_params": init_path,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models", default="cifar_cnn,mlp", help="comma-separated model names"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": MANIFEST_VERSION, "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        if name not in MODELS:
            raise SystemExit(f"unknown model {name!r}; have {sorted(MODELS)}")
        print(f"lowering {name} ...", flush=True)
        manifest["models"][name] = lower_model(MODELS[name], args.out_dir)
    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
