"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes and value regimes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dense,
    grad_stats,
    l2_norm_from_stats,
    matmul,
    sgd_momentum_flat,
    threshold_for_topk,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------- matmul ---


@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 130),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k), np.float32)
    y = rng.standard_normal((k, n), np.float32)
    got = matmul(jnp.array(x), jnp.array(y))
    want = ref.matmul_ref(jnp.array(x), jnp.array(y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((32, 64)), dtype)
    y = jnp.array(rng.standard_normal((64, 128)), dtype)
    got = matmul(x, y)
    assert got.dtype == jnp.float32  # fp32 accumulation
    want = np.asarray(x, np.float32) @ np.asarray(y, np.float32)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_dense_adds_bias():
    rng = np.random.default_rng(1)
    x = jnp.array(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.array(rng.standard_normal((16, 24)), jnp.float32)
    b = jnp.array(rng.standard_normal(24), jnp.float32)
    np.testing.assert_allclose(
        dense(x, w, b), ref.dense_ref(x, w, b), rtol=1e-5, atol=1e-4
    )


def test_matmul_exact_tile_boundaries():
    # M, N exactly at tile multiples (no padding path).
    rng = np.random.default_rng(2)
    x = jnp.array(rng.standard_normal((64, 256)), jnp.float32)
    y = jnp.array(rng.standard_normal((256, 256)), jnp.float32)
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-4
    )


# ------------------------------------------------------------ grad_stats ---


@settings(**SETTINGS)
@given(
    n=st.integers(1, 40_000),
    scale=st.sampled_from([1e-6, 1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grad_stats_matches_ref(n, scale, seed):
    rng = np.random.default_rng(seed)
    g = jnp.array(rng.standard_normal(n) * scale, jnp.float32)
    am, ss, h = grad_stats(g)
    am_r, ss_r, h_r = ref.grad_stats_ref(g)
    np.testing.assert_allclose(am, am_r, rtol=1e-6)
    np.testing.assert_allclose(ss, ss_r, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(h_r))


def test_grad_stats_l2_norm():
    rng = np.random.default_rng(3)
    g = jnp.array(rng.standard_normal(30_000), jnp.float32)
    _, ss, _ = grad_stats(g)
    np.testing.assert_allclose(
        l2_norm_from_stats(ss), ref.l2_norm_ref(g), rtol=1e-5
    )


def test_grad_stats_zeros_and_padding():
    g = jnp.zeros(100, jnp.float32)
    am, ss, h = grad_stats(g)
    assert float(am.max()) == 0.0
    assert float(ss.sum()) == 0.0
    assert float(h.sum()) == 0.0  # zeros fall below every bin


@settings(**SETTINGS)
@given(
    n=st.integers(64, 20_000),
    frac=st.floats(0.01, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_threshold_for_topk_brackets_exact(n, frac, seed):
    rng = np.random.default_rng(seed)
    g = jnp.array(rng.standard_normal(n), jnp.float32)
    k = max(1, int(n * frac))
    _, _, h = grad_stats(g)
    th = float(threshold_for_topk(h, k))
    kept = int((np.abs(np.asarray(g)) >= th).sum())
    # Histogram threshold keeps at least k and at most k + one bin's
    # population (bins are factor-of-2 wide).
    assert kept >= k
    exact = float(ref.topk_threshold_ref(g, k))
    assert th <= exact + 1e-9
    # and not absurdly below (within one power of two of the exact)
    if exact > 0:
        assert th >= exact / 2.0 - 1e-9


# ------------------------------------------------------------------- sgd ---


@settings(**SETTINGS)
@given(
    n=st.integers(1, 50_000),
    lr=st.floats(1e-4, 1.0),
    mu=st.floats(0.0, 0.99),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_matches_ref(n, lr, mu, seed):
    rng = np.random.default_rng(seed)
    p = jnp.array(rng.standard_normal(n), jnp.float32)
    m = jnp.array(rng.standard_normal(n), jnp.float32)
    g = jnp.array(rng.standard_normal(n), jnp.float32)
    got_p, got_m = sgd_momentum_flat(p, m, g, lr, mu)
    want_p, want_m = ref.sgd_momentum_ref(p, m, g, lr, mu)
    np.testing.assert_allclose(got_p, want_p, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-5, atol=1e-6)


def test_sgd_shape_mismatch_rejected():
    with pytest.raises(ValueError):
        sgd_momentum_flat(jnp.zeros(4), jnp.zeros(4), jnp.zeros(5), 0.1, 0.9)


def test_sgd_zero_lr_keeps_params():
    p = jnp.arange(10, dtype=jnp.float32)
    m = jnp.zeros(10)
    g = jnp.ones(10)
    p2, m2 = sgd_momentum_flat(p, m, g, 0.0, 0.9)
    np.testing.assert_allclose(p2, p)
    np.testing.assert_allclose(m2, g)
