"""AOT pipeline: artifacts exist, manifest is sane, HLO text parses (has an
ENTRY computation), and init params are the right size."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Lower only the small MLP for test speed.
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--models", "mlp"],
        cwd=PYDIR,
        check=True,
    )
    return out


def test_manifest_contents(artifacts):
    with open(artifacts / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["version"] == 1
    entry = manifest["models"]["mlp"]
    assert entry["batch"] == 32
    assert entry["total_params"] == sum(
        int(np.prod(p["shape"])) if p["shape"] else 1 for p in entry["params"]
    )
    for key in ("grad_step", "apply_update"):
        assert (artifacts / entry[key]["file"]).exists()


def test_hlo_text_has_entry(artifacts):
    with open(artifacts / "manifest.json") as f:
        entry = json.load(f)["models"]["mlp"]
    for key in ("grad_step", "apply_update"):
        text = (artifacts / entry[key]["file"]).read_text()
        assert "ENTRY" in text, f"{key}: no ENTRY computation"
        assert "f32" in text


def test_init_bin_size(artifacts):
    with open(artifacts / "manifest.json") as f:
        entry = json.load(f)["models"]["mlp"]
    raw = np.fromfile(artifacts / entry["init_params"], dtype="<f4")
    assert raw.size == entry["total_params"]
    assert np.isfinite(raw).all()
    assert np.abs(raw).max() > 0
