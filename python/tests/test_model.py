"""L2 correctness: grad_step vs jax.grad on a pure-jnp clone, apply_update
semantics, and actual learning on a small synthetic problem."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    MODELS,
    MOMENTUM,
    flatten_grads,
    make_apply_update,
    make_grad_step,
    split_flat,
)

BATCH = 8  # small batch for test speed


def synth_batch(spec, seed=0, batch=BATCH):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((batch,) + spec.input_shape).astype(np.float32)
    y = rng.integers(0, spec.n_classes, batch).astype(np.float32)
    return jnp.array(x), jnp.array(y)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_param_specs_consistent(name):
    spec = MODELS[name]
    params = spec.init(0)
    assert len(params) == len(spec.param_specs)
    for p, (pname, shape, _) in zip(params, spec.param_specs):
        assert p.shape == tuple(shape), pname
    assert sum(int(p.size) for p in params) == spec.total_params()


@pytest.mark.parametrize("name", sorted(MODELS))
def test_grad_step_shapes_and_finiteness(name):
    spec = MODELS[name]
    params = spec.init(0)
    x, y = synth_batch(spec)
    flat_grad, loss, n_correct = make_grad_step(spec)(params, x, y)
    assert flat_grad.shape == (spec.total_params(),)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(n_correct) <= BATCH
    assert np.isfinite(np.asarray(flat_grad)).all()
    # loss should be near log(n_classes) at init
    assert abs(float(loss) - np.log(spec.n_classes)) < 1.5


def test_grad_step_matches_pure_jnp_mlp():
    """The MLP forward is reimplemented with plain jnp ops; grads from the
    Pallas-backed graph must match jax.grad of the clone."""
    spec = MODELS["mlp"]
    params = spec.init(0)
    x, y = synth_batch(spec, seed=1)

    def clone_loss(params, x, y_f32):
        w1, b1, w2, b2, w3, b3 = params
        h = x.reshape(x.shape[0], -1)
        h = jax.nn.relu(h @ w1 + b1)
        h = jax.nn.relu(h @ w2 + b2)
        logits = h @ w3 + b3
        y = y_f32.astype(jnp.int32)
        logp = jax.nn.log_softmax(logits)
        onehot = jax.nn.one_hot(y, spec.n_classes, dtype=jnp.float32)
        return -jnp.mean(jnp.sum(onehot * logp, axis=-1))

    flat_grad, loss, _ = make_grad_step(spec)(params, x, y)
    loss_ref, grads_ref = jax.value_and_grad(clone_loss)(params, x, y)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(flat_grad),
        np.asarray(flatten_grads(grads_ref)),
        rtol=1e-4,
        atol=1e-5,
    )


def test_flatten_split_roundtrip():
    spec = MODELS["cifar_cnn"]
    params = spec.init(3)
    flat = flatten_grads(params)
    shapes = [shape for _, shape, _ in spec.param_specs]
    back = split_flat(flat, shapes)
    for a, b in zip(params, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", sorted(MODELS))
def test_apply_update_matches_manual_sgd(name):
    spec = MODELS[name]
    params = spec.init(0)
    moms = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(7)
    flat_grad = jnp.array(
        rng.standard_normal(spec.total_params()), jnp.float32
    )
    lr = jnp.float32(0.1)
    out = make_apply_update(spec)(params, moms, flat_grad, lr)
    n = len(params)
    new_params, new_moms = out[:n], out[n:]
    shapes = [shape for _, shape, _ in spec.param_specs]
    g_split = split_flat(flat_grad, shapes)
    for p, m, g, np_, nm_ in zip(params, moms, g_split, new_params, new_moms):
        want_m = MOMENTUM * m + g
        want_p = p - 0.1 * want_m
        np.testing.assert_allclose(np.asarray(nm_), np.asarray(want_m), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(np_), np.asarray(want_p), rtol=1e-5, atol=1e-6)


def test_training_reduces_loss_mlp():
    """A few steps of real grad_step + apply_update must reduce the loss on
    a fixed batch (full pipeline sanity — the e2e example does this at
    scale through the rust runtime)."""
    spec = MODELS["mlp"]
    params = spec.init(0)
    moms = [jnp.zeros_like(p) for p in params]
    x, y = synth_batch(spec, seed=2, batch=16)
    grad_step = make_grad_step(spec)
    apply_update = make_apply_update(spec)
    losses = []
    for _ in range(8):
        flat_grad, loss, _ = grad_step(params, x, y)
        losses.append(float(loss))
        out = apply_update(params, moms, flat_grad, jnp.float32(0.05))
        params, moms = list(out[: len(params)]), list(out[len(params) :])
    assert losses[-1] < losses[0] * 0.8, losses
