# Entry points referenced by the docs and code comments.
.PHONY: artifacts verify

# AOT-lower the JAX/Pallas models (L1+L2) to HLO text artifacts consumed by
# the rust runtime (`--features pjrt`). Needs JAX; run once, never on the
# request path.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Tier-1 build + tests plus the docs gate (rustdoc warnings fatal, doctests).
verify:
	scripts/verify.sh
