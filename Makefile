# Entry points referenced by the docs and code comments.
.PHONY: artifacts verify fuzz-smoke bench-transport bench-json trace-smoke perf-compare

# AOT-lower the JAX/Pallas models (L1+L2) to HLO text artifacts consumed by
# the rust runtime (`--features pjrt`). Needs JAX; run once, never on the
# request path.
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Tier-1 build + tests plus the docs gate (rustdoc warnings fatal, doctests).
verify:
	scripts/verify.sh

# Deterministic fuzz smoke: the wire-surface harnesses (frame codec, COO
# payloads, epoch envelopes, checkpoints) at 10k iterations per surface
# under the fixed default seed, plus the pinned regression-corpus replay.
# Bounded and reproducible — override with NETSENSE_FUZZ_SEED /
# NETSENSE_FUZZ_ITERS to explore.
fuzz-smoke:
	NETSENSE_FUZZ_ITERS=10000 cargo test -q --lib fuzz
	cargo test -q --test fuzz_corpus

# Loopback-throughput bench for the socket transport layer (frame codec,
# ring collectives, token-bucket overhead). NETSENSE_BENCH_FAST=1 shrinks
# the measurement windows for CI.
bench-transport:
	cargo bench --bench bench_transport

# Machine-readable perf baselines: writes BENCH_compress.json (fused vs
# staged throughput, allocs/step, parallel bucket scaling),
# BENCH_obs.json (telemetry-on vs -off fused throughput, <2% gate),
# BENCH_pipeline.json (pipelined vs monolithic exchange), and
# BENCH_transport.json (frame codec, ring collectives, envelope + token
# bucket overhead, and the event-loop fan-in: frames/s + p99 latency at
# 4/16/64 peers vs a thread-per-peer reference) at the repo root.
# NETSENSE_BENCH_FAST=1 shrinks the measurement windows for CI.
bench-json:
	cargo bench --bench bench_compress
	cargo bench --bench bench_obs
	cargo bench --bench bench_pipeline
	cargo bench --bench bench_transport

# Perf-trajectory gate: rerun the JSON benches and diff against the
# committed baselines (baselines/perf/). Direction-aware — throughput keys
# must not drop, cost keys must not rise, alloc counters are exact.
# PERF_TOLERANCE widens the relative band (default 0.35);
# PERF_COMPARE_MODE=warn reports without failing (noisy shared runners).
perf-compare: bench-json
	python3 scripts/perf_compare.py

# Telemetry smoke: a short healthy live run over real TCP sockets with
# tracing, the decision journal, the cluster gather, and a metrics
# snapshot enabled, then structural validation of all four artifacts
# (clock-aligned multi-rank Chrome trace, Prometheus cumulative buckets,
# journal ratio chain, critical-path attribution). CI uploads them.
trace-smoke:
	cargo build --release
	./target/release/netsenseml live --workers 4 --steps 12 --params 20000 \
	  --backend tcp --bind 127.0.0.1:0 --obs-collect \
	  --trace-out trace_smoke.json --journal-out journal_smoke.json \
	  --metrics-out metrics_smoke.prom --analysis-out analysis_smoke.json
	python3 scripts/check_trace.py trace_smoke.json metrics_smoke.prom \
	  journal_smoke.json analysis_smoke.json
