#!/usr/bin/env bash
# Tier-1 verify + docs gate. Run from anywhere; operates on the repo root.
#
#   scripts/verify.sh          # build, tests, rustdoc (warnings fatal), doctests
#   FAST=1 scripts/verify.sh   # same, with fast bench/experiment settings
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${FAST:-0}" == "1" ]]; then
  export NETSENSE_BENCH_FAST=1
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (unit + integration; doctests run separately below) =="
cargo test -q --lib --bins --tests

# Docs gate: broken intra-doc links and rustdoc warnings fail fast, and
# every module-header example actually runs.
echo "== cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test --doc =="
cargo test --doc -q

echo "verify: OK"
