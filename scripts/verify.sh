#!/usr/bin/env bash
# Tier-1 verify + docs gate. Run from anywhere; operates on the repo root.
#
#   scripts/verify.sh          # build, tests, rustdoc (warnings fatal), doctests
#   FAST=1 scripts/verify.sh   # same, with fast bench/experiment settings
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${FAST:-0}" == "1" ]]; then
  export NETSENSE_BENCH_FAST=1
fi

echo "== cargo build --release =="
cargo build --release

# Lint gate: clippy warnings are errors. Skipped (loudly) when the
# component is not installed — CI installs it explicitly.
if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy --all-targets (warnings are errors) =="
  cargo clippy --all-targets -- -D warnings
else
  echo "WARNING: cargo clippy not installed — lint gate SKIPPED (rustup component add clippy)"
fi

echo "== cargo test -q (unit + integration; doctests run separately below) =="
cargo test -q --lib --bins --tests

# Receive-path gates, run by name so a filter change can never silently
# drop them (cheap; also covered by the full run above): the fused
# decode-reduce corruption contract (malformed frames → named Err, no
# out-of-bounds scatter) and the zero-alloc steady-state gates on both
# halves of the data plane.
echo "== receive-path gates: decode-reduce corruption + zero-alloc (FAST-safe) =="
cargo test -q --lib decode_reduce
cargo test -q --lib allocation_free

# Observability gates, run by name for the same reason: the metrics
# registry / span ring / decision journal unit tests and the live-run
# acceptance test (trace + journal + snapshot cross-checks). The
# zero-alloc gates above already run with telemetry enabled.
echo "== observability gates: registry + spans + journal (FAST-safe) =="
cargo test -q --lib obs

# Codec-kernel gates, run by name for the same reason: the SIMD kernels
# must stay bit-identical to the scalar reference at every dispatch level
# (the full run above exercises the runtime-detected level; the
# NETSENSE_SIMD=off rerun pins the scalar fallback on hardware where they
# would otherwise never diverge), and the 3LC-style lossless stage must
# round-trip bit-exactly through both decode paths.
echo "== codec-kernel gates: SIMD bit-identity (detected + forced-scalar) + lossless (FAST-safe) =="
cargo test -q --lib simd
NETSENSE_SIMD=off cargo test -q --lib simd
cargo test -q --lib lossless

# Perf-trajectory gate self-test: prove the regression comparator trips on
# a synthetically regressed bench JSON (the real diff against
# baselines/perf/ runs via `make perf-compare`, which needs bench runs).
echo "== perf-compare self-test (comparator must trip on synthetic regression) =="
python3 scripts/perf_compare.py --self-test

# Adversarial gates, run by name for the same reason: the deterministic
# wire-surface fuzz harness (frame codec, COO payloads, epoch envelopes,
# checkpoints — malformed input → named Err, never a panic or OOB
# scatter) and the committed regression corpus, every entry pinned to
# its outcome. `make fuzz-smoke` runs the same harness at 10k iterations.
echo "== adversarial gates: wire-surface fuzz + corpus replay (FAST-safe) =="
cargo test -q --lib fuzz
cargo test -q --test fuzz_corpus

# Docs gate: broken intra-doc links and rustdoc warnings fail fast, and
# every module-header example actually runs.
echo "== cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test --doc =="
cargo test --doc -q

echo "verify: OK"
