#!/usr/bin/env python3
"""Perf-trajectory regression gate: diff fresh `make bench-json` output
against the committed baselines under `baselines/perf/`.

Usage:
    python3 scripts/perf_compare.py                 # compare repo-root BENCH_*.json
    python3 scripts/perf_compare.py --mode warn     # report only, always exit 0
    python3 scripts/perf_compare.py --tolerance 0.5 # looser gate (noisy runners)
    python3 scripts/perf_compare.py --self-test     # prove the gate trips

Environment overrides (CI wires these): PERF_TOLERANCE, PERF_COMPARE_MODE.

Direction awareness is keyed off the metric name:
  - throughput-ish keys (``*_gbps``, ``*_speedup``, ``*_per_s``,
    ``*_reduction``) regress when they DROP below baseline*(1-tol);
  - cost-ish keys (``*_pct``, ``*_ns``, ``*_us``, ``*_s``, ``*_bytes``,
    ``*overhead*``, ``*wall*``) regress when they RISE above
    baseline*(1+tol);
  - allocation counters (``*allocs*``) are exact: any increase over the
    committed baseline is a regression, tolerance does not apply (the
    zero-alloc contract is not a statistical property);
  - metadata and environment-shape keys (timestamps, thread counts,
    simd_level, …) are informational and never gated.

EXPERIMENTS.md ("Perf trajectory") documents how to read a failure and how
to bump a baseline on purpose.
"""

import argparse
import json
import os
import sys
import tempfile

BENCHES = ["compress", "pipeline", "obs", "transport"]
BASELINE_DIR = os.path.join("baselines", "perf")
DEFAULT_TOLERANCE = 0.35  # generous: shared runners are noisy

# Keys that describe the run, not its performance.
META_KEYS = {
    "bench",
    "schema_version",
    "fast_mode",
    "unix_time_s",
    "simd_level",
    "parallel_threads",
    "parallel_buckets",
    "n_params",
    "windows",
    "iters_per_window",
}

# Unit tokens appear mid-key too (`fused_gbps_10m`), so match as substrings —
# except `_per_s`, kept suffix-only so it cannot collide with `_per_step`.
HIGHER_BETTER_TOKENS = ("_gbps", "_speedup", "_reduction")
LOWER_BETTER_SUFFIXES = ("_pct", "_ns", "_us", "_s", "_bytes")
LOWER_BETTER_SUBSTRINGS = ("overhead", "wall")


def classify(key):
    """Return 'higher', 'lower', 'exact', or None (ungated)."""
    if key in META_KEYS:
        return None
    if "allocs" in key:
        return "exact"
    if key.endswith("_per_s") or any(t in key for t in HIGHER_BETTER_TOKENS):
        return "higher"
    if key.endswith(LOWER_BETTER_SUFFIXES) or any(
        s in key for s in LOWER_BETTER_SUBSTRINGS
    ):
        return "lower"
    return None


def compare_bench(name, baseline, fresh, tolerance):
    """Yield (severity, message) pairs; severity is 'regression' or 'note'."""
    for key in sorted(baseline):
        base = baseline[key]
        direction = classify(key)
        if direction is None or not isinstance(base, (int, float)) or isinstance(base, bool):
            continue
        if key not in fresh:
            yield ("regression", f"{name}: `{key}` missing from fresh run "
                                 "(renamed or dropped — baselines only gain fields)")
            continue
        cur = fresh[key]
        if not isinstance(cur, (int, float)) or isinstance(cur, bool):
            yield ("regression", f"{name}: `{key}` is no longer numeric ({cur!r})")
            continue
        if direction == "exact":
            if cur > base:
                yield ("regression",
                       f"{name}: `{key}` rose {base} -> {cur} (allocation gate is exact)")
            continue
        if direction == "higher":
            floor = base * (1.0 - tolerance)
            if cur < floor:
                yield ("regression",
                       f"{name}: `{key}` dropped {base:.4g} -> {cur:.4g} "
                       f"(floor {floor:.4g} at tolerance {tolerance:.0%})")
            continue
        # direction == "lower"
        if base == 0:
            if cur > 0:
                yield ("note", f"{name}: `{key}` moved off a zero baseline (0 -> {cur:.4g})")
            continue
        ceil = base * (1.0 + tolerance)
        if cur > ceil:
            yield ("regression",
                   f"{name}: `{key}` rose {base:.4g} -> {cur:.4g} "
                   f"(ceiling {ceil:.4g} at tolerance {tolerance:.0%})")
    for key in sorted(set(fresh) - set(baseline)):
        if classify(key) is not None:
            yield ("note", f"{name}: new metric `{key}` = {fresh[key]!r} "
                           "(not in baseline yet — bump the baseline to start gating it)")


def load(path):
    with open(path) as f:
        return json.load(f)


def run_compare(fresh_dir, baseline_dir, tolerance, mode):
    regressions, notes, compared = [], [], 0
    for bench in BENCHES:
        fname = f"BENCH_{bench}.json"
        base_path = os.path.join(baseline_dir, fname)
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(base_path):
            notes.append(f"{bench}: no committed baseline at {base_path} — skipped")
            continue
        if not os.path.exists(fresh_path):
            regressions.append(
                f"{bench}: fresh {fresh_path} missing — run `make bench-json` first"
            )
            continue
        baseline, fresh = load(base_path), load(fresh_path)
        if baseline.get("fast_mode") != fresh.get("fast_mode"):
            notes.append(
                f"{bench}: fast_mode differs (baseline {baseline.get('fast_mode')}, "
                f"fresh {fresh.get('fast_mode')}) — absolute numbers are not comparable; "
                "ratios/speedups/allocs still gate"
            )
        compared += 1
        for severity, msg in compare_bench(bench, baseline, fresh, tolerance):
            (regressions if severity == "regression" else notes).append(msg)

    for n in notes:
        print(f"note: {n}")
    for r in regressions:
        print(f"REGRESSION: {r}")
    if compared == 0:
        print("perf-compare: no baselines compared (nothing committed yet?)")
    if regressions:
        print(f"perf-compare: {len(regressions)} regression(s) beyond tolerance "
              f"{tolerance:.0%} across {compared} bench file(s)")
        if mode == "warn":
            print("perf-compare: warn mode — not failing the build")
            return 0
        return 1
    print(f"perf-compare: OK ({compared} bench file(s) within tolerance {tolerance:.0%})")
    return 0


def self_test():
    """Prove the gate trips on a synthetically regressed run and passes on a
    healthy one — the verify.sh hook, so a refactor can't neuter the gate."""
    baseline = {
        "bench": "compress",
        "schema_version": 1,
        "fast_mode": False,
        "unix_time_s": 0,
        "fused_gbps_10m": 10.0,
        "simd_quantize_f16_speedup": 4.0,
        "allocs_per_step_fused": 0,
        "lossless_wire_bytes": 1000,
        "decode_allocs_per_step_fused": 0,
    }
    healthy = dict(baseline, fused_gbps_10m=9.5, simd_quantize_f16_speedup=3.8)
    regressed = dict(
        baseline,
        fused_gbps_10m=2.0,            # throughput collapse
        allocs_per_step_fused=3,       # zero-alloc contract broken
        lossless_wire_bytes=5000,      # wire bloat
    )
    failures = []
    with tempfile.TemporaryDirectory() as tmp:
        base_dir = os.path.join(tmp, "baselines")
        os.makedirs(base_dir)
        with open(os.path.join(base_dir, "BENCH_compress.json"), "w") as f:
            json.dump(baseline, f)

        def write_fresh(doc):
            with open(os.path.join(tmp, "BENCH_compress.json"), "w") as f:
                json.dump(doc, f)

        write_fresh(healthy)
        if run_compare(tmp, base_dir, 0.35, "block") != 0:
            failures.append("healthy run was flagged as a regression")
        write_fresh(regressed)
        if run_compare(tmp, base_dir, 0.35, "block") == 0:
            failures.append("regressed run passed the gate")
        if run_compare(tmp, base_dir, 0.35, "warn") != 0:
            failures.append("warn mode failed the build")
        # Exactness of the alloc gate: +1 alloc must trip even at huge tolerance.
        write_fresh(dict(baseline, allocs_per_step_fused=1))
        if run_compare(tmp, base_dir, 10.0, "block") == 0:
            failures.append("alloc increase slipped through tolerance")
    if failures:
        for f in failures:
            print(f"self-test FAILED: {f}")
        return 1
    print("perf-compare self-test: OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("PERF_TOLERANCE", DEFAULT_TOLERANCE)),
                    help="relative tolerance before a drift is a regression "
                         f"(default {DEFAULT_TOLERANCE}, env PERF_TOLERANCE)")
    ap.add_argument("--mode", choices=["block", "warn"],
                    default=os.environ.get("PERF_COMPARE_MODE", "block"),
                    help="block: exit 1 on regression (self-hosted); "
                         "warn: report but exit 0 (shared runners). env PERF_COMPARE_MODE")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the fresh BENCH_*.json (default: repo root)")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR,
                    help=f"committed baseline directory (default: {BASELINE_DIR})")
    ap.add_argument("--self-test", action="store_true",
                    help="synthesize a regressed run and assert the gate trips")
    args = ap.parse_args()
    if args.self_test:
        sys.exit(self_test())
    sys.exit(run_compare(args.fresh_dir, args.baseline_dir, args.tolerance, args.mode))


if __name__ == "__main__":
    main()
