#!/usr/bin/env python3
"""Validate the telemetry artifacts a live run emits (`make trace-smoke`).

Usage: check_trace.py TRACE.json METRICS.prom [JOURNAL.json [ANALYSIS.json]]

Checks, hard-failing on the first violation:
  trace    — well-formed Chrome trace_event JSON: complete events ("ph": "X")
             with non-negative ts/dur, spans on one thread properly nested,
             and the live loop's span labels all present. When the gather
             ran, the top-level `clockOffsetsNs` object must cover every
             rank track and pin rank 0 at offset 0.
  metrics  — parseable Prometheus text exposition whose histogram bucket
             counts are cumulative, with the run's core series present.
  journal  — (optional) decision-journal JSON: schema_version 1, records
             with known kinds, and every ratio transition chained
             old_ratio -> new_ratio -> next old_ratio.
  analysis — (optional) critical-path report: schema_version 1, per-step
             attribution (compute/compress/wire/decode/recovery) summing
             exactly to the step wall time, critical ranks in range, and
             straggler counts consistent with the attributed steps.
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_trace(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents missing or empty")
    by_tid = {}
    labels = set()
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts", "dur", "pid", "tid"):
            if key not in ev:
                fail(f"{path}: event {i} lacks `{key}`")
        if ev["ph"] != "X":
            fail(f"{path}: event {i} has ph={ev['ph']!r}, want complete events")
        if ev["ts"] < 0 or ev["dur"] < 0:
            fail(f"{path}: event {i} has negative ts/dur")
        labels.add(ev["name"])
        by_tid.setdefault(ev["tid"], []).append((ev["ts"], ev["ts"] + ev["dur"]))
    for want in ("step", "compress", "round", "decode"):
        if want not in labels:
            fail(f"{path}: no `{want}` spans (have {sorted(labels)})")
    # Within one thread, spans must nest: sorted by start, each span either
    # contains or is disjoint from the next (tolerance for µs rounding).
    eps = 1e-3
    for tid, spans in by_tid.items():
        # On a start-time tie the enclosing (longer) span must come first.
        spans.sort(key=lambda x: (x[0], -x[1]))
        stack = []
        for s, e in spans:
            while stack and s >= stack[-1] - eps:
                stack.pop()
            if stack and e > stack[-1] + eps:
                fail(f"{path}: tid {tid}: span [{s}, {e}] crosses enclosing end {stack[-1]}")
            stack.append(e)
    # The gather embeds the clock offsets it applied; when present they
    # must cover every rank track and rank 0 (the reference) must be 0.
    offsets = doc.get("clockOffsetsNs")
    if offsets is not None:
        if not isinstance(offsets, dict) or not offsets:
            fail(f"{path}: clockOffsetsNs present but not a non-empty object")
        for rank, off in offsets.items():
            if not isinstance(off, (int, float)):
                fail(f"{path}: clockOffsetsNs[{rank!r}] is not a number")
        if offsets.get("0") not in (0, 0.0):
            fail(f"{path}: clockOffsetsNs['0'] must be 0, got {offsets.get('0')!r}")
        for tid in by_tid:
            if str(tid) not in offsets:
                fail(f"{path}: rank track {tid} has no clockOffsetsNs entry")
    print(f"check_trace: {path}: {len(events)} events across {len(by_tid)} ranks, "
          f"labels {sorted(labels)}"
          + (f", {len(offsets)} clock offsets" if offsets else ""))


def check_metrics(path: str) -> None:
    with open(path) as f:
        text = f.read()
    seen = set()
    buckets = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line or line.startswith("#"):
            continue
        parts = line.rsplit(None, 1)
        if len(parts) != 2:
            fail(f"{path}:{lineno}: not `name value`: {line!r}")
        name, value = parts
        try:
            v = float(value)
        except ValueError:
            fail(f"{path}:{lineno}: non-numeric value {value!r}")
        base = name.split("{")[0]
        seen.add(base)
        if base.endswith("_bucket"):
            series = buckets.setdefault(base, [])
            if series and v < series[-1]:
                fail(f"{path}:{lineno}: {base} counts not cumulative ({v} < {series[-1]})")
            series.append(v)
        elif base.endswith("_count") and v < 0:
            fail(f"{path}:{lineno}: negative count")
    for want in ("netsense_rounds_total", "netsense_rtt_us_bucket",
                 "netsense_compress_ns_bucket", "netsense_decode_ns_bucket",
                 "netsense_frame_bytes_bucket", "netsense_ratio"):
        if want not in seen:
            fail(f"{path}: series `{want}` missing")
    print(f"check_trace: {path}: {len(seen)} series, histogram buckets cumulative")


def check_journal(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema_version") != 1:
        fail(f"{path}: schema_version != 1")
    records = doc.get("records")
    if not isinstance(records, list) or not records:
        fail(f"{path}: records missing or empty")
    kinds = {"ratio", "round", "membership", "straggler", "congestion"}
    prev_new = None
    n_ratio = 0
    for i, r in enumerate(records):
        if r.get("kind") not in kinds:
            fail(f"{path}: record {i} has kind {r.get('kind')!r}")
        if r["kind"] != "ratio":
            continue
        n_ratio += 1
        if prev_new is not None and abs(r["old_ratio"] - prev_new) > 1e-12:
            fail(f"{path}: record {i} breaks the ratio chain "
                 f"({prev_new} -> old_ratio {r['old_ratio']})")
        prev_new = r["new_ratio"]
    if n_ratio == 0:
        fail(f"{path}: no ratio transitions recorded")
    print(f"check_trace: {path}: {len(records)} records, {n_ratio}-link ratio chain intact")


def check_analysis(path: str) -> None:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema_version") != 1:
        fail(f"{path}: schema_version != 1")
    n_ranks = doc.get("n_ranks")
    if not isinstance(n_ranks, int) or n_ranks < 1:
        fail(f"{path}: n_ranks {n_ranks!r} not a positive integer")
    steps = doc.get("steps")
    if not isinstance(steps, list) or not steps:
        fail(f"{path}: steps missing or empty")
    parts = ("compute_ns", "compress_ns", "wire_ns", "decode_ns", "recovery_ns")
    attributed = 0
    for i, b in enumerate(steps):
        for key in ("step", "wall_ns") + parts:
            if not isinstance(b.get(key), (int, float)) or b[key] < 0:
                fail(f"{path}: step {i}: `{key}` missing or negative")
        # The analyzer assigns every wall nanosecond to exactly one part.
        total = sum(b[k] for k in parts)
        if total != b["wall_ns"]:
            fail(f"{path}: step {i}: parts sum to {total}, wall_ns {b['wall_ns']}")
        crit = b.get("critical_rank")
        if crit is not None:
            if not isinstance(crit, int) or not 0 <= crit < n_ranks:
                fail(f"{path}: step {i}: critical_rank {crit!r} out of range")
            attributed += 1
    counts = doc.get("straggler_counts")
    if not isinstance(counts, list) or len(counts) != n_ranks:
        fail(f"{path}: straggler_counts must list one count per rank")
    if sum(counts) != attributed:
        fail(f"{path}: straggler_counts sum {sum(counts)} != {attributed} attributed steps")
    verdict = doc.get("straggler_verdict")
    if verdict is not None and (not isinstance(verdict, int) or not 0 <= verdict < n_ranks):
        fail(f"{path}: straggler_verdict {verdict!r} out of range")
    if not isinstance(doc.get("congestion_verdict"), bool):
        fail(f"{path}: congestion_verdict missing or not a bool")
    if not isinstance(doc.get("efficacy"), list):
        fail(f"{path}: efficacy missing or not a list")
    print(f"check_trace: {path}: {len(steps)} steps, {attributed} attributed, "
          f"straggler_verdict={verdict}")


def main() -> None:
    if len(sys.argv) not in (3, 4, 5):
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    check_trace(sys.argv[1])
    check_metrics(sys.argv[2])
    if len(sys.argv) >= 4:
        check_journal(sys.argv[3])
    if len(sys.argv) == 5:
        check_analysis(sys.argv[4])
    print("check_trace: OK")


if __name__ == "__main__":
    main()
