//! Sensing-layer cost: estimator updates and controller intervals must be
//! O(ns–µs) so they never gate the coordinator (§Perf), plus the Fig 2
//! sweep as an end-to-end timing reference.

use netsenseml::experiments::fig2::fig2;
use netsenseml::experiments::scenario::RunOpts;
use netsenseml::netsim::SimTime;
use netsenseml::sensing::{BandwidthEstimator, ControllerConfig, EstimatorConfig, RatioController};
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();

    b.group("estimator");
    let mut est = BandwidthEstimator::new(EstimatorConfig::default());
    let mut i = 0u64;
    b.run("observe + estimate", || {
        i += 1;
        est.observe(1_000_000 + (i % 997) * 1000, SimTime::from_micros(40_000 + (i % 31) * 100));
        bb(est.estimate());
    });

    b.group("controller (Algorithm 1)");
    let mut ctl = RatioController::new(ControllerConfig::default());
    let mut j = 0u64;
    b.run("on_interval", || {
        j += 1;
        bb(ctl.on_interval(
            500_000 + (j % 1013) * 500,
            SimTime::from_micros(42_000 + (j % 17) * 500),
            false,
        ));
    });

    b.group("fig2 sweep (end-to-end)");
    b.run_once("full sensing sweep", || {
        bb(fig2(&RunOpts::default()));
    });

    b.finish();
}
