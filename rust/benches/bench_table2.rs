//! End-to-end experiment bench: regenerates Table 2 (VGG16, 2.5/5/10 Gbps)
//! in fast mode (10× shorter horizons) and reports the wall time.
//! The full-scale table is produced by `netsenseml repro table2`.

use netsenseml::experiments::tables::table2;
use netsenseml::experiments::scenario::RunOpts;
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();
    let opts = RunOpts {
        fast: true,
        out_dir: None,
        seed: 42,
        n_workers: 8,
        fidelity_every: 0, // timing-only: keeps the bench wall-time bounded
    };
    b.group("Table 2 (VGG16, 2.5/5/10 Gbps)");
    b.run_once("table2 (fast mode)", || {
        let (table, _) = table2(&opts);
        bb(table).print();
    });
    b.finish();
}
