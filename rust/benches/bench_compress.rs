//! Compression hot-path benchmarks (the L3 §Perf targets): top-k selection
//! on paper-scale tensors, quantization, sparse codec, and the full
//! Algorithm-2 pipeline. Run: `cargo bench --bench bench_compress`.

use netsenseml::compress::quantize::{f32_to_f16_bits, Precision};
use netsenseml::compress::topk::{top_k_indices, top_k_with_threshold_hint};
use netsenseml::compress::{CompressionConfig, NetSenseCompressor, SparseGradient};
use netsenseml::util::bench::{bb, Bench};
use netsenseml::util::rng::Pcg64;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg64::seeded(seed);
    let mut v = vec![0f32; n];
    r.fill_normal_f32(&mut v, 0.0, 1.0);
    v
}

fn main() {
    let mut b = Bench::new();
    let n = 11_550_000; // ResNet18
    let g = randn(n, 1);
    let w = randn(n, 2);

    b.group("topk (11.55M elems, ResNet18-size)");
    b.run_throughput("exact quickselect k=1%", n as u64, || {
        bb(top_k_indices(bb(&g), n / 100));
    });
    // Steady-state: reuse last step's threshold.
    let (_, kth) = top_k_with_threshold_hint(&g, n / 100, None, 0.25);
    b.run_throughput("threshold-reuse k=1%", n as u64, || {
        bb(top_k_with_threshold_hint(bb(&g), n / 100, Some(kth), 0.25));
    });
    b.run_throughput("exact quickselect k=10%", n as u64, || {
        bb(top_k_indices(bb(&g), n / 10));
    });

    b.group("quantize");
    b.run_throughput("f32→f16 11.55M", n as u64, || {
        let mut acc = 0u16;
        for &x in g.iter().step_by(1) {
            acc ^= f32_to_f16_bits(x);
        }
        bb(acc);
    });

    b.group("sparse codec (k = 115k)");
    let idx = top_k_indices(&g, n / 100);
    let sg = SparseGradient::gather(&g, idx, Precision::F32);
    b.run_throughput("encode", sg.nnz() as u64, || {
        bb(sg.encode());
    });
    let wire = sg.encode();
    b.run_throughput("decode", sg.nnz() as u64, || {
        bb(SparseGradient::decode(bb(&wire)).unwrap());
    });
    let mut acc_buf = vec![0f32; n];
    b.run_throughput("add_into (aggregate)", sg.nnz() as u64, || {
        sg.add_into(bb(&mut acc_buf));
    });

    b.group("Algorithm 2 pipeline (ResNet18-size)");
    let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
    b.run_throughput("compress ratio=0.01 (steady)", n as u64, || {
        bb(c.compress(bb(&g), bb(&w), 0.01));
    });
    let mut c2 = NetSenseCompressor::new(n, CompressionConfig::default());
    b.run_throughput("compress ratio=0.1 (steady)", n as u64, || {
        bb(c2.compress(bb(&g), bb(&w), 0.1));
    });

    b.finish();
}
