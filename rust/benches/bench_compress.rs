//! Compression hot-path benchmarks (the L3 §Perf targets): the fused
//! zero-copy gradient→wire path vs the staged reference
//! (compress → encode → encode_frame), parallel per-bucket compression
//! scaling, allocs-per-step, and the original micro-benchmarks (top-k,
//! quantization, sparse codec). Emits the machine-readable baseline
//! `BENCH_compress.json` at the repo root (`make bench-json`).
//! Run: `cargo bench --bench bench_compress`.

mod common;

use common::{gbps, BenchJson};
use netsenseml::compress::bucket::{BucketLayout, BucketedCompressor};
use netsenseml::compress::quantize::{f32_to_f16_bits, Precision};
use netsenseml::compress::simd::{self, SimdLevel};
use netsenseml::compress::topk::{top_k_indices, top_k_with_threshold_hint};
use netsenseml::compress::{
    decode_reduce_frame_into, decode_reduce_into, CompressionConfig, NetSenseCompressor,
    SparseGradient, Workspace, WorkspacePool,
};
use netsenseml::testing::alloc::{thread_alloc_count, CountingAlloc};
use netsenseml::transport::frame::encode_frame;
use netsenseml::util::bench::{bb, Bench};
use netsenseml::util::rng::Pcg64;

// Count allocations so the baseline records allocs/step for both paths.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg64::seeded(seed);
    let mut v = vec![0f32; n];
    r.fill_normal_f32(&mut v, 0.0, 1.0);
    v
}

/// One staged reference step: Algorithm 2 → COO encode → transport frame.
fn staged_step(c: &mut NetSenseCompressor, g: &[f32], w: &[f32], ratio: f64) -> Vec<u8> {
    let out = c.compress(g, w, ratio);
    encode_frame(&out.payload.encode())
}

/// Mean allocations per call of `step` after a short warmup.
fn allocs_per_step(mut step: impl FnMut()) -> u64 {
    for _ in 0..3 {
        step();
    }
    let before = thread_alloc_count();
    let iters = 5u64;
    for _ in 0..iters {
        step();
    }
    (thread_alloc_count() - before) / iters
}

fn main() {
    let mut b = Bench::new();
    let mut json = BenchJson::new("compress");

    // ---- fused vs staged gradient→wire, 1M and 10M elements ------------
    for &(n, tag) in &[(1_000_000usize, "1m"), (10_000_000usize, "10m")] {
        let g = randn(n, 1);
        let w = randn(n, 2);
        b.group(&format!("Algorithm 2 → wire frame ({tag} elems, ratio 0.1)"));

        let mut staged_c = NetSenseCompressor::new(n, CompressionConfig::default());
        let staged = b
            .run_throughput("staged compress→encode→frame", n as u64, || {
                bb(staged_step(&mut staged_c, bb(&g), bb(&w), 0.1));
            })
            .clone();

        let mut fused_c = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut ws = Workspace::with_capacity(n);
        let mut wire: Vec<u8> = Vec::new();
        let fused = b
            .run_throughput("fused compress_frame_into", n as u64, || {
                wire.clear();
                bb(fused_c.compress_frame_into(bb(&g), bb(&w), 0.1, &mut ws, &mut wire));
            })
            .clone();

        let speedup = staged.mean.as_secs_f64() / fused.mean.as_secs_f64();
        eprintln!("  fused vs staged speedup ({tag}): {speedup:.2}x");
        json.set(&format!("staged_gbps_{tag}"), gbps(n, staged.mean));
        json.set(&format!("fused_gbps_{tag}"), gbps(n, fused.mean));
        json.set(&format!("fused_vs_staged_speedup_{tag}"), speedup);

        if tag == "10m" {
            let mut c1 = NetSenseCompressor::new(n, CompressionConfig::default());
            let staged_allocs = allocs_per_step(|| {
                bb(staged_step(&mut c1, &g, &w, 0.1));
            });
            let mut c2 = NetSenseCompressor::new(n, CompressionConfig::default());
            let mut ws2 = Workspace::with_capacity(n);
            let mut wire2: Vec<u8> = Vec::new();
            let fused_allocs = allocs_per_step(|| {
                wire2.clear();
                bb(c2.compress_frame_into(&g, &w, 0.1, &mut ws2, &mut wire2));
            });
            eprintln!("  allocs/step: staged {staged_allocs}, fused {fused_allocs}");
            json.set("allocs_per_step_staged", staged_allocs);
            json.set("allocs_per_step_fused", fused_allocs);
        }
    }

    // ---- fused vs staged decode-reduce (the receive half) ---------------
    for &(n, tag) in &[(1_000_000usize, "1m"), (10_000_000usize, "10m")] {
        let g = randn(n, 5);
        let w = randn(n, 6);
        // One realistic wire payload (ratio 0.1, warm compressor).
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut ws = Workspace::with_capacity(n);
        let mut payload: Vec<u8> = Vec::new();
        c.compress_payload_into(&g, &w, 0.1, &mut ws, &mut payload);
        b.group(&format!("wire → dense decode-reduce ({tag} elems, ratio 0.1)"));

        let mut acc1 = vec![0f32; n];
        let staged = b
            .run_throughput("staged decode + add_into", n as u64, || {
                let s = SparseGradient::decode(bb(&payload)).unwrap();
                s.add_into(bb(&mut acc1));
            })
            .clone();

        let mut acc2 = vec![0f32; n];
        let fused = b
            .run_throughput("fused decode_reduce_into", n as u64, || {
                bb(decode_reduce_into(bb(&payload), bb(&mut acc2)).unwrap());
            })
            .clone();

        let speedup = staged.mean.as_secs_f64() / fused.mean.as_secs_f64();
        eprintln!("  fused vs staged decode speedup ({tag}): {speedup:.2}x");
        json.set(&format!("decode_staged_gbps_{tag}"), gbps(n, staged.mean));
        json.set(&format!("decode_fused_gbps_{tag}"), gbps(n, fused.mean));
        json.set(&format!("decode_fused_vs_staged_speedup_{tag}"), speedup);

        if tag == "10m" {
            let staged_allocs = allocs_per_step(|| {
                let s = SparseGradient::decode(&payload).unwrap();
                s.add_into(bb(&mut acc1));
            });
            let fused_allocs = allocs_per_step(|| {
                bb(decode_reduce_into(&payload, bb(&mut acc2)).unwrap());
            });
            eprintln!("  decode allocs/step: staged {staged_allocs}, fused {fused_allocs}");
            json.set("decode_allocs_per_step_staged", staged_allocs);
            json.set("decode_allocs_per_step_fused", fused_allocs);
        }
    }

    // ---- decode-reduce over the standard bucket sweep -------------------
    {
        let n = 10_000_000usize;
        let g = randn(n, 7);
        let w = randn(n, 8);
        let layout = BucketLayout::new(n, 1 << 20); // 4 MB dense buckets
        let mut bc = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        let mut pool = WorkspacePool::new(1);
        let frames: Vec<Vec<u8>> = {
            let (_, frames) = bc.compress_frames(&g, &w, 0.1, &mut pool);
            frames.to_vec()
        };
        b.group("bucketed decode-reduce (10M elems, 4MB buckets, ratio 0.1)");
        let mut parts: Vec<Vec<f32>> =
            (0..layout.n_buckets()).map(|i| vec![0f32; layout.elems(i)]).collect();
        let staged = b
            .run_throughput("staged per-bucket decode + add_into", n as u64, || {
                for (i, frame) in frames.iter().enumerate() {
                    let s = SparseGradient::decode(&frame[8..]).unwrap();
                    s.add_into(bb(&mut parts[i]));
                }
            })
            .clone();
        let fused = b
            .run_throughput("fused per-bucket decode_reduce_frame_into", n as u64, || {
                for (i, frame) in frames.iter().enumerate() {
                    bb(decode_reduce_frame_into(bb(frame), bb(&mut parts[i])).unwrap());
                }
            })
            .clone();
        let speedup = staged.mean.as_secs_f64() / fused.mean.as_secs_f64();
        eprintln!("  bucketed fused vs staged decode speedup: {speedup:.2}x");
        json.set("decode_bucketed_staged_gbps", gbps(n, staged.mean));
        json.set("decode_bucketed_fused_gbps", gbps(n, fused.mean));
        json.set("decode_bucketed_fused_vs_staged_speedup", speedup);
    }

    // ---- parallel per-bucket compression --------------------------------
    {
        let n = 10_000_000usize;
        let g = randn(n, 3);
        let w = randn(n, 4);
        let layout = BucketLayout::new(n, 1 << 20); // 4 MB dense buckets
        let n_buckets = layout.n_buckets();
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        b.group("parallel per-bucket compression (10M elems, 4MB buckets, ratio 0.1)");

        let mut bc1 = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        let mut pool1 = WorkspacePool::new(1);
        let serial = b
            .run_throughput("pool=1 (inline, no spawns)", n as u64, || {
                bb(bc1.compress_frames(bb(&g), bb(&w), 0.1, &mut pool1));
            })
            .clone();

        let mut bcn = BucketedCompressor::new(layout, CompressionConfig::default());
        let mut pooln = WorkspacePool::with_available_parallelism();
        let par = b
            .run_throughput(
                &format!("pool={threads} (scoped threads)"),
                n as u64,
                || {
                    bb(bcn.compress_frames(bb(&g), bb(&w), 0.1, &mut pooln));
                },
            )
            .clone();

        let scaling = serial.mean.as_secs_f64() / par.mean.as_secs_f64();
        eprintln!("  parallel speedup at {threads} threads / {n_buckets} buckets: {scaling:.2}x");
        json.set("parallel_threads", threads as u64);
        json.set("parallel_buckets", n_buckets as u64);
        json.set("parallel_gbps_pool1", gbps(n, serial.mean));
        json.set("parallel_gbps", gbps(n, par.mean));
        json.set("parallel_speedup", scaling);
    }

    // ---- original micro-benchmarks (ResNet18-size) ----------------------
    let n = 11_550_000; // ResNet18
    let g = randn(n, 1);
    let w = randn(n, 2);

    b.group("topk (11.55M elems, ResNet18-size)");
    let topk = b
        .run_throughput("exact quickselect k=1%", n as u64, || {
            bb(top_k_indices(bb(&g), n / 100));
        })
        .clone();
    json.set("topk_exact_melem_per_s", topk.throughput_per_sec().unwrap_or(0.0) / 1e6);
    // Steady-state: reuse last step's threshold.
    let (_, kth) = top_k_with_threshold_hint(&g, n / 100, None, 0.25);
    b.run_throughput("threshold-reuse k=1%", n as u64, || {
        bb(top_k_with_threshold_hint(bb(&g), n / 100, Some(kth), 0.25));
    });

    b.group("quantize");
    b.run_throughput("f32→f16 11.55M", n as u64, || {
        let mut acc = 0u16;
        for &x in g.iter().step_by(1) {
            acc ^= f32_to_f16_bits(x);
        }
        bb(acc);
    });

    b.group("sparse codec (k = 115k)");
    let idx = top_k_indices(&g, n / 100);
    let sg = SparseGradient::gather(&g, idx, Precision::F32);
    let mut enc_buf = Vec::new();
    b.run_throughput("encode_into (reused buffer)", sg.nnz() as u64, || {
        enc_buf.clear();
        sg.encode_into(bb(&mut enc_buf));
    });
    let wire = sg.encode();
    b.run_throughput("decode", sg.nnz() as u64, || {
        bb(SparseGradient::decode(bb(&wire)).unwrap());
    });
    let mut acc_buf = vec![0f32; n];
    b.run_throughput("add_into (aggregate)", sg.nnz() as u64, || {
        sg.add_into(bb(&mut acc_buf));
    });

    // ---- SIMD kernels vs scalar reference (the tentpole trajectory) -----
    {
        let active = simd::active_level();
        let level_tag = format!("{active:?}").to_lowercase();
        json.set("simd_level", level_tag.as_str());
        b.group(&format!(
            "simd kernels, scalar vs {level_tag} (11.55M elems)"
        ));

        // quantize f32 → f16 bits
        let mut bits = vec![0u16; n];
        let q_scalar = b
            .run_throughput("quantize f16 scalar", n as u64, || {
                simd::quantize_f16_bits_with(SimdLevel::Scalar, bb(&g), bb(&mut bits));
            })
            .clone();
        let q_simd = b
            .run_throughput(&format!("quantize f16 {level_tag}"), n as u64, || {
                simd::quantize_f16_bits_with(active, bb(&g), bb(&mut bits));
            })
            .clone();
        json.set("simd_quantize_f16_scalar_gbps", gbps(n, q_scalar.mean));
        json.set("simd_quantize_f16_gbps", gbps(n, q_simd.mean));
        json.set(
            "simd_quantize_f16_speedup",
            q_scalar.mean.as_secs_f64() / q_simd.mean.as_secs_f64(),
        );

        // dequantize f16 bits → f32
        let mut floats = vec![0f32; n];
        let d_scalar = b
            .run_throughput("dequantize f16 scalar", n as u64, || {
                simd::dequantize_f16_bits_with(SimdLevel::Scalar, bb(&bits), bb(&mut floats));
            })
            .clone();
        let d_simd = b
            .run_throughput(&format!("dequantize f16 {level_tag}"), n as u64, || {
                simd::dequantize_f16_bits_with(active, bb(&bits), bb(&mut floats));
            })
            .clone();
        json.set("simd_dequantize_f16_scalar_gbps", gbps(n, d_scalar.mean));
        json.set("simd_dequantize_f16_gbps", gbps(n, d_simd.mean));
        json.set(
            "simd_dequantize_f16_speedup",
            d_scalar.mean.as_secs_f64() / d_simd.mean.as_secs_f64(),
        );

        // threshold scan (the steady-state top-k pre-filter, ~1% pass rate)
        let (_, kth) = top_k_with_threshold_hint(&g, n / 100, None, 0.25);
        let mut sel = Vec::with_capacity(n);
        let t_scalar = b
            .run_throughput("threshold scan scalar", n as u64, || {
                simd::threshold_select_into_with(SimdLevel::Scalar, bb(&g), kth, bb(&mut sel));
            })
            .clone();
        let t_simd = b
            .run_throughput(&format!("threshold scan {level_tag}"), n as u64, || {
                simd::threshold_select_into_with(active, bb(&g), kth, bb(&mut sel));
            })
            .clone();
        json.set("simd_threshold_scan_scalar_gbps", gbps(n, t_scalar.mean));
        json.set("simd_threshold_scan_gbps", gbps(n, t_simd.mean));
        json.set(
            "simd_threshold_scan_speedup",
            t_scalar.mean.as_secs_f64() / t_simd.mean.as_secs_f64(),
        );

        // fused compensate + striped L2 sweep
        let residual = randn(n, 9);
        let mut comp = Vec::with_capacity(n);
        let c_scalar = b
            .run_throughput("compensate+L2 scalar", n as u64, || {
                bb(simd::compensate_sum_sq_extend_with(
                    SimdLevel::Scalar,
                    bb(&g),
                    bb(&residual),
                    &mut comp,
                ));
            })
            .clone();
        let c_simd = b
            .run_throughput(&format!("compensate+L2 {level_tag}"), n as u64, || {
                bb(simd::compensate_sum_sq_extend_with(
                    active,
                    bb(&g),
                    bb(&residual),
                    &mut comp,
                ));
            })
            .clone();
        json.set("simd_compensate_l2_scalar_gbps", gbps(n, c_scalar.mean));
        json.set("simd_compensate_l2_gbps", gbps(n, c_simd.mean));
        json.set(
            "simd_compensate_l2_speedup",
            c_scalar.mean.as_secs_f64() / c_simd.mean.as_secs_f64(),
        );
    }

    // ---- lossless stage: wire reduction + fused round-trip --------------
    {
        let cfg = CompressionConfig {
            lossless: true,
            ..Default::default()
        };
        // ratio 0.01 quantizes to f16 — the payload 3LC targets.
        let mut c = NetSenseCompressor::new(n, cfg.clone());
        let mut ws = Workspace::with_capacity(n);
        let mut frame: Vec<u8> = Vec::new();
        b.group("lossless stage (11.55M elems, ratio 0.01 → f16)");
        let mut last_outcome = Default::default();
        let fused = b
            .run_throughput("fused compress_frame_into (lossless)", n as u64, || {
                frame.clear();
                last_outcome = bb(c.compress_frame_into(bb(&g), bb(&w), 0.01, &mut ws, &mut frame));
            })
            .clone();
        let o: netsenseml::compress::FusedOutcome = last_outcome;
        eprintln!(
            "  lossless wire {} vs raw {} ({:.2}x reduction, won: {})",
            o.wire_bytes,
            o.raw_wire_bytes,
            o.raw_wire_bytes as f64 / o.wire_bytes as f64,
            o.lossless
        );
        json.set("lossless_wire_bytes", o.wire_bytes);
        json.set("lossless_raw_wire_bytes", o.raw_wire_bytes);
        json.set(
            "lossless_wire_reduction",
            o.raw_wire_bytes as f64 / o.wire_bytes as f64,
        );
        json.set("lossless_fused_gbps", gbps(n, fused.mean));

        let mut acc = vec![0f32; n];
        let dec = b
            .run_throughput("fused decode_reduce_frame_into (lossless)", n as u64, || {
                bb(decode_reduce_frame_into(bb(&frame), bb(&mut acc)).unwrap());
            })
            .clone();
        json.set("lossless_decode_gbps", gbps(n, dec.mean));

        let mut c2 = NetSenseCompressor::new(n, cfg);
        let mut ws2 = Workspace::with_capacity(n);
        let mut frame2: Vec<u8> = Vec::new();
        let mut acc2 = vec![0f32; n];
        let lossless_allocs = allocs_per_step(|| {
            frame2.clear();
            bb(c2.compress_frame_into(&g, &w, 0.01, &mut ws2, &mut frame2));
            bb(decode_reduce_frame_into(&frame2, bb(&mut acc2)).unwrap());
        });
        eprintln!("  lossless round-trip allocs/step: {lossless_allocs}");
        json.set("lossless_allocs_per_step", lossless_allocs);
    }

    b.group("Algorithm 2 staged pipeline (ResNet18-size)");
    let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
    b.run_throughput("compress ratio=0.01 (steady)", n as u64, || {
        bb(c.compress(bb(&g), bb(&w), 0.01));
    });
    let mut c2 = NetSenseCompressor::new(n, CompressionConfig::default());
    b.run_throughput("compress ratio=0.1 (steady)", n as u64, || {
        bb(c2.compress(bb(&g), bb(&w), 0.1));
    });

    b.finish();
    json.write();
}
