//! Collective scheduling + numeric reduction rates.

use netsenseml::collectives::{ring_allgather, ring_allreduce, sum_dense};
use netsenseml::netsim::schedule::mbps;
use netsenseml::netsim::topology::StarTopology;
use netsenseml::netsim::{NetSim, SimTime};
use netsenseml::util::bench::{bb, Bench};
use netsenseml::util::rng::Pcg64;

fn main() {
    let mut b = Bench::new();

    b.group("timing models (8 workers)");
    let mut sim = NetSim::quiet(StarTopology::constant(8, mbps(10_000.0), SimTime::from_millis(1)));
    b.run("ring_allreduce schedule (46 MB)", || {
        bb(ring_allreduce(&mut sim, 46_200_000));
    });
    let payloads = vec![1_000_000u64; 8];
    let mut sim2 = NetSim::quiet(StarTopology::constant(8, mbps(10_000.0), SimTime::from_millis(1)));
    b.run("ring_allgather schedule (8×1 MB)", || {
        bb(ring_allgather(&mut sim2, bb(&payloads)));
    });

    b.group("numeric reduction (11.55M f32)");
    let n = 11_550_000;
    let mut r = Pcg64::seeded(1);
    let mut acc = vec![0f32; n];
    r.fill_normal_f32(&mut acc, 0.0, 1.0);
    let other = acc.clone();
    b.run_throughput("sum_dense one peer", n as u64, || {
        sum_dense(bb(&mut acc), &[bb(&other)]);
    });

    b.finish();
}
