//! End-to-end experiment bench: regenerates Table 1 (ResNet18, 200/500/800 Mbps)
//! in fast mode (10× shorter horizons) and reports the wall time.
//! The full-scale table is produced by `netsenseml repro table1`.

use netsenseml::experiments::tables::table1;
use netsenseml::experiments::scenario::RunOpts;
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();
    let opts = RunOpts {
        fast: true,
        out_dir: None,
        seed: 42,
        n_workers: 8,
        fidelity_every: 0, // timing-only: keeps the bench wall-time bounded
    };
    b.group("Table 1 (ResNet18, 200/500/800 Mbps)");
    b.run_once("table1 (fast mode)", || {
        let (table, _) = table1(&opts);
        bb(table).print();
    });
    b.finish();
}
