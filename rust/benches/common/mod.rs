//! Shared machine-readable bench reporting: each bench binary records its
//! headline metrics here and writes one `BENCH_<name>.json` at the repo
//! root — the perf-trajectory artifact `make bench-json` produces and CI
//! regenerates on every run (EXPERIMENTS.md "Perf baselines").
//!
//! Kept deliberately tiny: a flat string→number/string map on top of
//! [`netsenseml::util::json`], no schema machinery. Consumers diff fields
//! across commits; adding a field is always safe, renaming one is not.

// Each bench binary compiles its own copy and uses a subset of helpers.
#![allow(dead_code)]

use netsenseml::util::json::Json;
use std::collections::BTreeMap;
use std::time::{SystemTime, UNIX_EPOCH};

/// Builder for one `BENCH_<name>.json` baseline file.
pub struct BenchJson {
    name: String,
    fields: BTreeMap<String, Json>,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        let mut fields = BTreeMap::new();
        fields.insert("bench".to_string(), Json::from(name));
        fields.insert("schema_version".to_string(), Json::from(1u64));
        fields.insert(
            "fast_mode".to_string(),
            Json::from(std::env::var("NETSENSE_BENCH_FAST").ok().as_deref() == Some("1")),
        );
        fields.insert(
            "unix_time_s".to_string(),
            Json::from(
                SystemTime::now()
                    .duration_since(UNIX_EPOCH)
                    .map(|d| d.as_secs())
                    .unwrap_or(0),
            ),
        );
        BenchJson {
            name: name.to_string(),
            fields,
        }
    }

    /// Record one metric (numbers, strings, bools — anything `Json`-able).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        self.fields.insert(key.to_string(), value.into());
        self
    }

    /// Write `BENCH_<name>.json` into the current directory (cargo bench
    /// runs from the workspace root, so that is the repo root).
    pub fn write(&self) {
        let path = format!("BENCH_{}.json", self.name);
        let json = Json::Obj(self.fields.clone()).to_string_pretty();
        match std::fs::write(&path, json + "\n") {
            Ok(()) => eprintln!("\nwrote {path}"),
            Err(e) => eprintln!("\nWARNING: could not write {path}: {e}"),
        }
    }
}

/// Dense-f32 GB/s from a per-call mean duration over `elems` elements.
pub fn gbps(elems: usize, mean: std::time::Duration) -> f64 {
    (elems as f64 * 4.0) / mean.as_secs_f64() / 1e9
}
