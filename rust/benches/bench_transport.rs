//! Transport-layer micro-benchmarks: frame codec throughput, loopback
//! ring-collective throughput (the satellite registered in the Makefile as
//! `make bench-transport`), elastic-envelope overhead, and token-bucket
//! overhead on the unshaped path. Honors `NETSENSE_BENCH_FAST=1` via the
//! shared harness and emits the machine-readable baseline
//! `BENCH_transport.json` at the repo root (`make bench-json`).

mod common;

use common::BenchJson;
use netsenseml::fault::{parse_envelope, write_envelope, FrameKind};
use netsenseml::transport::{
    encode_frame, decode_frame, read_frame_into, ring_allgather_frames, ring_allreduce_f32,
    write_frame, LoopbackTransport, ShapedTransport, ShapingConfig, Transport,
};
use netsenseml::util::bench::{bb, Bench};
use netsenseml::util::poller::Poller;
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

fn main() {
    let mut b = Bench::new();
    let mut json = BenchJson::new("transport");

    b.group("frame codec");
    let payload = vec![0xABu8; 1 << 20];
    let enc = b
        .run_throughput("encode 1 MB", 1 << 20, || {
            bb(encode_frame(bb(&payload)));
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    let framed = encode_frame(&payload);
    let dec = b
        .run_throughput("decode 1 MB", 1 << 20, || {
            bb(decode_frame(bb(&framed)).unwrap());
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("frame_encode_gbps", enc / 1e9);
    json.set("frame_decode_gbps", dec / 1e9);

    b.group("elastic envelope (fault layer)");
    let mut env_buf: Vec<u8> = Vec::with_capacity((1 << 20) + 16);
    let env = b
        .run_throughput("wrap+parse 1 MB", 1 << 20, || {
            env_buf.clear();
            write_envelope(FrameKind::Data, 7, 42, &mut env_buf);
            env_buf.extend_from_slice(&payload);
            bb(parse_envelope(bb(&env_buf)).unwrap());
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("envelope_wrap_parse_gbps", env / 1e9);

    b.group("loopback collectives (4 ranks × 1 MB)");
    let block = vec![0x5Au8; 1 << 20];
    let ag = b
        .run_throughput("ring all-gather", 4 << 20, || {
            let mesh = LoopbackTransport::mesh(4);
            let handles: Vec<_> = mesh
                .into_iter()
                .map(|mut t| {
                    let payload = block.clone();
                    std::thread::spawn(move || {
                        bb(ring_allgather_frames(&mut t, &payload).unwrap());
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    let ar = b
        .run_throughput("ring all-reduce f32 (4 × 256k elems)", 4 << 20, || {
            let mesh = LoopbackTransport::mesh(4);
            let handles: Vec<_> = mesh
                .into_iter()
                .map(|mut t| {
                    std::thread::spawn(move || {
                        let mut data = vec![1.0f32; 1 << 18];
                        bb(ring_allreduce_f32(&mut t, &mut data).unwrap());
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("allgather_4x1mb_gbps", ag / 1e9);
    json.set("allreduce_4x256k_gbps", ar / 1e9);

    b.group("token bucket");
    // Rate far above the payload volume AND a burst far above one frame:
    // the bucket never goes into deficit, so this measures bookkeeping
    // overhead, not the deficit-sleep floor.
    let mut mesh = LoopbackTransport::mesh(2);
    let sink = mesh.pop().unwrap();
    let src = mesh.pop().unwrap();
    let mut unthrottled = ShapingConfig::constant(1e12);
    unthrottled.burst_bytes = 1e9;
    let mut shaped = ShapedTransport::new(src, unthrottled);
    let mut sink = sink;
    let msg = vec![0u8; 64 << 10];
    let tb = b
        .run_throughput("shaped send+recv 64 kB (unthrottled)", 64 << 10, || {
            shaped.send(1, &msg).unwrap();
            bb(sink.recv(0).unwrap());
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("shaped_sendrecv_gbps", tb / 1e9);

    // Event-loop fan-in over real sockets: N senders ship timestamped
    // 4 KiB frames to one receiver whose connections all ride the shared
    // epoll pool — versus the old design's thread-per-peer blocking
    // readers, rebuilt here inline as the reference harness. Frames/s is
    // the headline; p99 is the caller-visible sent→recv latency.
    b.group("event-loop fan-in (real TCP, 4 KiB frames)");
    let fast = std::env::var("NETSENSE_BENCH_FAST").ok().as_deref() == Some("1");
    let total_frames: usize = if fast { 2_048 } else { 12_800 };
    for &peers in &[4usize, 16, 64] {
        let frames = (total_frames / peers).max(8);
        let mut fps = 0.0;
        let mut p99_us = 0.0;
        b.run_once(&format!("evloop fan-in, {peers} peers"), || {
            let (elapsed_s, p99) = fanin_evloop(peers, frames, 4096);
            fps = (peers * frames) as f64 / elapsed_s;
            p99_us = p99;
        });
        json.set(&format!("evloop_p{peers}_frames_per_s"), fps);
        json.set(&format!("evloop_p{peers}_p99_latency_us"), p99_us);
        if peers == 16 {
            let mut ref_fps = 0.0;
            b.run_once("thread-per-peer reference, 16 peers", || {
                let (elapsed_s, _) = fanin_threadper(peers, frames, 4096);
                ref_fps = (peers * frames) as f64 / elapsed_s;
            });
            json.set(
                "evloop_p16_speedup",
                if ref_fps > 0.0 { fps / ref_fps } else { 0.0 },
            );
        }
    }
    // Informational (no higher/lower-is-better direction): reader-side
    // thread cost of each design at 16 peers. The event loop's pool is
    // process-global and fixed; the reference spawns one thread per peer.
    json.set(
        "threads_spawned_evloop",
        Poller::global().pool_size() as u64,
    );
    json.set("threads_spawned_threadper", 16u64);

    b.finish();
    json.write();
}

/// `peers` localhost connections fan into one receiver over the shared
/// event-loop pool. Senders stamp each 4 KiB payload with a send-time
/// offset; the receiver drains one frame per connection per pass
/// (round-robin, matching the collective receive pattern) and records the
/// caller-visible latency. Returns `(elapsed_s, p99_latency_us)`.
fn fanin_evloop(peers: usize, frames: usize, payload_len: usize) -> (f64, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let origin = Instant::now();
    let mut conns = Vec::with_capacity(peers);
    let mut senders = Vec::with_capacity(peers);
    for _ in 0..peers {
        let tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        conns.push(Poller::global().register(rx).unwrap());
        senders.push(tx);
    }
    let t0 = Instant::now();
    let threads: Vec<_> = senders
        .into_iter()
        .map(|mut tx| {
            std::thread::spawn(move || {
                let mut payload = vec![0u8; payload_len];
                for _ in 0..frames {
                    let ns = origin.elapsed().as_nanos() as u64;
                    payload[..8].copy_from_slice(&ns.to_le_bytes());
                    write_frame(&mut tx, &payload).unwrap();
                }
            })
        })
        .collect();
    let mut lat_ns: Vec<u64> = Vec::with_capacity(peers * frames);
    let mut buf: Vec<u8> = Vec::with_capacity(payload_len);
    for _ in 0..frames {
        for c in &conns {
            c.recv_frame_into(&mut buf, Duration::from_secs(30)).unwrap();
            let sent = u64::from_le_bytes(buf[..8].try_into().unwrap());
            lat_ns.push((origin.elapsed().as_nanos() as u64).saturating_sub(sent));
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    for th in threads {
        th.join().unwrap();
    }
    (elapsed, p99_us(&mut lat_ns))
}

/// The pre-event-loop design, rebuilt as the comparison baseline: one
/// blocking reader thread per connection, frames funneled to the caller
/// through an mpsc channel (which is exactly what the old transport's
/// per-peer readers did). Latency is measured where the caller sees the
/// frame — the channel pop.
fn fanin_threadper(peers: usize, frames: usize, payload_len: usize) -> (f64, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let origin = Instant::now();
    let (fan_tx, fan_rx) = std::sync::mpsc::channel::<u64>();
    let mut threads = Vec::with_capacity(2 * peers);
    for _ in 0..peers {
        let mut tx = TcpStream::connect(addr).unwrap();
        let (mut rx, _) = listener.accept().unwrap();
        let fan_tx = fan_tx.clone();
        threads.push(std::thread::spawn(move || {
            let mut buf: Vec<u8> = Vec::with_capacity(payload_len);
            for _ in 0..frames {
                read_frame_into(&mut rx, &mut buf).unwrap();
                let sent = u64::from_le_bytes(buf[..8].try_into().unwrap());
                let _ = fan_tx.send(sent);
            }
        }));
        threads.push(std::thread::spawn(move || {
            let mut payload = vec![0u8; payload_len];
            for _ in 0..frames {
                let ns = origin.elapsed().as_nanos() as u64;
                payload[..8].copy_from_slice(&ns.to_le_bytes());
                write_frame(&mut tx, &payload).unwrap();
            }
        }));
    }
    drop(fan_tx);
    let t0 = Instant::now();
    let mut lat_ns: Vec<u64> = Vec::with_capacity(peers * frames);
    for _ in 0..peers * frames {
        let sent = fan_rx.recv().unwrap();
        lat_ns.push((origin.elapsed().as_nanos() as u64).saturating_sub(sent));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    for th in threads {
        th.join().unwrap();
    }
    (elapsed, p99_us(&mut lat_ns))
}

/// p99 of a nanosecond sample set, in microseconds (sorts in place).
fn p99_us(lat_ns: &mut [u64]) -> f64 {
    if lat_ns.is_empty() {
        return 0.0;
    }
    lat_ns.sort_unstable();
    lat_ns[(lat_ns.len() - 1) * 99 / 100] as f64 / 1e3
}
