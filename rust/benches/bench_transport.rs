//! Transport-layer micro-benchmarks: frame codec throughput, loopback
//! ring-collective throughput (the satellite registered in the Makefile as
//! `make bench-transport`), elastic-envelope overhead, and token-bucket
//! overhead on the unshaped path. Honors `NETSENSE_BENCH_FAST=1` via the
//! shared harness and emits the machine-readable baseline
//! `BENCH_transport.json` at the repo root (`make bench-json`).

mod common;

use common::BenchJson;
use netsenseml::fault::{parse_envelope, write_envelope, FrameKind};
use netsenseml::transport::{
    encode_frame, decode_frame, ring_allgather_frames, ring_allreduce_f32, LoopbackTransport,
    ShapedTransport, ShapingConfig, Transport,
};
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();
    let mut json = BenchJson::new("transport");

    b.group("frame codec");
    let payload = vec![0xABu8; 1 << 20];
    let enc = b
        .run_throughput("encode 1 MB", 1 << 20, || {
            bb(encode_frame(bb(&payload)));
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    let framed = encode_frame(&payload);
    let dec = b
        .run_throughput("decode 1 MB", 1 << 20, || {
            bb(decode_frame(bb(&framed)).unwrap());
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("frame_encode_gbps", enc / 1e9);
    json.set("frame_decode_gbps", dec / 1e9);

    b.group("elastic envelope (fault layer)");
    let mut env_buf: Vec<u8> = Vec::with_capacity((1 << 20) + 16);
    let env = b
        .run_throughput("wrap+parse 1 MB", 1 << 20, || {
            env_buf.clear();
            write_envelope(FrameKind::Data, 7, 42, &mut env_buf);
            env_buf.extend_from_slice(&payload);
            bb(parse_envelope(bb(&env_buf)).unwrap());
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("envelope_wrap_parse_gbps", env / 1e9);

    b.group("loopback collectives (4 ranks × 1 MB)");
    let block = vec![0x5Au8; 1 << 20];
    let ag = b
        .run_throughput("ring all-gather", 4 << 20, || {
            let mesh = LoopbackTransport::mesh(4);
            let handles: Vec<_> = mesh
                .into_iter()
                .map(|mut t| {
                    let payload = block.clone();
                    std::thread::spawn(move || {
                        bb(ring_allgather_frames(&mut t, &payload).unwrap());
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    let ar = b
        .run_throughput("ring all-reduce f32 (4 × 256k elems)", 4 << 20, || {
            let mesh = LoopbackTransport::mesh(4);
            let handles: Vec<_> = mesh
                .into_iter()
                .map(|mut t| {
                    std::thread::spawn(move || {
                        let mut data = vec![1.0f32; 1 << 18];
                        bb(ring_allreduce_f32(&mut t, &mut data).unwrap());
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("allgather_4x1mb_gbps", ag / 1e9);
    json.set("allreduce_4x256k_gbps", ar / 1e9);

    b.group("token bucket");
    // Rate far above the payload volume AND a burst far above one frame:
    // the bucket never goes into deficit, so this measures bookkeeping
    // overhead, not the deficit-sleep floor.
    let mut mesh = LoopbackTransport::mesh(2);
    let sink = mesh.pop().unwrap();
    let src = mesh.pop().unwrap();
    let mut unthrottled = ShapingConfig::constant(1e12);
    unthrottled.burst_bytes = 1e9;
    let mut shaped = ShapedTransport::new(src, unthrottled);
    let mut sink = sink;
    let msg = vec![0u8; 64 << 10];
    let tb = b
        .run_throughput("shaped send+recv 64 kB (unthrottled)", 64 << 10, || {
            shaped.send(1, &msg).unwrap();
            bb(sink.recv(0).unwrap());
        })
        .throughput_per_sec()
        .unwrap_or(0.0);
    json.set("shaped_sendrecv_gbps", tb / 1e9);

    b.finish();
    json.write();
}
