//! Transport-layer micro-benchmarks: frame codec throughput, loopback
//! ring-collective throughput (the satellite registered in the Makefile as
//! `make bench-transport`), and token-bucket overhead on the unshaped
//! path. Honors `NETSENSE_BENCH_FAST=1` via the shared harness.

use netsenseml::transport::{
    encode_frame, decode_frame, ring_allgather_frames, ring_allreduce_f32, LoopbackTransport,
    ShapedTransport, ShapingConfig, Transport,
};
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();

    b.group("frame codec");
    let payload = vec![0xABu8; 1 << 20];
    b.run_throughput("encode 1 MB", 1 << 20, || {
        bb(encode_frame(bb(&payload)));
    });
    let framed = encode_frame(&payload);
    b.run_throughput("decode 1 MB", 1 << 20, || {
        bb(decode_frame(bb(&framed)).unwrap());
    });

    b.group("loopback collectives (4 ranks × 1 MB)");
    let block = vec![0x5Au8; 1 << 20];
    b.run_throughput("ring all-gather", 4 << 20, || {
        let mesh = LoopbackTransport::mesh(4);
        let handles: Vec<_> = mesh
            .into_iter()
            .map(|mut t| {
                let payload = block.clone();
                std::thread::spawn(move || {
                    bb(ring_allgather_frames(&mut t, &payload).unwrap());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    b.run_throughput("ring all-reduce f32 (4 × 256k elems)", 4 << 20, || {
        let mesh = LoopbackTransport::mesh(4);
        let handles: Vec<_> = mesh
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let mut data = vec![1.0f32; 1 << 18];
                    bb(ring_allreduce_f32(&mut t, &mut data).unwrap());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });

    b.group("token bucket");
    // Rate far above the payload volume AND a burst far above one frame:
    // the bucket never goes into deficit, so this measures bookkeeping
    // overhead, not the deficit-sleep floor.
    let mut mesh = LoopbackTransport::mesh(2);
    let sink = mesh.pop().unwrap();
    let src = mesh.pop().unwrap();
    let mut unthrottled = ShapingConfig::constant(1e12);
    unthrottled.burst_bytes = 1e9;
    let mut shaped = ShapedTransport::new(src, unthrottled);
    let mut sink = sink;
    let msg = vec![0u8; 64 << 10];
    b.run_throughput("shaped send+recv 64 kB (unthrottled)", 64 << 10, || {
        shaped.send(1, &msg).unwrap();
        bb(sink.recv(0).unwrap());
    });

    b.finish();
}
