//! Pipelined vs monolithic gradient exchange: regenerates the overlap
//! study (fluctuating-bandwidth scenario, ResNet18 payloads) in fast mode
//! and reports the wall time. The virtual-time table itself is the
//! artifact: pipelined schedules must beat the monolithic
//! compress-then-send baseline. Also emits the machine-readable
//! `BENCH_pipeline.json` baseline (`make bench-json`). Full-scale table:
//! `netsenseml repro pipeline`.

mod common;

use common::BenchJson;
use netsenseml::experiments::pipelined::pipeline_overlap;
use netsenseml::experiments::scenario::RunOpts;
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();
    let mut json = BenchJson::new("pipeline");
    let opts = RunOpts {
        fast: true,
        out_dir: None,
        seed: 42,
        n_workers: 8,
        fidelity_every: 0,
    };
    b.group("Pipelined vs monolithic exchange (fluctuating bandwidth)");
    let mut captured = None;
    let wall = b
        .run_once("pipeline overlap study (fast mode)", || {
            let (table, result) = pipeline_overlap(&opts);
            bb(table).print();
            captured = Some(result);
        })
        .clone();
    b.finish();

    let result = captured.expect("pipeline_overlap ran");
    let mono = &result.variants[0];
    json.set("wall_s", wall.mean.as_secs_f64());
    json.set("monolithic_total_s", mono.total_s);
    let mut best = 1.0f64;
    for (i, v) in result.variants[1..].iter().enumerate() {
        let verdict = if v.total_s < mono.total_s { "faster" } else { "SLOWER" };
        eprintln!(
            "  {}: {:.3}s vs monolithic {:.3}s ({:.3}x, {verdict})",
            v.label, v.total_s, mono.total_s, v.speedup
        );
        json.set(&format!("variant_{i}_label"), v.label.as_str());
        json.set(&format!("variant_{i}_total_s"), v.total_s);
        json.set(&format!("variant_{i}_speedup"), v.speedup);
        best = best.max(v.speedup);
    }
    json.set("best_pipelined_speedup", best);
    json.write();
}
