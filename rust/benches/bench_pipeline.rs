//! Pipelined vs monolithic gradient exchange: regenerates the overlap
//! study (fluctuating-bandwidth scenario, ResNet18 payloads) in fast mode
//! and reports the wall time. The virtual-time table itself is the
//! artifact: pipelined schedules must beat the monolithic
//! compress-then-send baseline. Full-scale table: `netsenseml repro
//! pipeline`.

use netsenseml::experiments::pipelined::pipeline_overlap;
use netsenseml::experiments::scenario::RunOpts;
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();
    let opts = RunOpts {
        fast: true,
        out_dir: None,
        seed: 42,
        n_workers: 8,
        fidelity_every: 0,
    };
    b.group("Pipelined vs monolithic exchange (fluctuating bandwidth)");
    b.run_once("pipeline overlap study (fast mode)", || {
        let (table, result) = pipeline_overlap(&opts);
        bb(table).print();
        let mono = &result.variants[0];
        for v in &result.variants[1..] {
            let verdict = if v.total_s < mono.total_s { "faster" } else { "SLOWER" };
            eprintln!(
                "  {}: {:.3}s vs monolithic {:.3}s ({:.3}x, {verdict})",
                v.label, v.total_s, mono.total_s, v.speedup
            );
        }
    });
    b.finish();
}
