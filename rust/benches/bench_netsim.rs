//! Network-simulator throughput: transfers/second and phase scheduling
//! rate (§Perf target: > 1M transfer events/s so virtual-time sweeps are
//! never netsim-bound).

use netsenseml::netsim::schedule::mbps;
use netsenseml::netsim::topology::StarTopology;
use netsenseml::netsim::traffic::{CompetingTraffic, LinkRef, TrafficPattern};
use netsenseml::netsim::{NetSim, NetSimConfig, SimTime};
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();

    b.group("point-to-point transfers");
    let mut sim = NetSim::quiet(StarTopology::constant(8, mbps(1000.0), SimTime::from_millis(1)));
    b.run_throughput("transfer (8-worker star)", 1, || {
        bb(sim.transfer(0, 1, 10_000));
    });

    b.group("phases (one ring step = 8 parallel transfers)");
    let mut sim2 = NetSim::quiet(StarTopology::constant(8, mbps(1000.0), SimTime::from_millis(1)));
    let transfers: Vec<(usize, usize, u64)> = (0..8).map(|i| (i, (i + 1) % 8, 100_000)).collect();
    b.run_throughput("phase of 8", 8, || {
        bb(sim2.phase(bb(&transfers)));
    });

    b.group("competing traffic");
    let topo = StarTopology::constant(8, mbps(1000.0), SimTime::from_millis(1));
    let traffic = CompetingTraffic::new(
        TrafficPattern::Poisson {
            msgs_per_sec: 10_000.0,
            mean_msg_bytes: 50_000.0,
        },
        vec![LinkRef::Up(0)],
        1,
    );
    let mut sim3 = NetSim::new(NetSimConfig {
        topology: topo,
        traffic: vec![traffic],
    });
    let mut t = 1u64;
    b.run_throughput("advance 100ms of poisson traffic (≈1k events)", 1000, || {
        sim3.advance_to(SimTime::from_millis(t * 100));
        t += 1;
    });

    b.finish();
}
