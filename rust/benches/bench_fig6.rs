//! End-to-end experiment bench: regenerates Fig 6 (TTA curves, VGG16)
//! in fast mode (10× shorter horizons) and reports the wall time.
//! The full-scale table is produced by `netsenseml repro fig6`.

use netsenseml::experiments::tta::fig6;
use netsenseml::experiments::scenario::RunOpts;
use netsenseml::util::bench::{bb, Bench};

fn main() {
    let mut b = Bench::new();
    let opts = RunOpts {
        fast: true,
        out_dir: None,
        seed: 42,
        n_workers: 8,
        fidelity_every: 0, // timing-only: keeps the bench wall-time bounded
    };
    b.group("Fig 6 (TTA curves, VGG16)");
    b.run_once("fig6 (fast mode)", || {
        let (table, _) = fig6(&opts);
        bb(table).print();
    });
    b.finish();
}
