//! PJRT runtime latency: grad_step / apply_update on the AOT artifacts —
//! the real-compute path of the e2e example. Skips cleanly when artifacts
//! are absent.

use netsenseml::runtime::ModelRuntime;
use netsenseml::util::bench::{bb, Bench};
use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_runtime: artifacts not built (run `make artifacts`); skipping");
        return;
    }
    let mut b = Bench::new();
    for model in ["mlp", "cifar_cnn"] {
        let rt = match ModelRuntime::load(&dir, model) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping {model}: {e}");
                continue;
            }
        };
        let state = rt.init_state().unwrap();
        let mm = &rt.manifest;
        let x = vec![0.05f32; mm.x_len()];
        let y: Vec<f32> = (0..mm.batch).map(|i| (i % mm.n_classes) as f32).collect();
        b.group(&format!("{model} ({} params, batch {})", mm.total_params, mm.batch));
        b.run_throughput("grad_step", mm.batch as u64, || {
            bb(rt.grad_step(bb(&state), bb(&x), bb(&y)).unwrap());
        });
        let grad = rt.grad_step(&state, &x, &y).unwrap().flat_grad;
        let mut st = state.clone();
        b.run("apply_update", || {
            rt.apply_update(bb(&mut st), bb(&grad), 0.01).unwrap();
        });
    }
    b.finish();
}
