//! Telemetry-overhead benchmark: the fused compress → decode-reduce step
//! with observability ON (span ring + hot-metric observes, exactly what
//! `experiments::live` records per step) vs OFF (disabled tracer, no
//! observes). The two variants run in interleaved windows and compare
//! medians, so clock drift and thermal throttling hit both equally.
//!
//! Emits `BENCH_obs.json` with both throughputs and the overhead
//! percentage. The "zero-overhead" claim is enforced: in full mode an
//! overhead above the gate fails the run (exit 1); under
//! `NETSENSE_BENCH_FAST=1` (CI smoke, noisy shared runners) it only
//! warns.
//!
//! A second section prices the end-of-run collection path — OBS payload
//! encode + decode round-trip and the critical-path analyzer over a
//! merged multi-rank trace. These run strictly after training, so they
//! are cost keys (`*_us`, gated lower-is-better by perf_compare.py), not
//! part of the per-step overhead gate.

mod common;

use common::{gbps, BenchJson};
use netsenseml::compress::{decode_reduce_into, CompressionConfig, NetSenseCompressor, Workspace};
use netsenseml::obs::{
    analyze, decode_telemetry, encode_telemetry, hot, merge_aligned, DecisionKind,
    DecisionRecord, RankTelemetry, SpanRecord, Tracer,
};
use netsenseml::util::bench::bb;
use netsenseml::util::rng::Pcg64;
use std::time::Instant;

/// Maximum tolerated telemetry-on slowdown, percent.
const GATE_PCT: f64 = 2.0;

struct Fixture {
    comp: NetSenseCompressor,
    grads: Vec<f32>,
    weights: Vec<f32>,
    ws: Workspace,
    wire: Vec<u8>,
    acc: Vec<f32>,
}

impl Fixture {
    fn new(n: usize) -> Fixture {
        let mut grads = vec![0f32; n];
        let mut weights = vec![0f32; n];
        let mut rng = Pcg64::new(7, 0xbe);
        rng.fill_normal_f32(&mut grads, 0.0, 1.0);
        rng.fill_normal_f32(&mut weights, 0.0, 0.1);
        Fixture {
            comp: NetSenseCompressor::new(n, CompressionConfig::default()),
            grads,
            weights,
            ws: Workspace::new(),
            wire: Vec::new(),
            acc: vec![0f32; n],
        }
    }

    /// One fused step with no telemetry in the path.
    fn step_off(&mut self) {
        self.wire.clear();
        self.comp
            .compress_payload_into(&self.grads, &self.weights, 0.05, &mut self.ws, &mut self.wire);
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        bb(decode_reduce_into(bb(&self.wire), &mut self.acc).unwrap());
    }

    /// The same step wrapped exactly the way `experiments::live` wraps
    /// it: step/compress/decode spans plus the per-step hot observes.
    fn step_on(&mut self, tracer: &mut Tracer) {
        let om = hot();
        let sp_step = tracer.start("step", 0);
        let sp_c = tracer.start("compress", 0);
        let t_c = Instant::now();
        self.wire.clear();
        self.comp
            .compress_payload_into(&self.grads, &self.weights, 0.05, &mut self.ws, &mut self.wire);
        om.compress_ns.observe(t_c.elapsed().as_nanos() as u64);
        tracer.end(sp_c);
        om.bytes_sent_total.add(self.wire.len() as u64);
        self.acc.iter_mut().for_each(|a| *a = 0.0);
        let sp_d = tracer.start("decode", 0);
        let t_d = Instant::now();
        bb(decode_reduce_into(bb(&self.wire), &mut self.acc).unwrap());
        om.decode_ns.observe(t_d.elapsed().as_nanos() as u64);
        tracer.end(sp_d);
        om.rounds_total.inc();
        tracer.end(sp_step);
    }
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Synthetic cluster telemetry shaped like a real run: rank 0 carries the
/// full step/compress/round/decode nest, the other ranks their round
/// spans, and the journal alternates Round digests with Ratio
/// transitions.
fn synth_cluster(n_ranks: usize, steps: u32) -> (Vec<Vec<SpanRecord>>, Vec<DecisionRecord>) {
    let mut per_rank: Vec<Vec<SpanRecord>> = vec![Vec::new(); n_ranks];
    for step in 0..steps {
        let base = step as u64 * 1_000_000;
        per_rank[0].extend([
            SpanRecord { rank: 0, id: u64::from(step) * 8 + 1, parent: 0, label: "step", step, start_ns: base, end_ns: base + 900_000 },
            SpanRecord { rank: 0, id: u64::from(step) * 8 + 2, parent: u64::from(step) * 8 + 1, label: "compress", step, start_ns: base + 10_000, end_ns: base + 100_000 },
            SpanRecord { rank: 0, id: u64::from(step) * 8 + 3, parent: u64::from(step) * 8 + 1, label: "round", step, start_ns: base + 200_000, end_ns: base + 800_000 },
            SpanRecord { rank: 0, id: u64::from(step) * 8 + 4, parent: u64::from(step) * 8 + 3, label: "decode", step, start_ns: base + 210_000, end_ns: base + 300_000 },
        ]);
        for (r, spans) in per_rank.iter_mut().enumerate().skip(1) {
            spans.push(SpanRecord {
                rank: r,
                id: u64::from(step) + 1,
                parent: 0,
                label: "round",
                step,
                start_ns: base + 200_000,
                end_ns: base + 700_000 + (r as u64) * 50_000,
            });
        }
    }
    let mut journal = Vec::new();
    for step in 0..steps {
        journal.push(DecisionRecord {
            kind: DecisionKind::Round,
            step,
            live: n_ranks,
            rtt_us: 600,
            payload_bytes: 40_000,
            ..DecisionRecord::default()
        });
        if step % 8 == 0 {
            journal.push(DecisionRecord {
                kind: DecisionKind::Ratio,
                step,
                live: n_ranks,
                old_ratio: 0.05,
                new_ratio: 0.06,
                predicted_wire_bytes: 40_000,
                ..DecisionRecord::default()
            });
        }
    }
    (per_rank, journal)
}

fn main() {
    let fast = std::env::var("NETSENSE_BENCH_FAST").ok().as_deref() == Some("1");
    let n = if fast { 1 << 16 } else { 1 << 18 };
    let (windows, iters) = if fast { (5, 20) } else { (11, 60) };

    let mut fx = Fixture::new(n);
    let mut tracer = Tracer::new(0, 4096, Instant::now());

    // Warm both variants: first-touch faults, registry registration, and
    // wire-buffer growth all happen here, outside the timed windows.
    for _ in 0..iters {
        fx.step_off();
        fx.step_on(&mut tracer);
    }

    let mut off_s: Vec<f64> = Vec::with_capacity(windows);
    let mut on_s: Vec<f64> = Vec::with_capacity(windows);
    for w in 0..windows {
        // Alternate which variant goes first so slow drift cancels.
        for leg in 0..2 {
            let on_leg = (w + leg) % 2 == 1;
            let t0 = Instant::now();
            for _ in 0..iters {
                if on_leg {
                    fx.step_on(&mut tracer);
                } else {
                    fx.step_off();
                }
            }
            let dt = t0.elapsed().as_secs_f64() / iters as f64;
            if on_leg {
                on_s.push(dt);
            } else {
                off_s.push(dt);
            }
        }
    }
    let off_med = median(&mut off_s);
    let on_med = median(&mut on_s);
    let off_gbps = gbps(n, std::time::Duration::from_secs_f64(off_med));
    let on_gbps = gbps(n, std::time::Duration::from_secs_f64(on_med));
    let overhead_pct = (on_med - off_med) / off_med * 100.0;

    println!(
        "fused step ({n} params, ratio 0.05): telemetry off {off_gbps:.2} GB/s, \
         on {on_gbps:.2} GB/s — overhead {overhead_pct:+.2}% (gate {GATE_PCT}%)"
    );

    // --- collection cost (runs after training, never on the hot path) ---
    let n_ranks = 4;
    let steps = if fast { 128u32 } else { 1024 };
    let (per_rank, journal) = synth_cluster(n_ranks, steps);
    let telemetry = RankTelemetry {
        rank: 1,
        clock_ns: 1_234_567,
        spans: per_rank[1].clone(),
        spans_dropped: 0,
        journal: journal.clone(),
        journal_dropped: 0,
        final_ratio: 0.06,
        recoveries: 0,
        lost_intervals: 0,
        decreases: 1,
        increases: 2,
    };
    let offsets: Vec<i64> = (0..n_ranks as i64).map(|r| r * 1_500 - 800).collect();
    let merged = merge_aligned(&per_rank, &offsets);
    let c_iters = if fast { 20 } else { 40 };
    let mut rt_us: Vec<f64> = Vec::with_capacity(windows);
    let mut an_us: Vec<f64> = Vec::with_capacity(windows);
    for _ in 0..windows {
        let t0 = Instant::now();
        for _ in 0..c_iters {
            let wire = encode_telemetry(bb(&telemetry));
            bb(decode_telemetry(bb(&wire)).unwrap());
        }
        rt_us.push(t0.elapsed().as_secs_f64() * 1e6 / c_iters as f64);
        let t1 = Instant::now();
        for _ in 0..c_iters {
            bb(analyze(bb(&merged), bb(&journal), n_ranks, 400_000));
        }
        an_us.push(t1.elapsed().as_secs_f64() * 1e6 / c_iters as f64);
    }
    let rt_med = median(&mut rt_us);
    let an_med = median(&mut an_us);
    println!(
        "collection ({} spans x {n_ranks} ranks): OBS round-trip {rt_med:.1} us, \
         analyze {an_med:.1} us",
        per_rank[0].len()
    );

    let mut json = BenchJson::new("obs");
    json.set("n_params", n as u64);
    json.set("windows", windows as u64);
    json.set("iters_per_window", iters as u64);
    json.set("fused_off_gbps", off_gbps);
    json.set("fused_on_gbps", on_gbps);
    json.set("overhead_pct", overhead_pct);
    json.set("gate_pct", GATE_PCT);
    json.set("collect_ranks", n_ranks as u64);
    json.set("collect_steps", steps as u64);
    json.set("collect_roundtrip_us", rt_med);
    json.set("analyze_us", an_med);
    json.write();

    if overhead_pct > GATE_PCT {
        if fast {
            eprintln!(
                "WARNING: telemetry overhead {overhead_pct:.2}% exceeds the {GATE_PCT}% gate \
                 (fast mode: warn only)"
            );
        } else {
            eprintln!(
                "FAIL: telemetry overhead {overhead_pct:.2}% exceeds the {GATE_PCT}% gate"
            );
            std::process::exit(1);
        }
    }
}
