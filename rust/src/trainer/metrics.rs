//! Experiment metrics: per-step records, the paper's three headline
//! measurements (time-to-accuracy, training throughput, convergence time),
//! and CSV export for figure regeneration.

use std::io::Write;
use std::path::Path;

/// One training step's telemetry.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    /// Virtual time at the END of this step, seconds.
    pub vtime_s: f64,
    pub compute_s: f64,
    pub comm_s: f64,
    /// Compression ratio used this step (1.0 = dense).
    pub ratio: f64,
    /// Per-worker wire payload for this step's sync, bytes (max across
    /// workers).
    pub payload_bytes: u64,
    /// Validation accuracy estimate (%) after this step.
    pub acc: f64,
    /// Training loss (real track only; surrogate logs a proxy).
    pub loss: f64,
}

impl StepRecord {
    /// Instantaneous throughput, samples/second.
    pub fn throughput(&self, samples_per_step: usize) -> f64 {
        samples_per_step as f64 / (self.compute_s + self.comm_s)
    }
}

/// A full training trace plus the paper-metric reductions.
#[derive(Clone, Debug)]
pub struct TrainLog {
    pub method: String,
    pub model: String,
    pub samples_per_step: usize,
    pub records: Vec<StepRecord>,
}

impl TrainLog {
    pub fn new(method: &str, model: &str, samples_per_step: usize) -> Self {
        TrainLog {
            method: method.to_string(),
            model: model.to_string(),
            samples_per_step,
            records: Vec::new(),
        }
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn total_vtime(&self) -> f64 {
        self.records.last().map(|r| r.vtime_s).unwrap_or(0.0)
    }

    pub fn best_acc(&self) -> f64 {
        self.records.iter().map(|r| r.acc).fold(0.0, f64::max)
    }

    /// Mean training throughput over the whole run (samples/s) — the
    /// paper's "Training Throughput" column.
    pub fn mean_throughput(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.len() as f64 * self.samples_per_step as f64 / self.total_vtime()
    }

    /// Time to first reach `target` accuracy (the paper's TTA), seconds.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.acc >= target)
            .map(|r| r.vtime_s)
    }

    /// Convergence time: first time accuracy reaches 99.5% of the run's
    /// best and never falls below 97% of best afterwards — `None` ("N/A"
    /// in the tables) when the run never stabilizes.
    pub fn convergence_time(&self) -> Option<f64> {
        let best = self.best_acc();
        if best <= 0.0 {
            return None;
        }
        let reach = best * 0.995;
        let hold = best * 0.97;
        let first = self.records.iter().position(|r| r.acc >= reach)?;
        if self.records[first..].iter().all(|r| r.acc >= hold) {
            Some(self.records[first].vtime_s)
        } else {
            None
        }
    }

    /// Accuracy trajectory downsampled to at most `n` points (for figures):
    /// (vtime_s, acc).
    pub fn acc_curve(&self, n: usize) -> Vec<(f64, f64)> {
        if self.records.is_empty() || n == 0 {
            return Vec::new();
        }
        let stride = (self.records.len() / n).max(1);
        self.records
            .iter()
            .step_by(stride)
            .map(|r| (r.vtime_s, r.acc))
            .collect()
    }

    /// Mean throughput within a virtual-time window (for Figs. 7–8 series).
    pub fn throughput_in_window(&self, t0: f64, t1: f64) -> Option<f64> {
        let in_window: Vec<&StepRecord> = self
            .records
            .iter()
            .filter(|r| r.vtime_s > t0 && r.vtime_s <= t1)
            .collect();
        if in_window.is_empty() {
            return None;
        }
        Some(in_window.len() as f64 * self.samples_per_step as f64 / (t1 - t0))
    }

    /// Write the full trace as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(
            f,
            "step,vtime_s,compute_s,comm_s,ratio,payload_bytes,acc,loss,throughput"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.4},{:.4},{:.6},{:.5},{},{:.3},{:.5},{:.2}",
                r.step,
                r.vtime_s,
                r.compute_s,
                r.comm_s,
                r.ratio,
                r.payload_bytes,
                r.acc,
                r.loss,
                r.throughput(self.samples_per_step)
            )?;
        }
        Ok(())
    }
}

/// Streaming convergence detector for long runs (avoids retaining every
/// record when only the verdict is needed).
#[derive(Clone, Debug, Default)]
pub struct ConvergenceTracker {
    best: f64,
    candidate: Option<f64>,
    violated: bool,
}

impl ConvergenceTracker {
    pub fn observe(&mut self, vtime_s: f64, acc: f64) {
        if acc > self.best {
            self.best = acc;
            // A new best can invalidate an old candidate threshold.
            if let Some(_t) = self.candidate {
                if acc * 0.995 > self.best {
                    self.candidate = None;
                }
            }
        }
        if self.candidate.is_none() && self.best > 0.0 && acc >= self.best * 0.995 {
            self.candidate = Some(vtime_s);
            self.violated = false;
        } else if let Some(_) = self.candidate {
            if acc < self.best * 0.97 {
                self.violated = true;
                self.candidate = None;
            }
        }
    }

    pub fn convergence_time(&self) -> Option<f64> {
        if self.violated {
            None
        } else {
            self.candidate
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, vtime: f64, acc: f64) -> StepRecord {
        StepRecord {
            step,
            vtime_s: vtime,
            compute_s: 0.3,
            comm_s: 0.2,
            ratio: 0.1,
            payload_bytes: 1000,
            acc,
            loss: 1.0,
        }
    }

    fn sample_log() -> TrainLog {
        let mut log = TrainLog::new("netsense", "resnet18", 256);
        for i in 0..100 {
            let t = (i + 1) as f64 * 0.5;
            let acc = 80.0 * (1.0 - (-(i as f64) / 20.0).exp());
            log.push(rec(i, t, acc));
        }
        log
    }

    #[test]
    fn throughput_math() {
        let r = rec(0, 0.5, 10.0);
        assert!((r.throughput(256) - 512.0).abs() < 1e-9);
        let log = sample_log();
        // 100 steps × 256 samples over 50 s of vtime
        assert!((log.mean_throughput() - 512.0).abs() < 1e-6);
    }

    #[test]
    fn tta_finds_first_crossing() {
        let log = sample_log();
        let t = log.time_to_accuracy(40.0).unwrap();
        assert!(t > 0.0 && t < 10.0, "{t}");
        assert!(log.time_to_accuracy(99.0).is_none());
    }

    #[test]
    fn convergence_time_of_saturating_curve() {
        let log = sample_log();
        let ct = log.convergence_time().unwrap();
        assert!(ct > 30.0 && ct <= 50.0, "{ct}");
    }

    #[test]
    fn convergence_none_for_unstable_curve() {
        let mut log = TrainLog::new("topk", "resnet18", 256);
        for i in 0..100 {
            // oscillates hard: best ~80, frequent dips to 40
            let acc = if i % 10 < 5 { 80.0 } else { 40.0 };
            log.push(rec(i, i as f64, acc));
        }
        assert_eq!(log.convergence_time(), None);
    }

    #[test]
    fn window_throughput() {
        let log = sample_log();
        // (10, 20] contains 20 steps → 20×256/10
        let tp = log.throughput_in_window(10.0, 20.0).unwrap();
        assert!((tp - 512.0).abs() < 1e-6);
        assert!(log.throughput_in_window(1000.0, 2000.0).is_none());
    }

    #[test]
    fn acc_curve_downsamples() {
        let log = sample_log();
        let curve = log.acc_curve(10);
        assert!(curve.len() >= 10 && curve.len() <= 11);
        assert!(curve.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn csv_roundtrip_lines() {
        let log = sample_log();
        let tmp = std::env::temp_dir().join("netsense_test_log.csv");
        log.write_csv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert_eq!(text.lines().count(), 101); // header + 100
        assert!(text.starts_with("step,"));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn tracker_matches_batch_computation() {
        let log = sample_log();
        let mut tr = ConvergenceTracker::default();
        for r in &log.records {
            tr.observe(r.vtime_s, r.acc);
        }
        // Same verdict as the batch version (within the same record set).
        assert_eq!(
            tr.convergence_time().is_some(),
            log.convergence_time().is_some()
        );
    }
}
