//! Training support: synthetic data, paper-scale model descriptors, the
//! surrogate training-dynamics model, and metric/TTA accounting.
//!
//! Two training tracks (DESIGN.md §2):
//! - **real**: the small JAX/Pallas models run through the PJRT runtime —
//!   losses and accuracies are actually computed (`examples/e2e_train.rs`).
//! - **surrogate**: the paper-scale ResNet18/VGG16 runs compress real
//!   full-size gradient tensors and time communication on the simulator,
//!   but validation accuracy follows a calibrated saturating curve of
//!   *effective steps* (steps × per-step information quality), replacing
//!   hours of GPU training the environment cannot perform.

pub mod data;
pub mod metrics;
pub mod models;
pub mod surrogate;

pub use data::SyntheticCifar;
pub use metrics::{ConvergenceTracker, StepRecord, TrainLog};
pub use models::{PaperModel, PAPER_MODELS};
pub use surrogate::SurrogateTrainer;
