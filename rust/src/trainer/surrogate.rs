//! Surrogate training dynamics for paper-scale models (DESIGN.md §2).
//!
//! What is real in a surrogate run: the gradient tensors (full-size,
//! realistic layered magnitude distribution, drifting over steps), the
//! compression pipeline, the wire volumes, and every network/timing
//! quantity. What is modeled: the mapping from *effective steps* to
//! validation accuracy,
//!
//! `acc(e) = acc_inf · (1 − exp(−(e/τ)^β)) + noise`,
//!
//! with per-step quality `q = q_dense · ratio^0.15` (error-feedback
//! compression delays but does not destroy gradient information — the
//! exponent is fitted to Table 1's accuracy/step-count pairs) and a ×0.8
//! penalty for *static* compression (TopK-0.1's instability in Fig. 5:
//! fixed ratios misallocate budget when gradient scales drift).

use super::models::PaperModel;
use crate::util::rng::Pcg64;

/// Quality of one step at compression `ratio` (1.0 = dense).
pub fn step_quality(model: &PaperModel, ratio: f64, static_compression: bool) -> f64 {
    let r = ratio.clamp(1e-4, 1.0);
    let q = model.q_dense * r.powf(0.15);
    if static_compression {
        q * 0.8
    } else {
        q
    }
}

/// Surrogate state: per-worker gradient tensors + the accuracy model.
pub struct SurrogateTrainer {
    pub model: &'static PaperModel,
    n_workers: usize,
    seed: u64,
    /// Per-worker gradient buffers (full model size). Materialized lazily:
    /// timing-only runs (`fidelity_every = 0`) never pay the ~n_workers ×
    /// n_params allocation + fill.
    grads: Vec<Vec<f32>>,
    /// Fake weights (for the pruning step of Algorithm 2); lazy too.
    weights: Vec<f32>,
    effective_steps: f64,
    rng: Pcg64,
    noise_rng: Pcg64,
}

impl SurrogateTrainer {
    pub fn new(model: &'static PaperModel, n_workers: usize, seed: u64) -> Self {
        SurrogateTrainer {
            model,
            n_workers,
            seed,
            grads: Vec::new(),
            weights: Vec::new(),
            effective_steps: 0.0,
            rng: Pcg64::new(seed, 11),
            noise_rng: Pcg64::new(seed, 12),
        }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    fn materialize(&mut self) {
        if !self.grads.is_empty() {
            return;
        }
        let n = self.model.n_params;
        // Layered magnitude structure: split the flat tensor into "layers"
        // with log-spaced scales (mimics real convnet gradient profiles).
        let n_layers = 20;
        for w in 0..self.n_workers {
            let mut g = vec![0f32; n];
            let mut lrng = Pcg64::new(self.seed ^ 0xbeef, w as u64 + 100);
            for (i, x) in g.iter_mut().enumerate() {
                let layer = i * n_layers / n;
                let scale = 10f32.powf(-1.0 - 0.1 * layer as f32);
                *x = scale * lrng.normal() as f32;
            }
            self.grads.push(g);
        }
        let mut wrng = Pcg64::new(self.seed, 10);
        self.weights = vec![0f32; n];
        wrng.fill_normal_f32(&mut self.weights, 0.0, 0.05);
    }

    pub fn weights(&mut self) -> &[f32] {
        self.materialize();
        &self.weights
    }

    /// Per-worker gradients for a full-fidelity compression step. Applies a
    /// small drift (re-randomizes ~0.5% of entries, decays scale slightly)
    /// so threshold-reuse top-k sees realistic distribution movement.
    pub fn worker_grads(&mut self) -> &[Vec<f32>] {
        self.materialize();
        let n = self.model.n_params;
        let n_touch = (n / 200).max(1);
        for w in 0..self.n_workers {
            for _ in 0..n_touch {
                let i = self.rng.index(n);
                let layer = i * 20 / n;
                let scale = 10f32.powf(-1.0 - 0.1 * layer as f32);
                self.grads[w][i] = scale * self.rng.normal() as f32;
            }
        }
        &self.grads
    }

    /// Both gradient and weight views in one borrow (spot-check path).
    pub fn grads_and_weights(&mut self) -> (&[Vec<f32>], &[f32]) {
        self.worker_grads();
        (&self.grads, &self.weights)
    }

    /// Advance the accuracy model by one step at `ratio`.
    pub fn advance(&mut self, ratio: f64, static_compression: bool) {
        self.effective_steps += step_quality(self.model, ratio, static_compression);
    }

    /// Current validation-accuracy estimate (%), with small seeded noise.
    pub fn accuracy(&mut self) -> f64 {
        let e = self.effective_steps;
        let m = self.model;
        let base = m.acc_inf * (1.0 - (-(e / m.tau).powf(m.beta)).exp());
        let noise = 0.25 * self.noise_rng.normal();
        (base + noise).clamp(0.0, 100.0)
    }

    /// A loss proxy for logging (cross-entropy-looking decay).
    pub fn loss_proxy(&self) -> f64 {
        let e = self.effective_steps;
        let m = self.model;
        let frac = 1.0 - (-(e / m.tau).powf(m.beta)).exp();
        (100f64).ln() * (1.0 - 0.9 * frac)
    }

    pub fn effective_steps(&self) -> f64 {
        self.effective_steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trainer::models::PAPER_MODELS;

    fn resnet() -> &'static PaperModel {
        &PAPER_MODELS[0]
    }

    #[test]
    fn quality_ordering() {
        let m = resnet();
        assert!(step_quality(m, 1.0, false) > step_quality(m, 0.1, false));
        assert!(step_quality(m, 0.1, false) > step_quality(m, 0.01, false));
        // static penalty
        assert!(step_quality(m, 0.1, true) < step_quality(m, 0.1, false));
        // dense step quality is exactly q_dense
        assert!((step_quality(m, 1.0, false) - m.q_dense).abs() < 1e-12);
    }

    #[test]
    fn accuracy_is_monotone_and_saturates() {
        let mut t = SurrogateTrainer::new(resnet(), 2, 1);
        let a0 = t.accuracy();
        for _ in 0..500 {
            t.advance(1.0, false);
        }
        let a1 = t.accuracy();
        for _ in 0..5000 {
            t.advance(1.0, false);
        }
        let a2 = t.accuracy();
        assert!(a1 > a0 + 10.0, "{a0} → {a1}");
        assert!(a2 > a1);
        assert!(a2 <= resnet().acc_inf + 2.0);
    }

    #[test]
    fn calibration_matches_table1_anchors() {
        // DESIGN.md calibration: ~260 dense-quality steps ≈ 67%, ~2215
        // effective steps ≈ 76% (Table 1's AllReduce@200 and
        // NetSenseML@200 operating points).
        let m = resnet();
        let acc = |e: f64| m.acc_inf * (1.0 - (-(e / m.tau).powf(m.beta)).exp());
        assert!((acc(260.0) - 67.3).abs() < 2.0, "{}", acc(260.0));
        assert!((acc(2215.0) - 75.8).abs() < 2.0, "{}", acc(2215.0));
    }

    #[test]
    fn grads_have_layered_scales_and_drift() {
        let mut t = SurrogateTrainer::new(resnet(), 1, 2);
        let g0: Vec<f32> = t.worker_grads()[0].clone();
        let n = g0.len();
        // early "layers" larger than late ones
        let head: f32 = g0[..n / 20].iter().map(|x| x.abs()).sum::<f32>() / (n / 20) as f32;
        let tail: f32 =
            g0[n - n / 20..].iter().map(|x| x.abs()).sum::<f32>() / (n / 20) as f32;
        assert!(head > 5.0 * tail, "head {head} tail {tail}");
        // drift touches a small fraction
        let g1: Vec<f32> = t.worker_grads()[0].clone();
        let changed = g0.iter().zip(&g1).filter(|(a, b)| a != b).count();
        assert!(changed > 0);
        assert!(changed < n / 50, "{changed} of {n} changed");
    }

    #[test]
    fn workers_have_distinct_gradients() {
        let mut t = SurrogateTrainer::new(resnet(), 3, 3);
        let gs = t.worker_grads();
        assert_ne!(gs[0][..100], gs[1][..100]);
        assert_ne!(gs[1][..100], gs[2][..100]);
    }

    #[test]
    fn loss_proxy_decreases() {
        let mut t = SurrogateTrainer::new(resnet(), 1, 4);
        let l0 = t.loss_proxy();
        for _ in 0..1000 {
            t.advance(0.5, false);
        }
        assert!(t.loss_proxy() < l0);
    }
}
