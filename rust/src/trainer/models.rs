//! Paper-scale model descriptors — the workloads of the evaluation section
//! (ResNet18 and VGG16 on CIFAR-100, batch 32 per worker) plus the
//! calibrated surrogate-dynamics constants (DESIGN.md §2).
//!
//! Calibration sources: the paper states ResNet18's gradient payload is
//! 46.2 MB (≈ 11.55 M f32 parameters); per-step compute time is set so the
//! uncongested throughput ceiling matches Table 1/2's best NetSenseML
//! throughput (ResNet18 ≈ 0.30 s/step → ≤ 853 samples/s with 8×32 batch;
//! VGG16 ≈ 0.70 s/step → ≤ 366 samples/s).

/// Static description of a paper-scale model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PaperModel {
    pub name: &'static str,
    /// Number of f32 parameters (gradient elements).
    pub n_params: usize,
    /// Local fwd+bwd compute time per step, seconds.
    pub compute_time_s: f64,
    /// Surrogate accuracy ceiling (%), CIFAR-100 validation.
    pub acc_inf: f64,
    /// Surrogate time constant (effective steps).
    pub tau: f64,
    /// Surrogate shape exponent.
    pub beta: f64,
    /// Base learning-progress quality of a *dense* step.
    pub q_dense: f64,
}

impl PaperModel {
    /// Dense gradient bytes (f32).
    pub fn dense_bytes(&self) -> u64 {
        4 * self.n_params as u64
    }

    pub fn by_name(name: &str) -> Option<&'static PaperModel> {
        PAPER_MODELS.iter().find(|m| m.name == name)
    }
}

/// ResNet18 (11.55 M params ⇒ the paper's 46.2 MB) and VGG16-CIFAR
/// (15.25 M params ⇒ 61 MB).
pub const PAPER_MODELS: &[PaperModel] = &[
    PaperModel {
        name: "resnet18",
        n_params: 11_550_000,
        compute_time_s: 0.30,
        acc_inf: 81.0,
        tau: 15.0,
        beta: 0.203,
        q_dense: 1.0,
    },
    PaperModel {
        name: "vgg16",
        n_params: 15_250_000,
        compute_time_s: 0.70,
        acc_inf: 76.5,
        tau: 15.0,
        beta: 0.203,
        q_dense: 1.0,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_matches_paper_payload() {
        let m = PaperModel::by_name("resnet18").unwrap();
        // 46.2 MB within 1%
        let mb = m.dense_bytes() as f64 / 1e6;
        assert!((mb - 46.2).abs() < 0.5, "{mb} MB");
    }

    #[test]
    fn lookup() {
        assert!(PaperModel::by_name("vgg16").is_some());
        assert!(PaperModel::by_name("alexnet").is_none());
    }

    #[test]
    fn throughput_ceilings_match_tables() {
        // 8 workers × batch 32 = 256 samples per step.
        let r = PaperModel::by_name("resnet18").unwrap();
        let ceiling = 256.0 / r.compute_time_s;
        assert!(ceiling > 824.0, "ResNet18 ceiling {ceiling} below Table 1 best");
        let v = PaperModel::by_name("vgg16").unwrap();
        let ceiling = 256.0 / v.compute_time_s;
        assert!(ceiling > 340.0, "VGG16 ceiling {ceiling} below Table 2 best");
    }
}
