//! Synthetic CIFAR-100-shaped dataset: class-conditional Gaussian images.
//!
//! Each class has a fixed random prototype in pixel space; a sample is
//! `prototype + noise`. This is genuinely learnable (a linear probe can
//! separate it), deterministic given the seed, and shaped exactly like the
//! paper's workload (32×32×3, 100 classes) — the substitution for the real
//! CIFAR-100 the environment cannot download.

use crate::util::rng::Pcg64;

/// Deterministic synthetic classification dataset.
pub struct SyntheticCifar {
    pub n_classes: usize,
    pub dim: usize,
    /// Per-class prototypes, `n_classes × dim`.
    prototypes: Vec<f32>,
    noise: f32,
    rng: Pcg64,
}

impl SyntheticCifar {
    pub fn new(n_classes: usize, dim: usize, noise: f32, seed: u64) -> Self {
        let mut proto_rng = Pcg64::new(seed, 1);
        let mut prototypes = vec![0f32; n_classes * dim];
        // Prototypes scaled so signal/noise is non-trivial but learnable.
        proto_rng.fill_normal_f32(&mut prototypes, 0.0, 0.5);
        SyntheticCifar {
            n_classes,
            dim,
            prototypes,
            noise,
            rng: Pcg64::new(seed, 2),
        }
    }

    /// CIFAR-100 shape with default noise.
    pub fn cifar100(seed: u64) -> Self {
        SyntheticCifar::new(100, 32 * 32 * 3, 1.0, seed)
    }

    /// Draw a batch: `x` is `batch×dim` flat, `y` is `batch` labels (f32,
    /// as the HLO interface expects).
    pub fn batch(&mut self, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut x = vec![0f32; batch * self.dim];
        let mut y = vec![0f32; batch];
        for b in 0..batch {
            let class = self.rng.index(self.n_classes);
            y[b] = class as f32;
            let proto = &self.prototypes[class * self.dim..(class + 1) * self.dim];
            let row = &mut x[b * self.dim..(b + 1) * self.dim];
            for (o, &p) in row.iter_mut().zip(proto) {
                *o = p + self.noise * self.rng.normal() as f32;
            }
        }
        (x, y)
    }

    /// A held-out evaluation batch drawn from an independent stream.
    pub fn eval_batch(&self, batch: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::new(seed, 3);
        let mut x = vec![0f32; batch * self.dim];
        let mut y = vec![0f32; batch];
        for b in 0..batch {
            let class = rng.index(self.n_classes);
            y[b] = class as f32;
            let proto = &self.prototypes[class * self.dim..(class + 1) * self.dim];
            let row = &mut x[b * self.dim..(b + 1) * self.dim];
            for (o, &p) in row.iter_mut().zip(proto) {
                *o = p + self.noise * rng.normal() as f32;
            }
        }
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_label_range() {
        let mut d = SyntheticCifar::cifar100(1);
        let (x, y) = d.batch(32);
        assert_eq!(x.len(), 32 * 3072);
        assert_eq!(y.len(), 32);
        assert!(y.iter().all(|&c| (0.0..100.0).contains(&c) && c.fract() == 0.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCifar::cifar100(7);
        let mut b = SyntheticCifar::cifar100(7);
        assert_eq!(a.batch(8), b.batch(8));
        let mut c = SyntheticCifar::cifar100(8);
        assert_ne!(a.batch(8), c.batch(8));
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-prototype classification on fresh samples should beat
        // chance by a wide margin — the dataset is learnable.
        let mut d = SyntheticCifar::new(10, 64, 1.0, 3);
        let (x, y) = d.batch(200);
        let mut correct = 0;
        for b in 0..200 {
            let row = &x[b * 64..(b + 1) * 64];
            let mut best = (f32::MAX, 0usize);
            for c in 0..10 {
                let proto = &d.prototypes[c * 64..(c + 1) * 64];
                let dist: f32 = row
                    .iter()
                    .zip(proto)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y[b] as usize {
                correct += 1;
            }
        }
        assert!(correct > 100, "only {correct}/200 correct (chance = 20)");
    }

    #[test]
    fn eval_batch_is_stable() {
        let d = SyntheticCifar::cifar100(5);
        let (x1, y1) = d.eval_batch(16, 99);
        let (x2, y2) = d.eval_batch(16, 99);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }
}
