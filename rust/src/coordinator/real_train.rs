//! Real-numerics training driver: the JAX/Pallas model (via the PJRT
//! runtime) trained by N simulated DDP workers over the simulated network.
//!
//! DDP invariant exploited: replicas start identical and apply identical
//! aggregated gradients, so a single parameter state stands for all
//! replicas — per-worker state reduces to the data shard and the
//! error-feedback residual (which [`SyncEngine`] already keeps per worker).
//! Compute time is *measured* wall-clock (per-worker grad_step calls run
//! sequentially and are averaged); network time is virtual.

use super::strategy::SyncStrategy;
use super::sync::SyncEngine;
use crate::netsim::{NetSim, SimTime};
use crate::runtime::{ModelRuntime, TrainState};
use crate::trainer::data::SyntheticCifar;
use crate::trainer::metrics::{StepRecord, TrainLog};
use crate::util::error::Result;

/// Configuration for a real-training run.
#[derive(Clone, Debug)]
pub struct RealTrainConfig {
    pub n_workers: usize,
    pub strategy: SyncStrategy,
    pub steps: usize,
    pub lr: f32,
    /// Evaluate on the held-out batch every N steps.
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for RealTrainConfig {
    fn default() -> Self {
        RealTrainConfig {
            n_workers: 8,
            strategy: SyncStrategy::NetSense,
            steps: 200,
            lr: 0.02,
            eval_every: 10,
            seed: 7,
        }
    }
}

/// The real-training coordinator.
pub struct RealTrainer<'rt> {
    runtime: &'rt ModelRuntime,
    config: RealTrainConfig,
    state: TrainState,
    engine: SyncEngine,
    workers_data: Vec<SyntheticCifar>,
    eval_x: Vec<f32>,
    eval_y: Vec<f32>,
}

impl<'rt> RealTrainer<'rt> {
    pub fn new(runtime: &'rt ModelRuntime, config: RealTrainConfig) -> Result<Self> {
        let mm = &runtime.manifest;
        let state = runtime.init_state()?;
        let engine = SyncEngine::new(config.strategy.clone(), config.n_workers, mm.total_params);
        let dim: usize = mm.input_shape.iter().product();
        let workers_data: Vec<SyntheticCifar> = (0..config.n_workers)
            .map(|w| SyntheticCifar::new(mm.n_classes, dim, 1.0, config.seed + w as u64))
            .collect();
        // Held-out eval data from the shared prototype space.
        let (eval_x, eval_y) = workers_data[0].eval_batch(mm.batch, 0xe7a1);
        Ok(RealTrainer {
            runtime,
            config,
            state,
            engine,
            workers_data,
            eval_x,
            eval_y,
        })
    }

    pub fn state(&self) -> &TrainState {
        &self.state
    }

    /// Evaluate accuracy (%) and loss on the held-out batch.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        let out = self
            .runtime
            .grad_step(&self.state, &self.eval_x, &self.eval_y)?;
        let acc = 100.0 * out.n_correct as f64 / self.runtime.manifest.batch as f64;
        Ok((acc, out.loss as f64))
    }

    /// Train for the configured number of steps over `sim`. Returns the
    /// trace (virtual-time comm, measured compute, real loss/acc).
    pub fn train(&mut self, sim: &mut NetSim) -> Result<TrainLog> {
        let mm = &self.runtime.manifest;
        let samples_per_step = self.config.n_workers * mm.batch;
        let mut log = TrainLog::new(
            &self.config.strategy.label(),
            &mm.name,
            samples_per_step,
        );
        let mut acc = 0.0;
        let mut eval_loss;
        for step in 0..self.config.steps {
            // --- local compute: one grad_step per worker (real) ----------
            let t0 = std::time::Instant::now();
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(self.config.n_workers);
            let mut train_loss = 0f64;
            for w in 0..self.config.n_workers {
                let (x, y) = self.workers_data[w].batch(mm.batch);
                let out = self.runtime.grad_step(&self.state, &x, &y)?;
                train_loss += out.loss as f64;
                grads.push(out.flat_grad);
            }
            train_loss /= self.config.n_workers as f64;
            // In a real deployment the workers run in parallel; the
            // per-step compute time is the mean per-worker wall time.
            let compute_s = t0.elapsed().as_secs_f64() / self.config.n_workers as f64;
            sim.advance_by(SimTime::from_secs_f64(compute_s));

            // --- gradient synchronization (real numerics + sim network) --
            let weights = self.state.flat_params();
            let outcome = self.engine.sync_full(sim, &grads, &weights)?;
            let mean_grad = outcome.mean_grad.as_ref().expect("full sync has numerics");

            // --- optimizer step (real, via PJRT) --------------------------
            self.runtime
                .apply_update(&mut self.state, mean_grad, self.config.lr)?;

            // --- metrics ---------------------------------------------------
            if step % self.config.eval_every == 0 || step + 1 == self.config.steps {
                let (a, l) = self.evaluate()?;
                acc = a;
                eval_loss = l;
                let _ = eval_loss;
            }
            log.push(StepRecord {
                step,
                vtime_s: sim.now().as_secs_f64(),
                compute_s,
                comm_s: outcome.comm.elapsed().as_secs_f64(),
                ratio: outcome.ratio,
                payload_bytes: outcome.max_payload(),
                acc,
                loss: train_loss,
            });
        }
        Ok(log)
    }
}
