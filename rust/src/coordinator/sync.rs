//! One gradient-synchronization round, per strategy: compress → transport
//! through the [`GroupTransport`] seam → aggregate → feed the sensing
//! controller.
//!
//! All byte movement goes through
//! [`crate::transport::GroupTransport`] — the engine never names a
//! backend. Simulated runs pass a [`NetSim`](crate::netsim::NetSim) (or
//! [`crate::transport::SimTransport`]); the live-socket track drives the
//! rank-level [`crate::transport::Transport`] endpoints directly
//! ([`crate::experiments::live`]).
//!
//! Two fidelities (DESIGN.md §4):
//! - [`SyncEngine::sync_full`] — real numerics: per-worker Algorithm-2
//!   compression of the actual gradient tensors, sparse aggregation, dense
//!   reduction. Used every step on the real-training track and on
//!   spot-check steps of surrogate runs.
//! - [`SyncEngine::sync_predicted`] — timing-only: wire sizes come from
//!   [`crate::compress::NetSenseCompressor::predict_wire_bytes`] (proven
//!   byte-exact against `sync_full` in tests), so million-step sweeps cost
//!   microseconds per step. The controller sees the identical observable
//!   stream either way. Once a run has spot-checked (compressors exist),
//!   predictions come from the per-worker compressor state, which keeps
//!   them exact even across the quantization-skip condition (a frozen
//!   layer's near-zero bucket).
//!
//! With [`SyncEngine::with_pipeline`] the sparse strategies switch to the
//! bucketed pipelined exchange: per-bucket Algorithm-2 compression (one
//! error-feedback residual per bucket), transport stages coalesced to the
//! sensed BDP, and compression of stage *k+1* overlapped with the
//! transmission of stage *k* ([`super::pipeline_exchange`]). Scheduling
//! knobs never change the reduced gradient — only when bytes move.

use super::pipeline_exchange::{ExchangeTiming, PipelineConfig, PipelineStage};
use super::strategy::SyncStrategy;
use crate::collectives::{sum_sparse, CollectiveTiming};
use crate::compress::{
    decode_reduce_frame_into, group_indices_by_bytes, BucketLayout, BucketedCompressor,
    CompressorState, NetSenseCompressor, SparseGradient, WorkspacePool,
};
use crate::fault::Checkpoint;
use crate::netsim::SimTime;
use crate::sensing::RatioController;
use crate::transport::GroupTransport;
use crate::util::error::{anyhow, Result};

/// Result of one synchronization round.
#[derive(Clone, Debug)]
pub struct SyncOutcome {
    /// Mean gradient across workers (only from `sync_full`).
    pub mean_grad: Option<Vec<f32>>,
    /// Wire payload each worker contributed (bytes).
    pub payload_bytes: Vec<u64>,
    pub comm: CollectiveTiming,
    /// Ratio used this round (1.0 for dense).
    pub ratio: f64,
    /// Did Algorithm 2 quantize this round?
    pub quantized: bool,
}

impl SyncOutcome {
    pub fn max_payload(&self) -> u64 {
        self.payload_bytes.iter().copied().max().unwrap_or(0)
    }
}

/// Per-run synchronization state (compressors, controller).
pub struct SyncEngine {
    pub strategy: SyncStrategy,
    n_workers: usize,
    n_params: usize,
    controller: Option<RatioController>,
    compression_cfg: Option<crate::compress::CompressionConfig>,
    /// Lazily allocated — per-worker residual buffers are n_params f32
    /// each, which timing-only runs never need.
    compressors: Vec<NetSenseCompressor>,
    /// Bucketed pipelined exchange; `None` = monolithic compress-then-send.
    pipeline: Option<PipelineConfig>,
    /// Lazily allocated per-worker bucketed compressors (pipeline mode).
    bucketed: Vec<BucketedCompressor>,
    /// Scratch arena for the fused compression hot path, shared across the
    /// simulated workers (they compress sequentially on this host; buckets
    /// within one worker fan out across the pool's workspaces).
    pool: WorkspacePool,
}

impl SyncEngine {
    pub fn new(strategy: SyncStrategy, n_workers: usize, n_params: usize) -> Self {
        let controller = strategy.controller_config().map(RatioController::new);
        let compression_cfg = strategy.compression_config();
        SyncEngine {
            strategy,
            n_workers,
            n_params,
            controller,
            compression_cfg,
            compressors: Vec::new(),
            pipeline: None,
            bucketed: Vec::new(),
            pool: WorkspacePool::with_available_parallelism(),
        }
    }

    /// Enable the bucketed pipelined exchange for the sparse strategies
    /// (dense AllReduce is unaffected). Must be called before the first
    /// sync round (the bucket layout fixes error-feedback granularity).
    pub fn with_pipeline(mut self, config: PipelineConfig) -> Self {
        assert!(config.bucket_size_bytes >= 4, "bucket must hold ≥ 1 f32");
        assert!(
            self.compressors.is_empty() && self.bucketed.is_empty(),
            "pipeline must be configured before the first sync round"
        );
        self.pipeline = Some(config);
        self
    }

    pub fn pipeline_config(&self) -> Option<&PipelineConfig> {
        self.pipeline.as_ref()
    }

    /// The compression bucket layout in effect (pipeline mode only).
    fn bucket_layout(&self) -> BucketLayout {
        let cfg = self.pipeline.as_ref().expect("pipeline configured");
        BucketLayout::from_bytes(self.n_params, cfg.bucket_size_bytes)
    }

    fn ensure_compressors(&mut self) {
        if self.compressors.is_empty() {
            let cfg = self
                .compression_cfg
                .clone()
                .expect("sparse strategy has a compression config");
            self.compressors = (0..self.n_workers)
                .map(|_| NetSenseCompressor::new(self.n_params, cfg.clone()))
                .collect();
        }
    }

    fn ensure_bucketed(&mut self) {
        if self.bucketed.is_empty() {
            let cfg = self
                .compression_cfg
                .clone()
                .expect("sparse strategy has a compression config");
            let layout = self.bucket_layout();
            self.bucketed = (0..self.n_workers)
                .map(|_| BucketedCompressor::new(layout.clone(), cfg.clone()))
                .collect();
        }
    }

    /// Wire bytes Algorithm 2 would produce at `ratio` over `n` elements
    /// (no allocation). Assumes the quantization density condition
    /// (`grad ℓ2 > tr_d`) holds whenever `ratio < tr_q` — the pure
    /// timing-only case, where no gradient has ever been seen. Runs that
    /// have spot-checked use the per-worker compressor state instead
    /// ([`NetSenseCompressor::predict_wire_bytes`]), which also covers the
    /// quantization-skip condition for near-zero tensors.
    fn predict_wire_n(&self, n: usize, ratio: f64) -> u64 {
        let cfg = self
            .compression_cfg
            .as_ref()
            .expect("sparse strategy has a compression config");
        let ratio = ratio.clamp(0.0, 1.0);
        let (eff, val_bytes) = if ratio < cfg.quant_ratio_threshold {
            ((2.0 * ratio).min(1.0), 2u64)
        } else {
            (ratio, 4u64)
        };
        let k = crate::compress::topk::k_for_ratio(n, eff) as u64;
        12 + k * (4 + val_bytes)
    }

    /// Wire bytes for the whole (monolithic) gradient at `ratio`.
    fn predict_wire(&self, ratio: f64) -> u64 {
        self.predict_wire_n(self.n_params, ratio)
    }

    /// Coalesce per-bucket wire sizes into transport stages: adaptive mode
    /// targets one sensed BDP per stage (shrinking under congestion), and
    /// falls back to one bucket per stage without an estimate source.
    fn stage_groups(&self, bucket_wire: &[u64]) -> Vec<std::ops::Range<usize>> {
        let cfg = self.pipeline.as_ref().expect("pipeline configured");
        let floor = bucket_wire.iter().copied().max().unwrap_or(1).max(1);
        let total: u64 = bucket_wire.iter().sum();
        let target = if cfg.adaptive {
            match &self.controller {
                Some(ctl) => ctl.recommended_bucket_bytes(floor, total.max(floor)),
                None => floor,
            }
        } else {
            floor
        };
        group_indices_by_bytes(bucket_wire, target)
    }

    /// Build the pipeline stages for one round from per-bucket wire sizes
    /// (`wire[worker][bucket]`).
    fn build_stages(&self, layout: &BucketLayout, wire: &[Vec<u64>]) -> Vec<PipelineStage> {
        let cfg = self.pipeline.as_ref().expect("pipeline configured");
        let nb = layout.n_buckets();
        let bucket_max: Vec<u64> = (0..nb)
            .map(|b| wire.iter().map(|w| w[b]).max().unwrap_or(0))
            .collect();
        self.stage_groups(&bucket_max)
            .into_iter()
            .map(|g| {
                let payload_bytes: Vec<u64> = wire
                    .iter()
                    .map(|w| g.clone().map(|b| w[b]).sum())
                    .collect();
                // Every worker decode-reduces the whole group's stage
                // payloads (all-gather semantics, own bucket included).
                let decode_time = cfg.decode_time(payload_bytes.iter().sum());
                PipelineStage {
                    compress_time: cfg
                        .compress_time(g.clone().map(|b| layout.dense_bytes(b)).sum()),
                    decode_time,
                    payload_bytes,
                }
            })
            .collect()
    }

    /// The `quantized` observable for a timing-only round — from compressor
    /// state when a spot check has run (matching `sync_full`'s density
    /// test, OR across workers/buckets), else the steady-state ratio test.
    fn predicted_quantized(&self, ratio: f64) -> bool {
        if !self.bucketed.is_empty() {
            return self.bucketed.iter().any(|b| b.would_quantize(ratio));
        }
        if !self.compressors.is_empty() {
            return self.compressors.iter().any(|c| c.would_quantize(ratio));
        }
        ratio
            < self
                .compression_cfg
                .as_ref()
                .map(|c| c.quant_ratio_threshold)
                .unwrap_or(0.0)
    }

    /// The ratio the next round will use.
    pub fn current_ratio(&self) -> f64 {
        match &self.strategy {
            SyncStrategy::NetSense => self.controller.as_ref().unwrap().ratio(),
            SyncStrategy::AllReduce => 1.0,
            SyncStrategy::TopK(r) => *r,
        }
    }

    pub fn controller(&self) -> Option<&RatioController> {
        self.controller.as_ref()
    }

    /// Snapshot every worker's compressor state into a [`Checkpoint`]
    /// (monolithic: one state per worker; pipelined: per-bucket states,
    /// worker-major). `None` before any full-fidelity round has run —
    /// there is no state worth saving yet.
    pub fn export_checkpoint(&self, epoch: u64, step: u64) -> Option<Checkpoint> {
        let states: Vec<CompressorState> = if !self.bucketed.is_empty() {
            self.bucketed.iter().flat_map(|b| b.export_state()).collect()
        } else if !self.compressors.is_empty() {
            self.compressors
                .iter()
                .map(NetSenseCompressor::export_state)
                .collect()
        } else {
            return None;
        };
        Some(Checkpoint::new(epoch, step, states))
    }

    /// Restore a [`Self::export_checkpoint`] snapshot into an engine
    /// configured identically (strategy, worker count, bucket layout).
    /// The engine then continues **bit-identically** to the one that
    /// exported — the rejoin guarantee tested below.
    ///
    /// A snapshot whose shape does not match this engine (wrong worker
    /// count, bucket layout, or residual lengths — a checkpoint from a
    /// different run, or one that decoded from a corrupted-but-parseable
    /// blob) is rejected as a named error **before any state is
    /// touched**: on `Err`, the engine continues exactly as it was.
    pub fn import_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        // Validate the full shape first; only then mutate. The inner
        // `import_state` length assertions become unreachable.
        if self.pipeline.is_some() {
            let layout = self.bucket_layout();
            let nb = layout.n_buckets();
            if ck.states.len() != self.n_workers * nb {
                return Err(anyhow!(
                    "checkpoint shape mismatch: {} states, engine has {} workers × {nb} buckets",
                    ck.states.len(),
                    self.n_workers
                ));
            }
            for (i, s) in ck.states.iter().enumerate() {
                let want = layout.elems(i % nb);
                if s.residual.len() != want {
                    return Err(anyhow!(
                        "checkpoint state {i}: residual has {} elems, bucket {} holds {want}",
                        s.residual.len(),
                        i % nb
                    ));
                }
            }
            self.ensure_bucketed();
            for (w, b) in self.bucketed.iter_mut().enumerate() {
                b.import_state(&ck.states[w * nb..(w + 1) * nb]);
            }
        } else {
            if ck.states.len() != self.n_workers {
                return Err(anyhow!(
                    "checkpoint shape mismatch: {} states, engine has {} workers",
                    ck.states.len(),
                    self.n_workers
                ));
            }
            for (i, s) in ck.states.iter().enumerate() {
                if s.residual.len() != self.n_params {
                    return Err(anyhow!(
                        "checkpoint state {i}: residual has {} elems, model has {}",
                        s.residual.len(),
                        self.n_params
                    ));
                }
            }
            self.ensure_compressors();
            for (c, s) in self.compressors.iter_mut().zip(&ck.states) {
                c.import_state(s);
            }
        }
        crate::obs::hot().checkpoint_restores_total.inc();
        Ok(())
    }

    /// Mean residual norm across workers (compression-health metric).
    pub fn mean_residual_norm(&self) -> f64 {
        if self.compressors.is_empty() {
            return 0.0;
        }
        self.compressors
            .iter()
            .map(NetSenseCompressor::residual_norm)
            .sum::<f64>()
            / self.compressors.len() as f64
    }

    /// Full-fidelity synchronization of per-worker gradients.
    ///
    /// `weights` is the flat parameter vector (identical across replicas),
    /// used by Algorithm 2's pruning step.
    ///
    /// Errors name the offending frame when the receive side rejects a
    /// payload (the pipelined path decode-reduces real wire frames) — a
    /// corrupt frame must never panic the engine, matching the live
    /// socket path ([`crate::experiments::live`]).
    pub fn sync_full(
        &mut self,
        net: &mut dyn GroupTransport,
        grads: &[Vec<f32>],
        weights: &[f32],
    ) -> Result<SyncOutcome> {
        assert_eq!(grads.len(), self.n_workers, "one gradient per worker");
        crate::obs::hot().sim_syncs_total.inc();
        match self.strategy.clone() {
            SyncStrategy::AllReduce => {
                let dense_bytes = 4 * self.n_params as u64;
                let comm = net.allreduce(dense_bytes);
                // Numeric: mean of the dense gradients.
                let mut acc = grads[0].clone();
                let others: Vec<&[f32]> = grads[1..].iter().map(|g| g.as_slice()).collect();
                crate::collectives::mean_dense(&mut acc, &others);
                Ok(SyncOutcome {
                    mean_grad: Some(acc),
                    payload_bytes: vec![dense_bytes; self.n_workers],
                    comm,
                    ratio: 1.0,
                    quantized: false,
                })
            }
            SyncStrategy::NetSense | SyncStrategy::TopK(_) => {
                if self.pipeline.is_some() {
                    return self.sync_full_pipelined(net, grads, weights);
                }
                self.ensure_compressors();
                let ratio = self.current_ratio();
                let mut payloads: Vec<SparseGradient> = Vec::with_capacity(self.n_workers);
                let mut quantized = false;
                for (w, grad) in grads.iter().enumerate() {
                    let out = self.compressors[w].compress(grad, weights, ratio);
                    quantized |= out.quantized;
                    payloads.push(out.payload);
                }
                let bytes: Vec<u64> = payloads.iter().map(SparseGradient::wire_bytes).collect();
                let comm = net.allgather(&bytes);
                // Numeric: every worker materializes the mean of all
                // payloads (all-gather → local sum).
                let mut acc = sum_sparse(self.n_params, &payloads);
                let scale = 1.0 / self.n_workers as f32;
                for a in acc.iter_mut() {
                    *a *= scale;
                }
                self.observe(&bytes, &comm);
                Ok(SyncOutcome {
                    mean_grad: Some(acc),
                    payload_bytes: bytes,
                    comm,
                    ratio,
                    quantized,
                })
            }
        }
    }

    /// Full-fidelity bucketed pipelined synchronization: per-bucket fused
    /// Algorithm-2 compression straight to wire frames
    /// ([`BucketedCompressor::compress_frames`] — no `SparseGradient` on
    /// the send side, buckets compressed in parallel across the workspace
    /// pool), BDP-sized transport stages, compress ∥ transmit overlap,
    /// and decode ∥ recv overlap on the way back (the stage timing model
    /// reduces bucket *b* while bucket *b+1* is still on the wire).
    /// The receive/reduce side consumes the frames exactly as a real
    /// receiver does — fused [`decode_reduce_frame_into`], no
    /// `SparseGradient` on this side either — and accumulates
    /// bucket-wise. A frame the decoder rejects is a named error, never a
    /// panic. The reduced gradient is invariant to the transport
    /// scheduling — only the virtual clock differs from a monolithic send
    /// of the same bucketed payloads.
    fn sync_full_pipelined(
        &mut self,
        net: &mut dyn GroupTransport,
        grads: &[Vec<f32>],
        weights: &[f32],
    ) -> Result<SyncOutcome> {
        self.ensure_bucketed();
        let ratio = self.current_ratio();
        let layout = self.bucketed[0].layout().clone();
        let nb = layout.n_buckets();
        let mut quantized = false;
        let mut wire: Vec<Vec<u64>> = Vec::with_capacity(self.n_workers);
        // Receive/reduce side: bucket-wise dense accumulators.
        let mut parts: Vec<Vec<f32>> = (0..nb).map(|b| vec![0f32; layout.elems(b)]).collect();
        let bucketed = &mut self.bucketed;
        let pool = &mut self.pool;
        for (w, grad) in grads.iter().enumerate() {
            let (outs, frames) = bucketed[w].compress_frames(grad, weights, ratio, pool);
            let mut w_wire = Vec::with_capacity(nb);
            for (b, (out, frame)) in outs.iter().zip(frames).enumerate() {
                quantized |= out.quantized;
                w_wire.push(out.wire_bytes);
                // Receive side: fused decode-reduce straight from the
                // wire frame into this bucket's dense accumulator.
                decode_reduce_frame_into(frame, &mut parts[b]).map_err(|e| {
                    anyhow!("worker {w} bucket {b}: corrupt frame on receive: {e}")
                })?;
            }
            wire.push(w_wire);
        }
        let stages = self.build_stages(&layout, &wire);
        let depth = self.pipeline.as_ref().unwrap().pipeline_depth;
        let timing = net.pipelined(&stages, depth);
        // Numeric: bucket-wise mean of everyone's payloads, fused back.
        let scale = 1.0 / self.n_workers as f32;
        for p in parts.iter_mut() {
            for a in p.iter_mut() {
                *a *= scale;
            }
        }
        let mean = layout.fuse(&parts);
        let bytes: Vec<u64> = wire.iter().map(|w| w.iter().sum()).collect();
        self.observe_exchange(&bytes, &timing);
        Ok(SyncOutcome {
            mean_grad: Some(mean),
            payload_bytes: bytes,
            comm: timing.comm,
            ratio,
            quantized,
        })
    }

    /// Timing-only bucketed pipelined synchronization. Byte-exact against
    /// [`SyncEngine::sync_full_pipelined`]: once a full-fidelity round has
    /// run (mixed-fidelity runs spot-check step 0), per-bucket predictions
    /// come from each worker's [`BucketedCompressor`] state, which honors
    /// the quantization-skip condition for near-zero buckets (a frozen
    /// layer at ratios below `tr_q`). A never-spot-checked run falls back
    /// to the steady-state density assumption of
    /// [`SyncEngine::predict_wire_n`].
    fn sync_predicted_pipelined(&mut self, net: &mut dyn GroupTransport) -> SyncOutcome {
        let ratio = self.current_ratio();
        let layout = self.bucket_layout();
        let nb = layout.n_buckets();
        let wire: Vec<Vec<u64>> = if self.bucketed.is_empty() {
            let per_bucket: Vec<u64> = (0..nb)
                .map(|b| self.predict_wire_n(layout.elems(b), ratio))
                .collect();
            vec![per_bucket; self.n_workers]
        } else {
            self.bucketed
                .iter()
                .map(|bc| bc.predict_wire_bytes(ratio))
                .collect()
        };
        let stages = self.build_stages(&layout, &wire);
        let depth = self.pipeline.as_ref().unwrap().pipeline_depth;
        let timing = net.pipelined(&stages, depth);
        let bytes: Vec<u64> = wire.iter().map(|w| w.iter().sum()).collect();
        let quantized = self.predicted_quantized(ratio);
        self.observe_exchange(&bytes, &timing);
        SyncOutcome {
            mean_grad: None,
            payload_bytes: bytes,
            comm: timing.comm,
            ratio,
            quantized,
        }
    }

    /// Timing-only synchronization (surrogate fast path): identical wire
    /// sizes and controller observations, no tensor math.
    pub fn sync_predicted(&mut self, net: &mut dyn GroupTransport) -> SyncOutcome {
        match self.strategy.clone() {
            SyncStrategy::AllReduce => {
                let dense_bytes = 4 * self.n_params as u64;
                let comm = net.allreduce(dense_bytes);
                SyncOutcome {
                    mean_grad: None,
                    payload_bytes: vec![dense_bytes; self.n_workers],
                    comm,
                    ratio: 1.0,
                    quantized: false,
                }
            }
            SyncStrategy::NetSense | SyncStrategy::TopK(_) => {
                if self.pipeline.is_some() {
                    return self.sync_predicted_pipelined(net);
                }
                let ratio = self.current_ratio();
                let bytes: Vec<u64> = if self.compressors.is_empty() {
                    vec![self.predict_wire(ratio); self.n_workers]
                } else {
                    self.compressors
                        .iter()
                        .map(|c| c.predict_wire_bytes(ratio))
                        .collect()
                };
                let comm = net.allgather(&bytes);
                self.observe(&bytes, &comm);
                let quantized = self.predicted_quantized(ratio);
                SyncOutcome {
                    mean_grad: None,
                    payload_bytes: bytes,
                    comm,
                    ratio,
                    quantized,
                }
            }
        }
    }

    /// Feed the Algorithm-1 controller with this round's observables.
    fn observe(&mut self, payload_bytes: &[u64], comm: &CollectiveTiming) {
        self.observe_rtt(payload_bytes, comm.elapsed());
    }

    /// Pipelined rounds report the *network* portion as the RTT observable
    /// (the paper measures transfer completion time of the interval's
    /// data); the leading compression stall is CPU, not network.
    fn observe_exchange(&mut self, payload_bytes: &[u64], timing: &ExchangeTiming) {
        self.observe_rtt(payload_bytes, timing.net_elapsed());
    }

    fn observe_rtt(&mut self, payload_bytes: &[u64], rtt: SimTime) {
        if let Some(ctl) = self.controller.as_mut() {
            let data_size = payload_bytes.iter().copied().max().unwrap_or(0).max(1);
            let rtt = if rtt > SimTime::ZERO {
                rtt
            } else {
                SimTime::from_nanos(1)
            };
            ctl.on_interval(data_size, rtt, false);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;
    use crate::netsim::{NetSim, SimTime};
    use crate::util::rng::Pcg64;

    const N: usize = 4;
    const P: usize = 10_000;

    fn sim(bw: f64) -> NetSim {
        NetSim::quiet(StarTopology::constant(N, mbps(bw), SimTime::from_millis(5)))
    }

    fn grads(seed: u64) -> Vec<Vec<f32>> {
        (0..N)
            .map(|w| {
                let mut r = Pcg64::new(seed, w as u64);
                let mut g = vec![0f32; P];
                r.fill_normal_f32(&mut g, 0.0, 1.0);
                g
            })
            .collect()
    }

    fn weights() -> Vec<f32> {
        let mut r = Pcg64::seeded(99);
        let mut w = vec![0f32; P];
        r.fill_normal_f32(&mut w, 0.0, 0.1);
        w
    }

    #[test]
    fn allreduce_mean_is_exact() {
        let mut eng = SyncEngine::new(SyncStrategy::AllReduce, N, P);
        let gs = grads(1);
        let out = eng.sync_full(&mut sim(1000.0), &gs, &weights()).unwrap();
        let mean = out.mean_grad.unwrap();
        for i in (0..P).step_by(997) {
            let want: f32 = gs.iter().map(|g| g[i]).sum::<f32>() / N as f32;
            assert!((mean[i] - want).abs() < 1e-5);
        }
        assert_eq!(out.ratio, 1.0);
        assert_eq!(out.payload_bytes, vec![4 * P as u64; N]);
    }

    #[test]
    fn topk_payload_matches_static_ratio() {
        let mut eng = SyncEngine::new(SyncStrategy::TopK(0.1), N, P);
        let out = eng.sync_full(&mut sim(1000.0), &grads(2), &weights()).unwrap();
        let k = (P as f64 * 0.1) as u64;
        for &b in &out.payload_bytes {
            assert_eq!(b, 12 + k * 8);
        }
        assert!(!out.quantized);
        // mean_grad is sparse-ish: at most N·k nonzeros
        let nnz = out
            .mean_grad
            .unwrap()
            .iter()
            .filter(|&&x| x != 0.0)
            .count();
        assert!(nnz <= N * k as usize);
        assert!(nnz >= k as usize);
    }

    #[test]
    fn netsense_controller_advances() {
        let mut eng = SyncEngine::new(SyncStrategy::NetSense, N, P);
        let w = weights();
        let r0 = eng.current_ratio();
        for seed in 0..5 {
            eng.sync_full(&mut sim(100.0), &grads(seed), &w).unwrap();
        }
        assert_eq!(eng.controller().unwrap().intervals(), 5);
        // Startup ramp should have moved the ratio off its initial value.
        assert_ne!(eng.current_ratio(), r0);
    }

    #[test]
    fn predicted_wire_bytes_match_full_fidelity() {
        // The fast path must be byte-exact vs the full path for both
        // sparse strategies, across the quantization boundary.
        for strat in [SyncStrategy::TopK(0.1), SyncStrategy::NetSense] {
            let mut full = SyncEngine::new(strat.clone(), N, P);
            let mut pred = SyncEngine::new(strat.clone(), N, P);
            let w = weights();
            for seed in 0..8 {
                let a = full.sync_full(&mut sim(50.0), &grads(seed), &w).unwrap();
                let b = pred.sync_predicted(&mut sim(50.0));
                assert_eq!(
                    a.payload_bytes, b.payload_bytes,
                    "{strat:?} seed {seed}: {} vs {}",
                    a.payload_bytes[0], b.payload_bytes[0]
                );
                assert_eq!(a.ratio, b.ratio, "{strat:?} ratio diverged");
            }
        }
    }

    #[test]
    fn lower_bandwidth_means_longer_comm() {
        // Use a serialization-dominated payload (10 M params ≈ 40 MB dense)
        // so the bandwidth difference is visible past the propagation floor.
        let big = 10_000_000usize;
        let mut a = SyncEngine::new(SyncStrategy::AllReduce, N, big);
        let mut b = SyncEngine::new(SyncStrategy::AllReduce, N, big);
        let t_fast = a.sync_predicted(&mut sim(1000.0)).comm.elapsed();
        let t_slow = b.sync_predicted(&mut sim(100.0)).comm.elapsed();
        assert!(t_slow.as_secs_f64() > 5.0 * t_fast.as_secs_f64());
    }

    #[test]
    fn netsense_payload_shrinks_under_congestion() {
        // On a slow link the controller must cut payloads far below dense.
        let mut eng = SyncEngine::new(SyncStrategy::NetSense, N, P);
        let mut s = sim(10.0);
        let mut last = 0u64;
        for _ in 0..40 {
            let out = eng.sync_predicted(&mut s);
            s.advance_by(SimTime::from_millis(300)); // compute gap
            last = out.max_payload();
        }
        assert!(
            last < 4 * P as u64 / 2,
            "payload {last} not reduced vs dense {}",
            4 * P
        );
    }

    #[test]
    fn pipelined_and_monolithic_produce_identical_reduced_gradients() {
        // A pipelined engine whose bucket covers the whole tensor runs the
        // exact same compression as the monolithic engine; the pipelined
        // transport scheduling must not change the reduced gradient by a
        // single bit.
        for strat in [SyncStrategy::TopK(0.1), SyncStrategy::NetSense] {
            let mut mono = SyncEngine::new(strat.clone(), N, P);
            let mut pipe = SyncEngine::new(strat.clone(), N, P).with_pipeline(PipelineConfig {
                bucket_size_bytes: 4 * P as u64, // single bucket
                ..Default::default()
            });
            let w = weights();
            for seed in 0..6 {
                let gs = grads(seed);
                let a = mono.sync_full(&mut sim(100.0), &gs, &w).unwrap();
                let b = pipe.sync_full(&mut sim(100.0), &gs, &w).unwrap();
                assert_eq!(a.ratio, b.ratio, "{strat:?} ratio diverged at {seed}");
                assert_eq!(
                    a.mean_grad, b.mean_grad,
                    "{strat:?} reduced gradient diverged at seed {seed}"
                );
                assert_eq!(a.payload_bytes, b.payload_bytes);
            }
        }
    }

    #[test]
    fn pipeline_scheduling_knobs_do_not_change_numerics() {
        // Same bucket layout, different transport scheduling (depth,
        // adaptivity): byte-identical payloads and reduced gradients.
        let mk = |depth: usize, adaptive: bool| {
            SyncEngine::new(SyncStrategy::TopK(0.1), N, P).with_pipeline(PipelineConfig {
                bucket_size_bytes: 8_192,
                pipeline_depth: depth,
                adaptive,
                ..Default::default()
            })
        };
        let mut a = mk(1, false);
        let mut b = mk(8, true);
        let w = weights();
        for seed in 0..5 {
            let gs = grads(seed);
            let oa = a.sync_full(&mut sim(50.0), &gs, &w).unwrap();
            let ob = b.sync_full(&mut sim(50.0), &gs, &w).unwrap();
            assert_eq!(oa.mean_grad, ob.mean_grad, "seed {seed}");
            assert_eq!(oa.payload_bytes, ob.payload_bytes, "seed {seed}");
        }
    }

    #[test]
    fn pipelined_predicted_wire_bytes_match_full_fidelity() {
        // The timing-only pipelined path must stay byte-exact against the
        // full pipelined path, bucket layout and all.
        let cfg = PipelineConfig {
            bucket_size_bytes: 10_000,
            ..Default::default()
        };
        for strat in [SyncStrategy::TopK(0.1), SyncStrategy::NetSense] {
            let mut full = SyncEngine::new(strat.clone(), N, P).with_pipeline(cfg.clone());
            let mut pred = SyncEngine::new(strat.clone(), N, P).with_pipeline(cfg.clone());
            let w = weights();
            for seed in 0..8 {
                let a = full.sync_full(&mut sim(50.0), &grads(seed), &w).unwrap();
                let b = pred.sync_predicted(&mut sim(50.0));
                assert_eq!(a.payload_bytes, b.payload_bytes, "{strat:?} seed {seed}");
                assert_eq!(a.ratio, b.ratio, "{strat:?} ratio diverged");
            }
        }
    }

    #[test]
    fn predicted_stays_byte_exact_for_frozen_buckets_after_spot_check() {
        // Regression (DESIGN.md §3 caveat, now fixed): a frozen layer's
        // bucket has zero gradient, fails the quantization density
        // condition, and used to make `sync_predicted` diverge from
        // `sync_full` at ratios below `tr_q`. With compressor-state-aware
        // prediction, a mixed-fidelity run (full spot-check at step 0,
        // predicted after) stays byte-exact against an all-full run.
        let cfg = PipelineConfig {
            bucket_size_bytes: 4 * 2_500, // 4 buckets of 2 500 elems
            ..Default::default()
        };
        let mut full = SyncEngine::new(SyncStrategy::NetSense, N, P).with_pipeline(cfg.clone());
        let mut mixed = SyncEngine::new(SyncStrategy::NetSense, N, P).with_pipeline(cfg);
        let w = weights();
        let frozen_grads = |seed: u64| -> Vec<Vec<f32>> {
            let mut gs = grads(seed);
            for g in gs.iter_mut() {
                for x in g[0..2_500].iter_mut() {
                    *x = 0.0; // bucket 0 is a frozen layer on every worker
                }
            }
            gs
        };
        // NetSense starts at ratio 0.01 < tr_q = 0.05, so the healthy
        // buckets quantize while the frozen bucket must skip.
        let a0 = full.sync_full(&mut sim(50.0), &frozen_grads(0), &w).unwrap();
        let b0 = mixed.sync_full(&mut sim(50.0), &frozen_grads(0), &w).unwrap();
        assert_eq!(a0.payload_bytes, b0.payload_bytes);
        for seed in 1..7 {
            let a = full.sync_full(&mut sim(50.0), &frozen_grads(seed), &w).unwrap();
            let b = mixed.sync_predicted(&mut sim(50.0));
            assert_eq!(
                a.payload_bytes, b.payload_bytes,
                "frozen-bucket divergence at step {seed} (ratio {})",
                a.ratio
            );
            assert_eq!(a.ratio, b.ratio, "controller drifted at step {seed}");
            assert_eq!(a.quantized, b.quantized, "quantized flag diverged at step {seed}");
        }
    }

    #[test]
    fn pipelined_round_is_not_slower_than_monolithic_with_compression_cost() {
        // Same compression granularity (single bucket is mono's exact
        // equal) — multi-bucket pipeline must win once compression time and
        // transmission time both matter.
        let big = 2_000_000usize; // 8 MB dense
        let cfg = PipelineConfig {
            bucket_size_bytes: 1 << 20,
            pipeline_depth: 2,
            compress_bytes_per_sec: 200e6, // 8 MB → 40 ms per round
            adaptive: false,
            ..Default::default()
        };
        let mut mono = SyncEngine::new(SyncStrategy::TopK(0.25), N, big).with_pipeline(
            PipelineConfig {
                bucket_size_bytes: 4 * big as u64,
                ..cfg.clone()
            },
        );
        let mut pipe = SyncEngine::new(SyncStrategy::TopK(0.25), N, big).with_pipeline(cfg);
        let t_mono = mono.sync_predicted(&mut sim(100.0)).comm.elapsed();
        let t_pipe = pipe.sync_predicted(&mut sim(100.0)).comm.elapsed();
        assert!(
            t_pipe < t_mono,
            "pipelined {t_pipe} not faster than monolithic {t_mono}"
        );
    }

    /// The rejoin path end-to-end through the coordinator: checkpoint →
    /// wire → restore into a fresh engine → bitwise-identical
    /// continuation, monolithic and pipelined both.
    #[test]
    fn checkpoint_restores_engine_to_bitwise_continuation() {
        for pipelined in [false, true] {
            let mk = || {
                let e = SyncEngine::new(SyncStrategy::TopK(0.1), N, P);
                if pipelined {
                    e.with_pipeline(PipelineConfig {
                        bucket_size_bytes: 10_000,
                        ..Default::default()
                    })
                } else {
                    e
                }
            };
            let w = weights();
            let mut original = mk();
            assert!(original.export_checkpoint(0, 0).is_none(), "no state yet");
            for seed in 0..4 {
                original.sync_full(&mut sim(100.0), &grads(seed), &w).unwrap();
            }
            let wire = original.export_checkpoint(1, 4).unwrap().encode();
            let ck = crate::fault::Checkpoint::decode(&wire).unwrap();
            assert_eq!((ck.epoch, ck.step), (1, 4));
            let mut rejoined = mk();
            rejoined.import_checkpoint(&ck).unwrap();
            for seed in 4..8 {
                let gs = grads(seed);
                let a = original.sync_full(&mut sim(100.0), &gs, &w).unwrap();
                let b = rejoined.sync_full(&mut sim(100.0), &gs, &w).unwrap();
                assert_eq!(
                    a.mean_grad, b.mean_grad,
                    "pipelined={pipelined} seed {seed}: restored engine diverged"
                );
                assert_eq!(a.payload_bytes, b.payload_bytes, "pipelined={pipelined}");
            }
        }
    }

    /// A checkpoint whose shape does not match the engine — wrong state
    /// count or wrong residual length, e.g. a blob from a different run
    /// that still parsed — is a named error, and the engine is left
    /// untouched: it continues bit-identically to a witness engine that
    /// never saw the corrupt blob.
    #[test]
    fn corrupt_checkpoint_is_rejected_and_engine_continues_untouched() {
        for pipelined in [false, true] {
            let mk = || {
                let e = SyncEngine::new(SyncStrategy::TopK(0.1), N, P);
                if pipelined {
                    e.with_pipeline(PipelineConfig {
                        bucket_size_bytes: 10_000,
                        ..Default::default()
                    })
                } else {
                    e
                }
            };
            let w = weights();
            let mut engine = mk();
            let mut witness = mk();
            for seed in 0..3 {
                engine.sync_full(&mut sim(100.0), &grads(seed), &w).unwrap();
                witness.sync_full(&mut sim(100.0), &grads(seed), &w).unwrap();
            }
            let good = engine.export_checkpoint(0, 3).unwrap();
            // Wrong state count (a different worker count or bucket layout).
            let mut bad = good.clone();
            bad.states.pop();
            let e = engine.import_checkpoint(&bad).unwrap_err();
            assert!(
                format!("{e}").contains("shape mismatch"),
                "pipelined={pipelined}: {e}"
            );
            // Right count, wrong residual length in one state — caught by
            // validation *before* any compressor is mutated (the panic
            // inside `import_state` is unreachable).
            let mut bad = good.clone();
            bad.states[0].residual.pop();
            let e = engine.import_checkpoint(&bad).unwrap_err();
            assert!(
                format!("{e}").contains("residual has"),
                "pipelined={pipelined}: {e}"
            );
            // The engine that survived two rejected imports continues
            // exactly like the witness that never saw them.
            for seed in 3..6 {
                let gs = grads(seed);
                let a = engine.sync_full(&mut sim(100.0), &gs, &w).unwrap();
                let b = witness.sync_full(&mut sim(100.0), &gs, &w).unwrap();
                assert_eq!(
                    a.mean_grad, b.mean_grad,
                    "pipelined={pipelined} seed {seed}: engine was perturbed by rejected import"
                );
            }
        }
    }

    #[test]
    fn error_feedback_keeps_sparse_mean_unbiased_over_time() {
        // Summed over many rounds, the sparse-aggregated means must track
        // the dense means (error feedback drains everything eventually).
        let mut eng = SyncEngine::new(SyncStrategy::TopK(0.25), N, P);
        let w = weights();
        let gs = grads(7); // constant gradients each round
        let mut sparse_sum = vec![0f64; P];
        let rounds = 30;
        for _ in 0..rounds {
            let out = eng.sync_full(&mut sim(1000.0), &gs, &w).unwrap();
            for (s, &v) in sparse_sum.iter_mut().zip(out.mean_grad.as_ref().unwrap()) {
                *s += v as f64;
            }
        }
        let mut err = 0f64;
        let mut mag = 0f64;
        for i in 0..P {
            let dense_mean: f64 =
                gs.iter().map(|g| g[i] as f64).sum::<f64>() / N as f64;
            let want = dense_mean * rounds as f64;
            err += (sparse_sum[i] - want).abs();
            mag += want.abs();
        }
        // Within a couple of rounds' worth of residual.
        assert!(err / mag < 0.15, "relative drift {}", err / mag);
    }
}
