//! The DDP coordinator — the paper's system layer. Owns the training loop,
//! the gradient-synchronization strategies, and the interposition point
//! where NetSenseML's sensing + adaptive compression replace the default
//! all-reduce (the role of the paper's PyTorch DDP communication hook).
//!
//! - [`strategy`] — the three methods of the evaluation: `NetSense`,
//!   `AllReduce` (dense ring), `TopK(r)` (static sparsification).
//! - [`sync`] — one gradient-synchronization round: compress (per
//!   strategy), move bytes on the simulated network, aggregate, and feed
//!   the sensing controller.
//! - [`pipeline_exchange`] — the bucketed pipeline scheduler: compress
//!   bucket *k+1* while bucket *k* is in flight (compress ∥ transmit
//!   overlap), with BDP-adaptive transport staging.
//! - [`sim_train`] — the virtual-time training driver for paper-scale
//!   models (surrogate dynamics; used by every table/figure experiment).
//! - [`real_train`] — the real-numerics driver: JAX/Pallas models through
//!   the PJRT runtime with the network still simulated (the e2e example).

pub mod pipeline_exchange;
pub mod real_train;
pub mod sim_train;
pub mod strategy;
pub mod sync;

pub use pipeline_exchange::{
    monolithic_exchange, pipelined_exchange, ExchangeTiming, PipelineConfig, PipelineStage,
};
pub use real_train::{RealTrainConfig, RealTrainer};
pub use sim_train::{run_sim_training, SimTrainConfig};
pub use strategy::SyncStrategy;
pub use sync::{SyncEngine, SyncOutcome};
