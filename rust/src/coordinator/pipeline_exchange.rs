//! Pipelined gradient exchange: compress bucket *k+1* while bucket *k* is
//! in flight on the simulated link.
//!
//! The monolithic path pays `T_compress + T_transmit` every round because
//! no byte enters the network until Algorithm 2 has processed the whole
//! gradient. The pipelined path cuts the gradient into transport stages
//! (groups of compression buckets, see [`crate::compress::bucket`]) and
//! overlaps the CPU-side compression of stage *k+1* with the network-side
//! all-gather of stage *k*, approaching
//! `max(T_compress, T_transmit) + first-stage latency` — the same overlap
//! argument GraVAC and DDP gradient bucketing make for backward/all-reduce.
//!
//! Compression cost is modeled in virtual time via
//! [`PipelineConfig::compress_bytes_per_sec`] (dense input bytes per
//! second), calibrated against the measured throughput of the real
//! compressor: `bench_compress` records the fused single-pass path
//! ([`NetSenseCompressor::compress_frame_into`]) and the parallel
//! per-bucket fan-out
//! ([`BucketedCompressor::compress_frames`]) in the machine-readable
//! `BENCH_compress.json` baseline (`make bench-json`) — the
//! `fused_gbps_*` fields are the number this knob should track.
//!
//! [`NetSenseCompressor::compress_frame_into`]: crate::compress::NetSenseCompressor::compress_frame_into
//! [`BucketedCompressor::compress_frames`]: crate::compress::BucketedCompressor::compress_frames
//!
//! This module is the *simulated* backend of
//! [`crate::transport::GroupTransport::pipelined`]: the coordinator
//! ([`super::sync`]) never calls it directly — it drives the transport
//! seam, and the `NetSim` implementation lands here.

use crate::collectives::{ring_allgather, CollectiveTiming, StagedAllGather};
use crate::netsim::{NetSim, SimTime};

/// Knobs of the bucketed pipeline (`[pipeline]` table in config TOML).
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineConfig {
    /// Dense bytes per compression bucket — the error-feedback granularity
    /// and the smallest transport unit.
    pub bucket_size_bytes: u64,
    /// Maximum compressed-but-unsent stages in flight; compression of stage
    /// `i` stalls until stage `i − depth` has finished transmitting
    /// (bounded lookahead buffering). `0` means unbounded.
    pub pipeline_depth: usize,
    /// Modeled compression throughput, dense input bytes per second.
    pub compress_bytes_per_sec: f64,
    /// Modeled decode-reduce throughput, received wire bytes per second.
    /// The fused receive path is a single dequantize+scatter sweep
    /// (`decode_reduce_into`), substantially cheaper than compression —
    /// calibrate against `decode_fused_gbps_*` in `BENCH_compress.json`.
    pub decode_bytes_per_sec: f64,
    /// Let the sensing controller coalesce buckets into transport stages
    /// sized to the sensed BDP (stages shrink under congestion).
    pub adaptive: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            bucket_size_bytes: 4 << 20, // 4 MB dense per bucket
            pipeline_depth: 2,          // double buffering
            compress_bytes_per_sec: 2e9,
            decode_bytes_per_sec: 8e9,
            adaptive: true,
        }
    }
}

impl PipelineConfig {
    /// Virtual CPU time to compress `dense_bytes` of gradient input.
    pub fn compress_time(&self, dense_bytes: u64) -> SimTime {
        assert!(self.compress_bytes_per_sec > 0.0);
        SimTime::from_secs_f64(dense_bytes as f64 / self.compress_bytes_per_sec)
    }

    /// Virtual CPU time to decode-reduce `wire_bytes` of received
    /// payloads.
    pub fn decode_time(&self, wire_bytes: u64) -> SimTime {
        assert!(self.decode_bytes_per_sec > 0.0);
        SimTime::from_secs_f64(wire_bytes as f64 / self.decode_bytes_per_sec)
    }
}

/// One transport stage of the exchange: a group of one or more compression
/// buckets that ships as a unit.
#[derive(Clone, Debug)]
pub struct PipelineStage {
    /// Wire bytes each worker contributes for this stage.
    pub payload_bytes: Vec<u64>,
    /// CPU time to produce this stage's payload. Workers compress their own
    /// shards in parallel, so this is per-worker (not summed over workers).
    pub compress_time: SimTime,
    /// CPU time to decode-reduce this stage's received payloads (every
    /// worker scatters the whole group's stage payloads into its
    /// accumulator). In the pipelined schedule this overlaps the next
    /// stage's transfer — reduce bucket *b* while bucket *b+1* is still
    /// on the wire; the monolithic reference serializes it after the
    /// all-gather.
    pub decode_time: SimTime,
}

/// Timing of one full exchange (compression + transport).
#[derive(Clone, Debug)]
pub struct ExchangeTiming {
    /// Transport-level timing covering the whole exchange; `comm.start` is
    /// when the round began (compression included), `comm.end` when the
    /// last block arrived everywhere.
    pub comm: CollectiveTiming,
    /// When the first stage's payload entered the network (end of the
    /// unhidable first compression).
    pub net_start: SimTime,
    /// Total CPU compression time paid this round (per worker).
    pub compress_total: SimTime,
    /// When the receive-side decode-reduce of the last stage finished.
    /// In the pipelined schedule earlier stages decode while later ones
    /// are still on the wire, so only the tail past `comm.end` is
    /// exposed; the monolithic reference pays the full decode serialized
    /// after the all-gather.
    pub decode_done: SimTime,
    /// Number of transport stages.
    pub stages: usize,
}

impl ExchangeTiming {
    /// The network-only portion — the "RTT" observable fed to the sensing
    /// controller (transfer completion time of the round's data).
    pub fn net_elapsed(&self) -> SimTime {
        self.comm.end.saturating_sub(self.net_start)
    }

    /// The whole exchange including the exposed decode tail — what the
    /// training step actually waits for.
    pub fn total_elapsed(&self) -> SimTime {
        self.decode_done.max(self.comm.end).saturating_sub(self.comm.start)
    }
}

/// Run the pipelined exchange: stages compress sequentially on the CPU
/// timeline and enter the ring as soon as (a) their compression finished
/// and (b) the depth window allows; transport interleaves bucket phases via
/// [`StagedAllGather`]. Advances the simulator to the exchange end.
pub fn pipelined_exchange(
    sim: &mut NetSim,
    stages: &[PipelineStage],
    depth: usize,
) -> ExchangeTiming {
    let start = sim.now();
    let mut sag = StagedAllGather::new(sim);
    let mut cpu_free = start;
    let mut compress_total = SimTime::ZERO;
    let mut net_start = start;
    let mut decode_done = start;
    let mut completions: Vec<SimTime> = Vec::with_capacity(stages.len());
    for (i, st) in stages.iter().enumerate() {
        let gate = if depth > 0 && i >= depth {
            completions[i - depth]
        } else {
            start
        };
        let begin = cpu_free.max(gate);
        cpu_free = begin + st.compress_time;
        compress_total += st.compress_time;
        if i == 0 {
            net_start = cpu_free;
        }
        let done = sag.push(sim, cpu_free, &st.payload_bytes);
        // Decode-reduce of stage i starts the moment its blocks have all
        // arrived AND the previous stage's decode finished — overlapping
        // the transfers of every later stage.
        decode_done = decode_done.max(done) + st.decode_time;
        completions.push(done);
    }
    let comm = sag.finish(sim);
    // Only the decode tail past the last arrival is exposed wall-clock.
    if decode_done > sim.now() {
        let tail = decode_done.saturating_sub(sim.now());
        sim.advance_by(tail);
    }
    ExchangeTiming {
        comm,
        net_start,
        compress_total,
        decode_done: decode_done.max(comm.end),
        stages: stages.len(),
    }
}

/// Reference schedule: compress *everything*, then ship one monolithic
/// payload per worker — what the coordinator did before bucketing. Same
/// bytes, no overlap. Advances the simulator to the exchange end.
pub fn monolithic_exchange(sim: &mut NetSim, stages: &[PipelineStage]) -> ExchangeTiming {
    let start = sim.now();
    let n = sim.topology.n_workers();
    let mut total = vec![0u64; n];
    let mut compress_total = SimTime::ZERO;
    let mut decode_total = SimTime::ZERO;
    for st in stages {
        assert_eq!(st.payload_bytes.len(), n);
        for (t, &b) in total.iter_mut().zip(&st.payload_bytes) {
            *t += b;
        }
        compress_total += st.compress_time;
        decode_total += st.decode_time;
    }
    sim.advance_by(compress_total);
    let net_start = sim.now();
    let t = ring_allgather(sim, &total);
    // No overlap: the monolithic receiver decodes everything after the
    // last block arrives.
    sim.advance_by(decode_total);
    ExchangeTiming {
        comm: CollectiveTiming {
            start,
            end: t.end,
            sent_per_worker: t.sent_per_worker,
        },
        net_start,
        compress_total,
        decode_done: t.end + decode_total,
        stages: stages.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;

    const N: usize = 4;

    fn sim(bw_mbps: f64) -> NetSim {
        NetSim::quiet(StarTopology::constant(
            N,
            mbps(bw_mbps),
            SimTime::from_millis(1),
        ))
    }

    fn stages(k: usize, bytes: u64, compress_ms: u64) -> Vec<PipelineStage> {
        stages_with_decode(k, bytes, compress_ms, 0)
    }

    fn stages_with_decode(
        k: usize,
        bytes: u64,
        compress_ms: u64,
        decode_ms: u64,
    ) -> Vec<PipelineStage> {
        (0..k)
            .map(|_| PipelineStage {
                payload_bytes: vec![bytes; N],
                compress_time: SimTime::from_millis(compress_ms),
                decode_time: SimTime::from_millis(decode_ms),
            })
            .collect()
    }

    #[test]
    fn pipelined_beats_monolithic_when_both_costs_matter() {
        let st = stages(8, 1_000_000, 50);
        let pipe = pipelined_exchange(&mut sim(100.0), &st, 2);
        let mono = monolithic_exchange(&mut sim(100.0), &st);
        assert_eq!(pipe.compress_total, mono.compress_total);
        assert_eq!(pipe.comm.total_sent(), mono.comm.total_sent());
        assert!(
            pipe.comm.end < mono.comm.end,
            "pipelined {} not faster than monolithic {}",
            pipe.comm.end,
            mono.comm.end
        );
        // Compression can cost the pipeline at most its own total (fully
        // exposed) and never makes it faster than the free-compression run.
        let free = stages(8, 1_000_000, 0);
        let pipe0 = pipelined_exchange(&mut sim(100.0), &free, 2);
        assert!(pipe.comm.end >= pipe0.comm.end);
        assert!(pipe.comm.end <= pipe0.comm.end + pipe.compress_total);
    }

    #[test]
    fn single_stage_pipeline_equals_monolithic() {
        // One stage = compress-then-send either way; the staged all-gather
        // equals the barriered one on uniform payloads.
        let st = stages(1, 2_000_000, 40);
        let pipe = pipelined_exchange(&mut sim(200.0), &st, 2);
        let mono = monolithic_exchange(&mut sim(200.0), &st);
        assert_eq!(pipe.comm.end, mono.comm.end);
        assert_eq!(pipe.net_start, mono.net_start);
        assert_eq!(pipe.net_elapsed(), mono.net_elapsed());
    }

    #[test]
    fn zero_compress_time_still_benefits_from_no_barrier() {
        // With free compression the pipeline reduces to the staged
        // all-gather, which is never slower than the monolithic one.
        let st = stages(4, 500_000, 0);
        let pipe = pipelined_exchange(&mut sim(100.0), &st, 0);
        let mono = monolithic_exchange(&mut sim(100.0), &st);
        assert!(pipe.comm.end <= mono.comm.end);
        assert_eq!(pipe.compress_total, SimTime::ZERO);
    }

    #[test]
    fn depth_one_serializes_more_than_unbounded() {
        // depth=1: stage i's compression waits for stage i−1's transport —
        // strictly less lookahead than unbounded, so never faster.
        let st = stages(6, 1_500_000, 30);
        let deep = pipelined_exchange(&mut sim(80.0), &st, 0);
        let shallow = pipelined_exchange(&mut sim(80.0), &st, 1);
        assert!(deep.comm.end <= shallow.comm.end);
    }

    #[test]
    fn net_elapsed_excludes_leading_compression() {
        let st = stages(3, 1_000_000, 100);
        let x = pipelined_exchange(&mut sim(100.0), &st, 2);
        assert_eq!(x.net_start, SimTime::from_millis(100));
        assert_eq!(x.comm.start, SimTime::ZERO);
        assert!(x.net_elapsed() < x.comm.end - x.comm.start);
        assert_eq!(x.stages, 3);
    }

    /// The ISSUE receive-path claim: in the pipelined schedule the
    /// decode-reduce of stage *b* runs while stage *b+1* is still on the
    /// wire, so only the last stage's decode tail is exposed; the
    /// monolithic reference pays every stage's decode serialized after
    /// the all-gather.
    #[test]
    fn decode_overlaps_recv_in_the_pipelined_schedule() {
        let k = 8;
        let st = stages_with_decode(k, 1_000_000, 0, 20);
        let pipe = pipelined_exchange(&mut sim(100.0), &st, 0);
        let mono = monolithic_exchange(&mut sim(100.0), &st);
        // Monolithic: the full decode bill lands after the wire.
        assert_eq!(
            mono.decode_done,
            mono.comm.end + SimTime::from_millis(20 * k as u64)
        );
        // Pipelined: stages arrive slower than they decode (1 MB at
        // 100 Mbps ≫ 20 ms), so every decode except the last hides under
        // a later transfer — the exposed tail is one stage's decode.
        assert_eq!(pipe.decode_done, pipe.comm.end + SimTime::from_millis(20));
        assert!(
            pipe.total_elapsed() < mono.total_elapsed(),
            "pipelined decode tail {} not shorter than monolithic {}",
            pipe.total_elapsed(),
            mono.total_elapsed()
        );
        // Zero decode time: decode_done collapses onto the wire end.
        let free = stages(3, 500_000, 0);
        let x = pipelined_exchange(&mut sim(100.0), &free, 0);
        assert_eq!(x.decode_done, x.comm.end);
        assert_eq!(x.total_elapsed(), x.comm.end.saturating_sub(x.comm.start));
    }

    /// The simulator's clock must advance past the exposed decode tail —
    /// the next round cannot start while this round is still reducing.
    #[test]
    fn sim_clock_advances_past_the_decode_tail() {
        let mut s = sim(100.0);
        let st = stages_with_decode(2, 100_000, 0, 50);
        let x = pipelined_exchange(&mut s, &st, 0);
        assert_eq!(s.now(), x.decode_done);
        assert!(x.decode_done > x.comm.end);

        let mut s = sim(100.0);
        let x = monolithic_exchange(&mut s, &st);
        assert_eq!(s.now(), x.decode_done);
    }

    #[test]
    fn empty_stage_list_is_a_noop() {
        let mut s = sim(100.0);
        let x = pipelined_exchange(&mut s, &[], 2);
        assert_eq!(x.comm.start, x.comm.end);
        assert_eq!(x.net_elapsed(), SimTime::ZERO);
        assert_eq!(s.now(), SimTime::ZERO);
    }
}
