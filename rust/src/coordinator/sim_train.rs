//! Virtual-time training driver for paper-scale models.
//!
//! Each step: advance the simulator by the model's compute time, run one
//! synchronization round (full-fidelity on spot-check steps, predicted
//! otherwise — see [`super::sync`]), advance the surrogate dynamics by the
//! step's information quality, and record metrics. Wall-clock cost is
//! dominated by the spot checks; a 4 000-step run at the default cadence
//! finishes in seconds.

use super::pipeline_exchange::PipelineConfig;
use super::strategy::SyncStrategy;
use super::sync::SyncEngine;
use crate::netsim::{NetSim, SimTime};
use crate::trainer::metrics::{StepRecord, TrainLog};
use crate::trainer::models::PaperModel;
use crate::trainer::surrogate::SurrogateTrainer;
use crate::util::error::Result;

/// Configuration of one simulated training run.
#[derive(Clone, Debug)]
pub struct SimTrainConfig {
    pub model: &'static PaperModel,
    pub n_workers: usize,
    pub batch_per_worker: usize,
    pub strategy: SyncStrategy,
    /// Stop when virtual time exceeds this (seconds).
    pub max_vtime_s: f64,
    /// Hard step cap (safety).
    pub max_steps: usize,
    /// Run full-fidelity compression every N steps (0 = never; first step
    /// is always full when > 0).
    pub fidelity_every: usize,
    pub seed: u64,
    /// Bucketed pipelined exchange (None = monolithic compress-then-send).
    pub pipeline: Option<PipelineConfig>,
}

impl SimTrainConfig {
    pub fn new(model: &'static PaperModel, strategy: SyncStrategy) -> Self {
        SimTrainConfig {
            model,
            n_workers: 8,
            batch_per_worker: 32,
            strategy,
            max_vtime_s: 2000.0,
            max_steps: 100_000,
            fidelity_every: 250,
            seed: 42,
            pipeline: None,
        }
    }

    pub fn samples_per_step(&self) -> usize {
        self.n_workers * self.batch_per_worker
    }
}

/// Run one simulated training job on the given network. Returns the trace.
///
/// Errors propagate from the sync engine's receive side
/// ([`SyncEngine::sync_full`] decode-reduces real wire frames on
/// spot-check steps); a surrogate run's self-encoded frames cannot be
/// corrupt, so an `Err` here means an engine invariant broke.
pub fn run_sim_training(config: &SimTrainConfig, sim: &mut NetSim) -> Result<TrainLog> {
    assert_eq!(
        sim.topology.n_workers(),
        config.n_workers,
        "topology/config worker mismatch"
    );
    let mut engine = SyncEngine::new(
        config.strategy.clone(),
        config.n_workers,
        config.model.n_params,
    );
    if let Some(p) = &config.pipeline {
        engine = engine.with_pipeline(p.clone());
    }
    // Surrogate state is only materialized when spot checks will run
    // (it allocates n_workers full-size gradient tensors).
    let mut surrogate = SurrogateTrainer::new(config.model, config.n_workers, config.seed);
    let is_static = config.strategy.is_static_compression();
    let compute = SimTime::from_secs_f64(config.model.compute_time_s);

    let mut log = TrainLog::new(
        &config.strategy.label(),
        config.model.name,
        config.samples_per_step(),
    );

    for step in 0..config.max_steps {
        let t_before = sim.now();
        // Local fwd+bwd.
        sim.advance_by(compute);
        // Gradient synchronization.
        let full_fidelity =
            config.fidelity_every > 0 && step % config.fidelity_every == 0;
        let outcome = if full_fidelity {
            let (grads, weights) = surrogate.grads_and_weights();
            engine.sync_full(sim, grads, weights)?
        } else {
            engine.sync_predicted(sim)
        };
        // Learning progress.
        surrogate.advance(outcome.ratio, is_static);
        let acc = surrogate.accuracy();
        let vtime = sim.now();
        log.push(StepRecord {
            step,
            vtime_s: vtime.as_secs_f64(),
            compute_s: config.model.compute_time_s,
            comm_s: outcome.comm.elapsed().as_secs_f64(),
            ratio: outcome.ratio,
            payload_bytes: outcome.max_payload(),
            acc,
            loss: surrogate.loss_proxy(),
        });
        let _ = t_before;
        if vtime.as_secs_f64() >= config.max_vtime_s {
            break;
        }
    }
    Ok(log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;
    use crate::trainer::models::PAPER_MODELS;

    fn resnet() -> &'static PaperModel {
        &PAPER_MODELS[0]
    }

    fn star(n: usize, bw_mbps: f64) -> NetSim {
        NetSim::quiet(StarTopology::constant(
            n,
            mbps(bw_mbps),
            SimTime::from_millis(10),
        ))
    }

    fn quick_config(strategy: SyncStrategy, vtime: f64) -> SimTrainConfig {
        let mut c = SimTrainConfig::new(resnet(), strategy);
        c.max_vtime_s = vtime;
        c.fidelity_every = 0; // timing-only for test speed
        c
    }

    #[test]
    fn netsense_beats_baselines_at_200mbps() {
        let horizon = 300.0;
        let tp = |s: SyncStrategy| {
            let c = quick_config(s, horizon);
            let mut sim = star(8, 200.0);
            run_sim_training(&c, &mut sim).unwrap().mean_throughput()
        };
        let ns = tp(SyncStrategy::NetSense);
        let ar = tp(SyncStrategy::AllReduce);
        let tk = tp(SyncStrategy::TopK(0.1));
        // The paper's headline: 1.55–9.84× over compression-enabled
        // baselines under constrained bandwidth; check ordering + margin.
        assert!(ns > 1.5 * ar, "NetSense {ns:.1} vs AllReduce {ar:.1}");
        assert!(ns > 1.5 * tk, "NetSense {ns:.1} vs TopK {tk:.1}");
        // TopK moves less data than dense AllReduce at 200 Mbps → faster.
        assert!(tk > ar, "TopK {tk:.1} vs AllReduce {ar:.1}");
    }

    #[test]
    fn netsense_throughput_roughly_flat_across_bandwidth() {
        let tp = |bw: f64| {
            let c = quick_config(SyncStrategy::NetSense, 300.0);
            let mut sim = star(8, bw);
            run_sim_training(&c, &mut sim).unwrap().mean_throughput()
        };
        let at_200 = tp(200.0);
        let at_800 = tp(800.0);
        assert!(
            at_200 > 0.4 * at_800,
            "NetSense collapsed at low bandwidth: {at_200:.1} vs {at_800:.1}"
        );
    }

    #[test]
    fn allreduce_throughput_scales_with_bandwidth() {
        let tp = |bw: f64| {
            let c = quick_config(SyncStrategy::AllReduce, 300.0);
            let mut sim = star(8, bw);
            run_sim_training(&c, &mut sim).unwrap().mean_throughput()
        };
        assert!(tp(800.0) > 2.0 * tp(200.0));
    }

    #[test]
    fn accuracy_increases_over_run() {
        let c = quick_config(SyncStrategy::NetSense, 400.0);
        let mut sim = star(8, 500.0);
        let log = run_sim_training(&c, &mut sim).unwrap();
        assert!(log.records.len() > 100);
        let early = log.records[10].acc;
        let late = log.records.last().unwrap().acc;
        assert!(late > early + 5.0, "{early} → {late}");
    }

    #[test]
    fn spot_checks_do_not_change_timing_statistics() {
        // fidelity_every only affects numerics, not the controller or the
        // virtual clock: the final vtime and step count must agree.
        let mk = |fid: usize| {
            let mut c = quick_config(SyncStrategy::NetSense, 60.0);
            c.model = resnet();
            c.fidelity_every = fid;
            let mut sim = star(8, 200.0);
            let log = run_sim_training(&c, &mut sim).unwrap();
            (log.records.len(), log.total_vtime())
        };
        let (steps_pred, t_pred) = mk(0);
        let (steps_spot, t_spot) = mk(40);
        assert_eq!(steps_pred, steps_spot);
        let rel = (t_pred - t_spot).abs() / t_pred;
        assert!(rel < 0.02, "vtime diverged: {t_pred} vs {t_spot}");
    }

    #[test]
    fn pipelined_training_matches_monolithic_throughput_or_better() {
        use crate::coordinator::pipeline_exchange::PipelineConfig;
        let mut mono = quick_config(SyncStrategy::NetSense, 200.0);
        // Model compression cost in both runs so the comparison is fair:
        // the monolithic run is a single-bucket pipeline.
        mono.pipeline = Some(PipelineConfig {
            bucket_size_bytes: 4 * resnet().n_params as u64,
            ..Default::default()
        });
        let mut pipe = quick_config(SyncStrategy::NetSense, 200.0);
        pipe.pipeline = Some(PipelineConfig::default());
        let tp_mono = {
            let mut sim = star(8, 200.0);
            run_sim_training(&mono, &mut sim).unwrap().mean_throughput()
        };
        let tp_pipe = {
            let mut sim = star(8, 200.0);
            run_sim_training(&pipe, &mut sim).unwrap().mean_throughput()
        };
        assert!(tp_pipe > 0.0 && tp_mono > 0.0);
        assert!(
            tp_pipe >= 0.95 * tp_mono,
            "pipelined throughput {tp_pipe:.1} collapsed vs monolithic {tp_mono:.1}"
        );
    }

    #[test]
    fn respects_step_cap() {
        let mut c = quick_config(SyncStrategy::AllReduce, 1e9);
        c.max_steps = 7;
        let mut sim = star(8, 1000.0);
        let log = run_sim_training(&c, &mut sim).unwrap();
        assert_eq!(log.records.len(), 7);
    }
}
