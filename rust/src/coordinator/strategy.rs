//! Gradient-synchronization strategies (the paper's three methods).

use crate::compress::CompressionConfig;
use crate::sensing::ControllerConfig;

/// Which synchronization method a run uses.
#[derive(Clone, Debug, PartialEq)]
pub enum SyncStrategy {
    /// The paper's system: Algorithm 1 ratio control + Algorithm 2
    /// compression, sparse all-gather transport.
    NetSense,
    /// Dense NCCL-style ring all-reduce (no compression).
    AllReduce,
    /// Static Top-K at the given ratio (the paper's TopK-0.1 baseline),
    /// sparse all-gather transport, error feedback, no quantization or
    /// pruning.
    TopK(f64),
}

impl SyncStrategy {
    /// Parse a CLI name: `netsense`, `allreduce`, `topk` or `topk:<r>`.
    pub fn parse(s: &str) -> Option<SyncStrategy> {
        match s {
            "netsense" => Some(SyncStrategy::NetSense),
            "allreduce" => Some(SyncStrategy::AllReduce),
            "topk" => Some(SyncStrategy::TopK(0.1)),
            _ => s
                .strip_prefix("topk:")
                .and_then(|r| r.parse::<f64>().ok())
                .filter(|r| (0.0..=1.0).contains(r) && *r > 0.0)
                .map(SyncStrategy::TopK),
        }
    }

    /// Display name used in tables/figures.
    pub fn label(&self) -> String {
        match self {
            SyncStrategy::NetSense => "NetSenseML".to_string(),
            SyncStrategy::AllReduce => "AllReduce".to_string(),
            SyncStrategy::TopK(r) => format!("TopK-{r}"),
        }
    }

    /// Is this a *static* compression scheme (for the surrogate's
    /// instability penalty)?
    pub fn is_static_compression(&self) -> bool {
        matches!(self, SyncStrategy::TopK(_))
    }

    /// The Algorithm-2 configuration this strategy uses (None for dense).
    pub fn compression_config(&self) -> Option<CompressionConfig> {
        match self {
            SyncStrategy::NetSense => Some(CompressionConfig::default()),
            SyncStrategy::AllReduce => None,
            SyncStrategy::TopK(_) => Some(CompressionConfig {
                quant_ratio_threshold: 0.0, // never quantize
                enable_pruning: false,
                error_feedback: true,
                ..Default::default()
            }),
        }
    }

    /// The Algorithm-1 controller config (NetSense only).
    pub fn controller_config(&self) -> Option<ControllerConfig> {
        match self {
            SyncStrategy::NetSense => Some(ControllerConfig::default()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(SyncStrategy::parse("netsense"), Some(SyncStrategy::NetSense));
        assert_eq!(SyncStrategy::parse("allreduce"), Some(SyncStrategy::AllReduce));
        assert_eq!(SyncStrategy::parse("topk"), Some(SyncStrategy::TopK(0.1)));
        assert_eq!(SyncStrategy::parse("topk:0.05"), Some(SyncStrategy::TopK(0.05)));
        assert_eq!(SyncStrategy::parse("topk:0"), None);
        assert_eq!(SyncStrategy::parse("topk:2.0"), None);
        assert_eq!(SyncStrategy::parse("bogus"), None);
    }

    #[test]
    fn labels() {
        assert_eq!(SyncStrategy::NetSense.label(), "NetSenseML");
        assert_eq!(SyncStrategy::TopK(0.1).label(), "TopK-0.1");
    }

    #[test]
    fn configs_match_paper_baselines() {
        assert!(SyncStrategy::AllReduce.compression_config().is_none());
        let topk = SyncStrategy::TopK(0.1).compression_config().unwrap();
        assert!(!topk.enable_pruning);
        assert_eq!(topk.quant_ratio_threshold, 0.0);
        assert!(topk.error_feedback);
        let ns = SyncStrategy::NetSense.compression_config().unwrap();
        assert!(ns.enable_pruning);
        assert!(SyncStrategy::NetSense.controller_config().is_some());
        assert!(SyncStrategy::TopK(0.1).controller_config().is_none());
        assert!(SyncStrategy::TopK(0.1).is_static_compression());
        assert!(!SyncStrategy::NetSense.is_static_compression());
    }
}
