//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client — the only bridge between the rust
//! coordinator and the JAX/Pallas-authored compute. Python never runs here.
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Outputs arrive as a 1-tuple literal
//! (jax lowers with `return_tuple=True`).
//!
//! The PJRT bindings (the `xla` crate) are gated behind the off-by-default
//! `pjrt` cargo feature so the default build has zero external
//! dependencies. Without the feature, [`ModelRuntime::load`] returns a
//! descriptive error and everything that needs real model execution (the
//! `e2e` CLI command, `examples/e2e_train.rs`, `bench_runtime`, the
//! runtime integration tests) degrades gracefully, exactly as it already
//! does when `make artifacts` has not run.

pub mod manifest;

pub use manifest::{Manifest, ModelManifest, ParamSpec};

use crate::util::error::{bail, Context, Result};
use std::path::Path;

/// Process-wide PJRT client plus the compiled executables for one model.
#[cfg(feature = "pjrt")]
pub struct ModelRuntime {
    pub manifest: ModelManifest,
    client: xla::PjRtClient,
    grad_step: xla::PjRtLoadedExecutable,
    apply_update: xla::PjRtLoadedExecutable,
}

/// Stub runtime for builds without the `pjrt` feature: same API surface,
/// but [`ModelRuntime::load`] always fails with a pointer at the feature.
#[cfg(not(feature = "pjrt"))]
pub struct ModelRuntime {
    pub manifest: ModelManifest,
}

/// Host-side training state: flat-f32 views of every parameter tensor (in
/// manifest order) and the matching momentum buffers.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: Vec<Vec<f32>>,
    pub moms: Vec<Vec<f32>>,
}

impl TrainState {
    /// Concatenate all parameters (manifest order) — the flat view the
    /// compression pipeline consumes for magnitude pruning.
    pub fn flat_params(&self) -> Vec<f32> {
        let total: usize = self.params.iter().map(Vec::len).sum();
        let mut out = Vec::with_capacity(total);
        for p in &self.params {
            out.extend_from_slice(p);
        }
        out
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(Vec::len).sum()
    }
}

/// Outputs of one `grad_step` call.
#[derive(Clone, Debug)]
pub struct GradStepOut {
    /// Flat gradient over all parameters (manifest order).
    pub flat_grad: Vec<f32>,
    pub loss: f32,
    pub n_correct: f32,
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    /// Load and compile one model's executables from an artifact dir.
    pub fn load(artifact_dir: &Path, model: &str) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifact_dir)?;
        let mm = manifest.model(model)?.clone();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let grad_step = Self::compile(&client, &mm.grad_step_file)?;
        let apply_update = Self::compile(&client, &mm.apply_update_file)?;
        Ok(ModelRuntime {
            manifest: mm,
            client,
            grad_step,
            apply_update,
        })
    }

    fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(not(feature = "pjrt"))]
impl ModelRuntime {
    /// Without the `pjrt` feature there is nothing to execute artifacts
    /// with, so loading always fails. Callers degrade gracefully: the e2e
    /// CLI/example surface the error, `bench_runtime` skips on load
    /// failure, and the runtime integration tests skip via
    /// `cfg!(not(feature = "pjrt"))`.
    pub fn load(artifact_dir: &Path, model: &str) -> Result<ModelRuntime> {
        let _ = (artifact_dir, model);
        bail!(
            "netsenseml was built without the `pjrt` feature; \
             PJRT execution is unavailable (rebuild with `--features pjrt` \
             and an `xla` bindings crate)"
        );
    }

    pub fn platform(&self) -> String {
        "unavailable (built without `pjrt`)".to_string()
    }

    /// Stub: unreachable in practice because [`ModelRuntime::load`] is the
    /// only constructor and it always fails without the feature.
    pub fn grad_step(&self, state: &TrainState, x: &[f32], y: &[f32]) -> Result<GradStepOut> {
        let _ = (state, x, y);
        bail!("grad_step requires the `pjrt` feature");
    }

    /// Stub — see [`ModelRuntime::grad_step`].
    pub fn apply_update(&self, state: &mut TrainState, flat_grad: &[f32], lr: f32) -> Result<()> {
        let _ = (state, flat_grad, lr);
        bail!("apply_update requires the `pjrt` feature");
    }
}

impl ModelRuntime {
    /// Build the initial [`TrainState`] from `artifacts/<model>_init.bin`.
    pub fn init_state(&self) -> Result<TrainState> {
        let raw = std::fs::read(&self.manifest.init_params_file)
            .with_context(|| format!("reading {:?}", self.manifest.init_params_file))?;
        if raw.len() != self.manifest.total_params * 4 {
            bail!(
                "init params: {} bytes, expected {}",
                raw.len(),
                self.manifest.total_params * 4
            );
        }
        let flat: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(self.state_from_flat(&flat))
    }

    /// Split a flat parameter vector into per-tensor buffers (zero moms).
    pub fn state_from_flat(&self, flat: &[f32]) -> TrainState {
        assert_eq!(flat.len(), self.manifest.total_params);
        let mut params = Vec::with_capacity(self.manifest.params.len());
        let mut off = 0usize;
        for spec in &self.manifest.params {
            let n = spec.size();
            params.push(flat[off..off + n].to_vec());
            off += n;
        }
        let moms = params.iter().map(|p| vec![0f32; p.len()]).collect();
        TrainState { params, moms }
    }
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    fn literal_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let n: usize = shape.iter().product::<usize>().max(1);
        assert_eq!(data.len(), n, "literal shape/data mismatch");
        let bytes =
            unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
            .context("creating literal")
    }

    /// Run `grad_step(params, x, y)`. `x` is `batch×H×W×C` flat, `y` is
    /// `batch` labels as f32.
    pub fn grad_step(&self, state: &TrainState, x: &[f32], y: &[f32]) -> Result<GradStepOut> {
        let mm = &self.manifest;
        if x.len() != mm.x_len() {
            bail!("x length {} != {}", x.len(), mm.x_len());
        }
        if y.len() != mm.batch {
            bail!("y length {} != batch {}", y.len(), mm.batch);
        }
        let mut args: Vec<xla::Literal> = Vec::with_capacity(mm.params.len() + 2);
        for (p, spec) in state.params.iter().zip(&mm.params) {
            args.push(self.literal_f32(p, &spec.shape)?);
        }
        let mut x_shape = vec![mm.batch];
        x_shape.extend_from_slice(&mm.input_shape);
        args.push(self.literal_f32(x, &x_shape)?);
        args.push(self.literal_f32(y, &[mm.batch])?);

        let result = self.grad_step.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            bail!("grad_step returned {} outputs, expected 3", parts.len());
        }
        let flat_grad = parts[0].to_vec::<f32>()?;
        let loss = parts[1].to_vec::<f32>()?[0];
        let n_correct = parts[2].to_vec::<f32>()?[0];
        if flat_grad.len() != mm.total_params {
            bail!(
                "flat_grad length {} != total_params {}",
                flat_grad.len(),
                mm.total_params
            );
        }
        Ok(GradStepOut {
            flat_grad,
            loss,
            n_correct,
        })
    }

    /// Run `apply_update(params, moms, flat_grad, lr)` and write the new
    /// parameters/momenta back into `state`.
    pub fn apply_update(&self, state: &mut TrainState, flat_grad: &[f32], lr: f32) -> Result<()> {
        let mm = &self.manifest;
        if flat_grad.len() != mm.total_params {
            bail!("flat_grad length {} != {}", flat_grad.len(), mm.total_params);
        }
        let n = mm.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(2 * n + 2);
        for (p, spec) in state.params.iter().zip(&mm.params) {
            args.push(self.literal_f32(p, &spec.shape)?);
        }
        for (m, spec) in state.moms.iter().zip(&mm.params) {
            args.push(self.literal_f32(m, &spec.shape)?);
        }
        args.push(self.literal_f32(flat_grad, &[mm.total_params])?);
        args.push(xla::Literal::scalar(lr));

        let result = self.apply_update.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 2 * n {
            bail!(
                "apply_update returned {} outputs, expected {}",
                parts.len(),
                2 * n
            );
        }
        for (i, part) in parts.into_iter().enumerate() {
            let v = part.to_vec::<f32>()?;
            if i < n {
                state.params[i] = v;
            } else {
                state.moms[i - n] = v;
            }
        }
        Ok(())
    }
}
