//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed with the in-repo JSON substrate.

use crate::util::error::{anyhow, bail, Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One named parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Everything the runtime needs to know about one AOT-compiled model.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    pub name: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub momentum: f64,
    pub total_params: usize,
    pub params: Vec<ParamSpec>,
    pub grad_step_file: PathBuf,
    pub apply_update_file: PathBuf,
    pub init_params_file: PathBuf,
}

impl ModelManifest {
    /// Input tensor element count (batch × H × W × C).
    pub fn x_len(&self) -> usize {
        self.batch * self.input_shape.iter().product::<usize>()
    }
}

/// The whole manifest (all models).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: Vec<ModelManifest>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON; artifact paths are resolved against `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest: missing version"))?;
        if version != 1 {
            bail!("manifest version {version} unsupported (want 1)");
        }
        let models_obj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing models"))?;
        let mut models = Vec::new();
        for (name, m) in models_obj {
            let get_usize = |k: &str| {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("manifest[{name}]: missing {k}"))
            };
            let params_json = m
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest[{name}]: missing params"))?;
            let mut params = Vec::new();
            for p in params_json {
                let pname = p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest[{name}]: param missing name"))?;
                let shape = p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("manifest[{name}]: param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                    .collect::<Result<Vec<_>>>()?;
                params.push(ParamSpec {
                    name: pname.to_string(),
                    shape,
                });
            }
            let file_of = |k: &str| -> Result<PathBuf> {
                let f = m
                    .get(k)
                    .and_then(|v| v.get("file"))
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest[{name}]: missing {k}.file"))?;
                Ok(dir.join(f))
            };
            let total_params = get_usize("total_params")?;
            let declared: usize = params.iter().map(ParamSpec::size).sum();
            if declared != total_params {
                bail!(
                    "manifest[{name}]: total_params {total_params} != Σ shapes {declared}"
                );
            }
            models.push(ModelManifest {
                name: name.clone(),
                batch: get_usize("batch")?,
                input_shape: m
                    .get("input_shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("manifest[{name}]: missing input_shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad input dim")))
                    .collect::<Result<Vec<_>>>()?,
                n_classes: get_usize("n_classes")?,
                momentum: m
                    .get("momentum")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("manifest[{name}]: missing momentum"))?,
                total_params,
                params,
                grad_step_file: file_of("grad_step")?,
                apply_update_file: file_of("apply_update")?,
                init_params_file: dir.join(
                    m.get("init_params")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("manifest[{name}]: missing init_params"))?,
                ),
            });
        }
        Ok(Manifest { models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "model `{name}` not in manifest (have: {})",
                    self.models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "models": {
        "mlp": {
          "batch": 32, "input_shape": [32, 32, 3], "n_classes": 100,
          "momentum": 0.9, "init_seed": 0, "total_params": 14,
          "params": [
            {"name": "w", "shape": [3, 4]},
            {"name": "b", "shape": [2]}
          ],
          "grad_step": {"file": "mlp_grad_step.hlo.txt"},
          "apply_update": {"file": "mlp_apply_update.hlo.txt"},
          "init_params": "mlp_init.bin"
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        let mm = m.model("mlp").unwrap();
        assert_eq!(mm.batch, 32);
        assert_eq!(mm.total_params, 14);
        assert_eq!(mm.params[0].size(), 12);
        assert_eq!(mm.params[1].size(), 2);
        assert_eq!(mm.x_len(), 32 * 3072);
        assert!(mm.grad_step_file.ends_with("mlp_grad_step.hlo.txt"));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_param_sum_mismatch() {
        let bad = SAMPLE.replace("\"total_params\": 14", "\"total_params\": 99");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 2");
        assert!(Manifest::parse(&bad, Path::new("/a")).is_err());
    }

    #[test]
    fn parses_real_artifacts_if_present() {
        // Integration guard: when `make artifacts` has run, the real
        // manifest must parse and be internally consistent.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.models.is_empty());
        for mm in &m.models {
            assert!(mm.grad_step_file.exists(), "{:?}", mm.grad_step_file);
            assert!(mm.apply_update_file.exists());
            let init_len = std::fs::metadata(&mm.init_params_file).unwrap().len();
            assert_eq!(init_len as usize, mm.total_params * 4);
        }
    }
}
