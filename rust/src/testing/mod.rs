//! Test-support substrates: a proptest-style property testing harness
//! ([`prop`]) used by unit and integration tests across the crate, and a
//! counting allocator ([`alloc`]) for allocation-regression tests and
//! allocs-per-step bench reporting.

pub mod alloc;
pub mod prop;
