//! Test-support substrates: a proptest-style property testing harness
//! ([`prop`]) used by unit and integration tests across the crate, a
//! counting allocator ([`alloc`]) for allocation-regression tests and
//! allocs-per-step bench reporting, and the deterministic wire-surface
//! fuzzer ([`fuzz`]) with its committed regression corpus
//! (`rust/tests/corpus/`).

pub mod alloc;
pub mod fuzz;
pub mod prop;
