//! Test-support substrates: a proptest-style property testing harness
//! ([`prop`]) used by unit and integration tests across the crate.

pub mod prop;
