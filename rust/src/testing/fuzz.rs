//! Deterministic structured fuzzing of the wire surfaces — dependency
//! free, seed-reproducible, corpus-pinned (DESIGN.md §3.9).
//!
//! Five byte formats cross a trust boundary in this crate: the
//! length-prefixed transport frame ([`crate::transport::frame`]), the COO
//! sparse payload ([`crate::compress::sparse`]), the 9-byte elastic
//! envelope ([`crate::fault::parse_envelope`]), the versioned
//! [`Checkpoint`] blob, and the `NSOB` telemetry-gather payload
//! ([`crate::obs::collect`]). Each gets a **probe** here — a total function
//! driving one input through every decoder of that surface while
//! asserting the PR-5 corruption contract: a malformed input returns a
//! named `Err` with the accumulator/state untouched, never panics, never
//! scatters out of bounds; a valid input decodes identically on the fused
//! and staged paths. The probes are the shared oracle of three layers of
//! testing:
//!
//! - the lib fuzz tests below (structured generator → [`ByteMutator`] →
//!   probe, bounded iterations, fixed seed — `NETSENSE_FUZZ_ITERS` /
//!   `NETSENSE_FUZZ_SEED` override them, which is how `make fuzz-smoke`
//!   runs the same harness at 10k iterations),
//! - the committed regression corpus (`rust/tests/corpus/` replayed by
//!   `rust/tests/fuzz_corpus.rs` — every crasher found once is pinned to
//!   its named error forever),
//! - ad-hoc reproduction: a corpus file plus [`probe_surface`] is a
//!   one-line repro of any historical finding.
//!
//! The mutator is seeded with SplitMix64 — 64 bits of state, so a failing
//! case reproduces from nothing but the printed seed and iteration count.
//!
//! ```
//! use netsenseml::testing::fuzz::{probe_frame, ByteMutator};
//!
//! let mut frame = netsenseml::transport::frame::encode_frame(b"payload");
//! assert!(probe_frame(&frame).is_ok());
//! ByteMutator::new(2).mutate(&mut frame);
//! let _ = probe_frame(&frame); // Ok or a named Err — never a panic
//! ```

use crate::compress::{decode_reduce_into, SparseGradient};
use crate::compress::quantize::Precision;
use crate::fault::{parse_envelope, write_envelope, Checkpoint, FrameKind, ENVELOPE_OVERHEAD};
use crate::obs::{
    decode_telemetry, encode_telemetry, DecisionKind, DecisionRecord, RankTelemetry, SpanRecord,
};
use crate::transport::frame::{decode_frame_into, encode_frame, frame_payload, read_frame_into};

/// Default mutator/generator seed — override with `NETSENSE_FUZZ_SEED`.
pub const FUZZ_SEED: u64 = 0x5eed_f055;

/// The seed the fuzz harnesses run at (`NETSENSE_FUZZ_SEED` overrides the
/// built-in [`FUZZ_SEED`]; failures print it, so any run reproduces).
pub fn fuzz_seed() -> u64 {
    std::env::var("NETSENSE_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(FUZZ_SEED)
}

/// Iterations per fuzz harness: `NETSENSE_FUZZ_ITERS` if set (the
/// `fuzz-smoke` target runs 10_000), else `default` (kept small enough
/// for tier-1 `cargo test`).
pub fn fuzz_iters(default: usize) -> usize {
    std::env::var("NETSENSE_FUZZ_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// SplitMix64: the 64-bit state PRNG the fuzzer is seeded with. Distinct
/// from the crate's simulation RNG ([`crate::util::rng::Pcg64`]) on
/// purpose — one u64 of state means a finding replays from the seed
/// alone, and stepping the generator can never perturb simulation
/// streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)` (multiply-shift; bias is irrelevant at fuzzing
    /// sample sizes).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        (((self.next() as u128) * (n as u128)) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < p
    }
}

/// The byte-level mutation engine: each [`ByteMutator::mutate`] applies
/// one to three of five mutation classes, chosen and parameterized by the
/// SplitMix64 stream — bit flips (single-bit corruption), truncation
/// (torn writes), length-field lies (a plausible-looking header word
/// rewritten, targeting the u32 length/count fields every surface leads
/// with), splice (one region copied over another — crossed frames on a
/// desynchronized stream), and repeat-section (a slice duplicated
/// in place — replayed or duplicated fragments).
pub struct ByteMutator {
    rng: SplitMix64,
}

impl ByteMutator {
    pub fn new(seed: u64) -> ByteMutator {
        ByteMutator {
            rng: SplitMix64::new(seed),
        }
    }

    /// Mutate `buf` in place (1–3 rounds). Empty buffers stay empty under
    /// shrinking mutations but can grow back via repeat-section's cousin
    /// (a length-lie on an empty buffer is a no-op; callers fuzz decoders
    /// with the empty input anyway since truncation reaches it).
    pub fn mutate(&mut self, buf: &mut Vec<u8>) {
        let rounds = 1 + self.rng.index(3);
        for _ in 0..rounds {
            match self.rng.index(5) {
                // Bit flip.
                0 => {
                    if !buf.is_empty() {
                        let at = self.rng.index(buf.len());
                        buf[at] ^= 1 << self.rng.index(8);
                    }
                }
                // Truncation (possibly to empty).
                1 => {
                    if !buf.is_empty() {
                        let keep = self.rng.index(buf.len());
                        buf.truncate(keep);
                    }
                }
                // Length-field lie: rewrite one u32-aligned word among the
                // first 16 bytes — where every wire surface keeps its
                // magic / length / count fields.
                2 => {
                    let words = (buf.len() / 4).min(4);
                    if words > 0 {
                        let at = 4 * self.rng.index(words);
                        let lie = match self.rng.index(4) {
                            0 => u32::MAX,               // absurd
                            1 => (1u32 << 30) + 1,       // just over the frame cap
                            2 => self.rng.next() as u32, // arbitrary
                            _ => {
                                // Off-by-a-little: the hardest class to
                                // catch with pure randomness.
                                let cur = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
                                cur.wrapping_add(self.rng.below(9) as u32).wrapping_sub(4)
                            }
                        };
                        buf[at..at + 4].copy_from_slice(&lie.to_le_bytes());
                    }
                }
                // Splice: copy one region over another (overwrite).
                3 => {
                    if buf.len() >= 2 {
                        let n = 1 + self.rng.index(buf.len().min(16));
                        let src = self.rng.index(buf.len() - n + 1);
                        let dst = self.rng.index(buf.len() - n + 1);
                        buf.copy_within(src..src + n, dst);
                    }
                }
                // Repeat-section: duplicate a slice, growing the buffer
                // (bounded so a mutation chain cannot balloon).
                _ => {
                    if !buf.is_empty() && buf.len() <= 1 << 16 {
                        let n = 1 + self.rng.index(buf.len().min(16));
                        let start = self.rng.index(buf.len() - n + 1);
                        let at = self.rng.index(buf.len() + 1);
                        let section: Vec<u8> = buf[start..start + n].to_vec();
                        buf.splice(at..at, section);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Surface probes: total functions with the corruption contract asserted
// inside. The returned Result is the decoder's verdict (the corpus pins
// its Err strings); the asserts are the fuzz oracle.
// ---------------------------------------------------------------------------

/// Drive one input through the **frame** surface: the borrowing validator
/// ([`frame_payload`]), the buffer-reusing decoder ([`decode_frame_into`]
/// — must agree with the validator and must leave its output untouched on
/// error), and the streaming reader ([`read_frame_into`] — any verdict is
/// legal on a byte blob, but it must neither panic nor reserve
/// unboundedly). Panics if any contract is violated.
pub fn probe_frame(bytes: &[u8]) -> Result<(), String> {
    let staged: Result<Vec<u8>, String> =
        frame_payload(bytes).map(|p| p.to_vec()).map_err(|e| e.to_string());
    let sentinel = vec![0xa5u8; 7];
    let mut out = sentinel.clone();
    match decode_frame_into(bytes, &mut out) {
        Ok(()) => {
            let p = staged.as_ref().expect("decode_frame_into accepted what frame_payload rejected");
            assert_eq!(&out, p, "decode_frame_into != frame_payload");
        }
        Err(e) => {
            assert!(staged.is_err(), "decode_frame_into rejected what frame_payload accepted");
            assert_eq!(out, sentinel, "frame error path clobbered the out buffer: {e}");
        }
    }
    // The same bytes as a stream: a short or lying stream must error (or
    // yield a prefix frame), never panic, and a length lie must not turn
    // into a huge up-front reservation (the chunked-read contract).
    let mut cursor = std::io::Cursor::new(bytes);
    let mut payload = Vec::new();
    let _ = read_frame_into(&mut cursor, &mut payload);
    // Chunked-growth bound: delivered bytes plus one 1 MiB read chunk,
    // doubled for Vec's amortized growth — a length lie must never reserve
    // anywhere near its declared size.
    assert!(
        payload.capacity() <= 2 * (bytes.len() + (1 << 20)),
        "read_frame_into reserved {} bytes for a {}-byte stream",
        payload.capacity(),
        bytes.len()
    );
    staged.map(|_| ())
}

/// Drive one input through the **COO** surface: the fused decode-reduce
/// ([`decode_reduce_into`], against a sentinel accumulator sized from the
/// declared `n_total`, capped) differentially checked against the staged
/// decode + scatter ([`SparseGradient::decode`] + `add_into`). On `Err`
/// the accumulator must be bit-untouched (no partial scatter); on `Ok`
/// both paths must produce bit-identical sums. Panics if violated.
pub fn probe_coo(bytes: &[u8]) -> Result<(), String> {
    // The accumulator a receiver would hold: the declared dense length
    // (capped so a lying header cannot make the *harness* allocate big —
    // past the cap the mismatch is itself a named error, which is the
    // contract under test).
    let n = if bytes.len() >= 4 {
        (u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize).min(4096)
    } else {
        16
    };
    let sentinel: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
    let mut fused = sentinel.clone();
    let verdict = decode_reduce_into(bytes, &mut fused);
    let staged = SparseGradient::decode(bytes); // must be total too
    match &verdict {
        Ok(out) => {
            let s = staged
                .as_ref()
                .expect("fused decode-reduce accepted what staged decode rejected");
            assert_eq!(s.nnz(), out.nnz, "fused/staged nnz diverged");
            let mut acc = sentinel.clone();
            s.add_into(&mut acc);
            assert!(
                acc.iter().zip(&fused).all(|(a, b)| a.to_bits() == b.to_bits()),
                "fused scatter diverged from staged decode + add_into"
            );
        }
        Err(e) => {
            assert!(
                fused.iter().zip(&sentinel).all(|(a, b)| a.to_bits() == b.to_bits()),
                "COO error `{e}` left a partial scatter in the accumulator"
            );
        }
    }
    verdict.map(|_| ())
}

/// Drive one input through the **envelope** surface
/// ([`parse_envelope`]): an accepted envelope must slice exactly as the
/// manual layout says and re-encode byte-identically via
/// [`write_envelope`]; a rejected one must name the defect. Panics if
/// violated.
pub fn probe_envelope(bytes: &[u8]) -> Result<(), String> {
    match parse_envelope(bytes) {
        Ok((kind, epoch, step, body)) => {
            assert!(bytes.len() >= ENVELOPE_OVERHEAD);
            assert_eq!(body.len(), bytes.len() - ENVELOPE_OVERHEAD);
            assert_eq!(epoch, u32::from_le_bytes(bytes[1..5].try_into().unwrap()));
            assert_eq!(step, u32::from_le_bytes(bytes[5..9].try_into().unwrap()));
            let mut re = Vec::with_capacity(bytes.len());
            write_envelope(kind, epoch, step, &mut re);
            re.extend_from_slice(body);
            assert_eq!(re, bytes, "envelope re-encode diverged");
            Ok(())
        }
        Err(e) => {
            let msg = e.to_string();
            assert!(!msg.is_empty(), "envelope rejection must be named");
            Err(msg)
        }
    }
}

/// Drive one input through the **checkpoint** surface
/// ([`Checkpoint::decode`]): an accepted blob must re-encode to a
/// canonical form that decodes back to the same checkpoint (flag-off
/// slots zero; byte-stable thereafter); a rejected one names the defect.
/// Panics if violated.
pub fn probe_checkpoint(bytes: &[u8]) -> Result<(), String> {
    match Checkpoint::decode(bytes) {
        Ok(ck) => {
            let canon = ck.encode();
            let again = Checkpoint::decode(&canon)
                .expect("canonical re-encode of an accepted checkpoint must decode");
            // Bit-level comparison (re-encode) rather than PartialEq:
            // mutated-but-accepted blobs may carry NaN residuals, which
            // compare unequal to themselves.
            assert_eq!(again.encode(), canon, "checkpoint decode∘encode not canonical");
            Ok(())
        }
        Err(e) => Err(e.to_string()),
    }
}

/// Drive one input through the **OBS telemetry** surface
/// ([`decode_telemetry`]): an accepted payload must re-encode to a
/// canonical form (unused label-table entries dropped, span ranks
/// normalized to the header rank) that decodes back byte-stably; a
/// rejected one names the defect. Panics if violated.
pub fn probe_obs(bytes: &[u8]) -> Result<(), String> {
    match decode_telemetry(bytes) {
        Ok(t) => {
            let canon = encode_telemetry(&t);
            // Bit-level comparison (re-encode) rather than PartialEq:
            // mutated-but-accepted payloads may carry NaN ratios, which
            // compare unequal to themselves.
            let again = decode_telemetry(&canon)
                .expect("canonical re-encode of accepted telemetry must decode");
            assert_eq!(encode_telemetry(&again), canon, "OBS decode∘encode not canonical");
            Ok(())
        }
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(!msg.is_empty(), "OBS rejection must be named");
            Err(msg)
        }
    }
}

/// Dispatch a corpus entry to its surface probe (`None` for an unknown
/// surface tag) — the replay seam `rust/tests/fuzz_corpus.rs` shares with
/// ad-hoc reproduction.
pub fn probe_surface(surface: &str, bytes: &[u8]) -> Option<Result<(), String>> {
    match surface {
        "frame" => Some(probe_frame(bytes)),
        // Both COO codecs (raw 0, lossless 1) go through the same probe —
        // the codec byte is part of the payload under test.
        "coo" | "coo-lossless" => Some(probe_coo(bytes)),
        "envelope" => Some(probe_envelope(bytes)),
        "checkpoint" => Some(probe_checkpoint(bytes)),
        "obs" => Some(probe_obs(bytes)),
        _ => None,
    }
}

// ---------------------------------------------------------------------------
// Structured generators: a *valid* instance per surface, driven by the
// same SplitMix64 stream. Mutating a valid encoding reaches the deep
// validation paths (index ordering, residual lengths, trailing-byte
// checks) that random bytes never get past the magic word to see.
// ---------------------------------------------------------------------------

/// A valid transport frame with a random payload (up to ~300 bytes).
pub fn gen_frame(rng: &mut SplitMix64) -> Vec<u8> {
    let n = rng.index(300);
    let payload: Vec<u8> = (0..n).map(|_| rng.next() as u8).collect();
    encode_frame(&payload)
}

/// A valid COO payload: `n_total ≤ 512` (within [`probe_coo`]'s
/// accumulator cap), sorted distinct indices, finite values, random
/// precision.
pub fn gen_coo(rng: &mut SplitMix64) -> Vec<u8> {
    let n_total = 1 + rng.index(512);
    let nnz = rng.index(n_total.min(64) + 1);
    // Selection sampling: nnz distinct sorted indices in [0, n_total).
    let mut indices = Vec::with_capacity(nnz);
    for i in 0..n_total {
        let left = (n_total - i) as u64;
        let need = (nnz - indices.len()) as u64;
        if need > 0 && rng.below(left) < need {
            indices.push(i as u32);
        }
    }
    let precision = [Precision::F32, Precision::F16, Precision::Bf16][rng.index(3)];
    let values: Vec<f32> = (0..nnz).map(|_| (rng.next() as i32 as f32) * 1e-6).collect();
    let s = SparseGradient {
        n_total,
        indices,
        values,
        precision,
    };
    s.encode()
}

/// A valid **lossless-codec** COO payload (codec byte 1: delta-encoded
/// byte planes + ZRLE): same structural space as [`gen_coo`], emitted
/// through the fused lossless encoder. Mutations of these reach the
/// plane-length, token-stream, and index-reconstruction validators that
/// raw-codec inputs never touch.
pub fn gen_coo_lossless(rng: &mut SplitMix64) -> Vec<u8> {
    use crate::compress::lossless::encode_gathered_lossless_into;
    let n_total = 1 + rng.index(512);
    let nnz = rng.index(n_total.min(64) + 1);
    let mut indices = Vec::with_capacity(nnz);
    for i in 0..n_total {
        let left = (n_total - i) as u64;
        let need = (nnz - indices.len()) as u64;
        if need > 0 && rng.below(left) < need {
            indices.push(i as u32);
        }
    }
    let precision = [Precision::F32, Precision::F16, Precision::Bf16][rng.index(3)];
    let mut dense = vec![0f32; n_total];
    for &i in &indices {
        dense[i as usize] = (rng.next() as i32 as f32) * 1e-6;
    }
    let (mut val_bits, mut out) = (Vec::new(), Vec::new());
    encode_gathered_lossless_into(&dense, &indices, precision, &mut val_bits, &mut out);
    out
}

/// A valid elastic envelope (random kind/epoch/step) plus a random body.
pub fn gen_envelope(rng: &mut SplitMix64) -> Vec<u8> {
    let kind = if rng.chance(0.5) { FrameKind::Data } else { FrameKind::Probe };
    let mut out = Vec::new();
    write_envelope(kind, rng.next() as u32, rng.next() as u32, &mut out);
    let n = rng.index(32);
    out.extend((0..n).map(|_| rng.next() as u8));
    out
}

/// A valid checkpoint blob: 1–3 compressor states with random residual
/// lengths, optional cache fields present at random.
pub fn gen_checkpoint(rng: &mut SplitMix64) -> Vec<u8> {
    use crate::compress::CompressorState;
    let n_states = 1 + rng.index(3);
    let states: Vec<CompressorState> = (0..n_states)
        .map(|_| {
            let n = rng.index(48);
            CompressorState {
                residual: (0..n).map(|_| (rng.next() as i32 as f32) * 1e-6).collect(),
                last_threshold: rng.chance(0.5).then(|| (rng.next() as i32 as f32) * 1e-6),
                prune_cache: rng
                    .chance(0.5)
                    .then(|| ((rng.next() as i32 as f64) * 1e-6, (rng.next() as i32 as f32) * 1e-6)),
                prune_cache_age: rng.next() as u32,
                last_grad_l2: rng.chance(0.5).then(|| (rng.next() as i32 as f64) * 1e-6),
            }
        })
        .collect();
    Checkpoint::new(rng.next(), rng.next(), states).encode()
}

/// A valid OBS telemetry payload: random header counters, 0–12 spans over
/// the well-known label set (mutations reach the unknown-label and
/// interning paths; generating unknown labels here would instead leak
/// into the process-global intern table), 0–8 journal records across all
/// five kinds.
pub fn gen_obs(rng: &mut SplitMix64) -> Vec<u8> {
    const LABELS: &[&str] = &["step", "compress", "round", "decode", "recovery"];
    const KINDS: &[DecisionKind] = &[
        DecisionKind::Ratio,
        DecisionKind::Round,
        DecisionKind::Membership,
        DecisionKind::Straggler,
        DecisionKind::Congestion,
    ];
    let rank = rng.index(64);
    let spans: Vec<SpanRecord> = (0..rng.index(13))
        .map(|i| {
            let start_ns = rng.below(1 << 40);
            SpanRecord {
                rank,
                id: i as u64 + 1,
                parent: rng.below(i as u64 + 1),
                label: LABELS[rng.index(LABELS.len())],
                step: rng.next() as u32,
                start_ns,
                end_ns: start_ns + rng.below(1 << 30),
            }
        })
        .collect();
    let journal: Vec<DecisionRecord> = (0..rng.index(9))
        .map(|_| DecisionRecord {
            kind: KINDS[rng.index(KINDS.len())],
            rank,
            step: rng.next() as u32,
            epoch: rng.next() as u32,
            live: rng.index(64),
            rtt_us: rng.below(1 << 30),
            payload_bytes: rng.below(1 << 30),
            lost: rng.chance(0.3),
            phase_netsense: rng.chance(0.5),
            old_ratio: (rng.next() as i32 as f64) * 1e-9,
            new_ratio: (rng.next() as i32 as f64) * 1e-9,
            predicted_wire_bytes: rng.below(1 << 30),
            recoveries: rng.next() as u32,
            dropped_stale: rng.next() as u32,
            dropped_garbage: rng.next() as u32,
        })
        .collect();
    encode_telemetry(&RankTelemetry {
        rank,
        clock_ns: rng.next(),
        spans,
        spans_dropped: rng.below(1 << 20),
        journal,
        journal_dropped: rng.below(1 << 20),
        final_ratio: (rng.next() as i32 as f64) * 1e-9,
        recoveries: rng.next() as u32,
        lost_intervals: rng.next() as u32,
        decreases: rng.next() as u32,
        increases: rng.next() as u32,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Generator → pristine probe (must accept) → mutate → probe (must be
    /// total: Ok or a named Err, contract asserts inside). One harness per
    /// surface; `NETSENSE_FUZZ_ITERS` scales it to smoke depth.
    fn fuzz_surface(
        name: &str,
        gen: fn(&mut SplitMix64) -> Vec<u8>,
        probe: fn(&[u8]) -> Result<(), String>,
    ) {
        let iters = fuzz_iters(400);
        let seed = fuzz_seed();
        let mut rng = SplitMix64::new(seed);
        let mut mutator = ByteMutator::new(seed ^ 0x6d75_7461); // "muta"
        let mut rejected = 0usize;
        for i in 0..iters {
            let mut buf = gen(&mut rng);
            if let Err(e) = probe(&buf) {
                panic!("{name}: pristine input rejected at iter {i} (seed {seed:#x}): {e}");
            }
            mutator.mutate(&mut buf);
            match probe(&buf) {
                Ok(()) => {}
                Err(e) => {
                    assert!(!e.is_empty(), "{name}: unnamed rejection at iter {i} (seed {seed:#x})");
                    rejected += 1;
                }
            }
        }
        assert!(
            rejected > 0,
            "{name}: {iters} mutations never produced a rejected input (seed {seed:#x})"
        );
    }

    #[test]
    fn fuzz_frame_surface() {
        fuzz_surface("frame", gen_frame, probe_frame);
    }

    #[test]
    fn fuzz_coo_surface() {
        fuzz_surface("coo", gen_coo, probe_coo);
    }

    #[test]
    fn fuzz_coo_lossless_surface() {
        fuzz_surface("coo-lossless", gen_coo_lossless, probe_coo);
    }

    #[test]
    fn fuzz_envelope_surface() {
        fuzz_surface("envelope", gen_envelope, probe_envelope);
    }

    #[test]
    fn fuzz_checkpoint_surface() {
        fuzz_surface("checkpoint", gen_checkpoint, probe_checkpoint);
    }

    #[test]
    fn fuzz_obs_surface() {
        fuzz_surface("obs", gen_obs, probe_obs);
    }

    /// Hostile raw bytes (no valid prefix at all) — the probes must stay
    /// total from byte zero, including the empty input.
    #[test]
    fn fuzz_raw_bytes_never_panic() {
        let mut rng = SplitMix64::new(fuzz_seed() ^ 0x7261_77);
        for len in [0usize, 1, 3, 8, 9, 11, 12, 13, 29, 64, 257] {
            for _ in 0..32 {
                let buf: Vec<u8> = (0..len).map(|_| rng.next() as u8).collect();
                let _ = probe_frame(&buf);
                let _ = probe_coo(&buf);
                let _ = probe_envelope(&buf);
                let _ = probe_checkpoint(&buf);
                let _ = probe_obs(&buf);
            }
        }
    }

    #[test]
    fn fuzz_mutator_is_seed_deterministic() {
        let base = gen_frame(&mut SplitMix64::new(1));
        let (mut a, mut b, mut c) = (base.clone(), base.clone(), base);
        let mut ma = ByteMutator::new(7);
        let mut mb = ByteMutator::new(7);
        let mut mc = ByteMutator::new(8);
        let mut other_seed_diverged = false;
        for _ in 0..200 {
            ma.mutate(&mut a);
            mb.mutate(&mut b);
            mc.mutate(&mut c);
            assert_eq!(a, b, "same seed must produce the same mutation stream");
            other_seed_diverged |= c != a;
        }
        assert!(other_seed_diverged, "a different seed never diverged");
    }

    #[test]
    fn fuzz_generators_emit_valid_instances() {
        let mut rng = SplitMix64::new(fuzz_seed() ^ 0x67_656e);
        for _ in 0..50 {
            probe_frame(&gen_frame(&mut rng)).expect("gen_frame invalid");
            probe_coo(&gen_coo(&mut rng)).expect("gen_coo invalid");
            probe_coo(&gen_coo_lossless(&mut rng)).expect("gen_coo_lossless invalid");
            probe_envelope(&gen_envelope(&mut rng)).expect("gen_envelope invalid");
            probe_checkpoint(&gen_checkpoint(&mut rng)).expect("gen_checkpoint invalid");
            probe_obs(&gen_obs(&mut rng)).expect("gen_obs invalid");
        }
    }

    #[test]
    fn fuzz_probe_surface_dispatches_and_rejects_unknown() {
        let mut rng = SplitMix64::new(3);
        assert!(probe_surface("frame", &gen_frame(&mut rng)).unwrap().is_ok());
        assert!(probe_surface("coo", &gen_coo(&mut rng)).unwrap().is_ok());
        assert!(probe_surface("envelope", &gen_envelope(&mut rng)).unwrap().is_ok());
        assert!(probe_surface("checkpoint", &gen_checkpoint(&mut rng)).unwrap().is_ok());
        assert!(probe_surface("obs", &gen_obs(&mut rng)).unwrap().is_ok());
        assert!(probe_surface("unknown-surface", b"").is_none());
    }
}
