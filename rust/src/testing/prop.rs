//! Property-based testing harness (proptest replacement for the offline
//! build): seeded generators, a `forall` runner that reports the failing
//! seed, and greedy input shrinking for `Vec`-shaped inputs.
//!
//! ```
//! use netsenseml::testing::prop::*;
//! forall("reverse twice is identity", 100, vec_f32(0..500, -1e3..1e3), |v| {
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     w == *v
//! });
//! ```

use crate::util::rng::Pcg64;
use std::ops::Range;

/// A generator of values of type `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;
    /// Candidate smaller versions of a failing input (greedy shrink step).
    fn shrink(&self, value: &T) -> Vec<T> {
        let _ = value;
        Vec::new()
    }
}

/// Run `prop` on `cases` random inputs from `gen`. On failure, shrink the
/// input greedily and panic with the seed, case index, and minimized input
/// (via `Debug`).
pub fn forall<T: std::fmt::Debug + Clone, G: Gen<T>, P: Fn(&T) -> bool>(
    name: &str,
    cases: usize,
    gen: G,
    prop: P,
) {
    // Env-overridable base seed so failures can be replayed exactly.
    let base_seed = std::env::var("NETSENSE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5eed_0001);
    for case in 0..cases {
        let mut rng = Pcg64::new(base_seed, case as u64);
        let input = gen.generate(&mut rng);
        if !prop(&input) {
            let minimized = shrink_loop(&gen, input.clone(), &prop);
            panic!(
                "property `{name}` failed (seed={base_seed}, case={case})\n  original: {input:?}\n  minimized: {minimized:?}"
            );
        }
    }
}

fn shrink_loop<T: Clone, G: Gen<T>, P: Fn(&T) -> bool>(gen: &G, mut failing: T, prop: &P) -> T {
    // Greedy descent: take the first shrink candidate that still fails.
    'outer: for _ in 0..1000 {
        for cand in gen.shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

// ---------------------------------------------------------------- basic gens

/// Uniform usize in a range.
pub struct UsizeGen(pub Range<usize>);

impl Gen<usize> for UsizeGen {
    fn generate(&self, rng: &mut Pcg64) -> usize {
        self.0.start + rng.index((self.0.end - self.0.start).max(1))
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0.start {
            out.push(self.0.start);
            out.push(self.0.start + (v - self.0.start) / 2);
        }
        out.dedup();
        out
    }
}

pub fn usize_in(r: Range<usize>) -> UsizeGen {
    UsizeGen(r)
}

/// Uniform f64 in a range.
pub struct F64Gen(pub Range<f64>);

impl Gen<f64> for F64Gen {
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.range_f64(self.0.start, self.0.end)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mid = (self.0.start + self.0.end) / 2.0;
        if (*v - mid).abs() > 1e-9 {
            vec![mid, (*v + mid) / 2.0]
        } else {
            vec![]
        }
    }
}

pub fn f64_in(r: Range<f64>) -> F64Gen {
    F64Gen(r)
}

/// Vec of f32 with length sampled from `len` and values from `vals`.
/// Occasionally injects special values (0, ±max, duplicates) to probe edges.
pub struct VecF32Gen {
    pub len: Range<usize>,
    pub vals: Range<f32>,
}

impl Gen<Vec<f32>> for VecF32Gen {
    fn generate(&self, rng: &mut Pcg64) -> Vec<f32> {
        let n = self.len.start + rng.index((self.len.end - self.len.start).max(1));
        let mut v: Vec<f32> = (0..n)
            .map(|_| self.vals.start + rng.f32() * (self.vals.end - self.vals.start))
            .collect();
        // edge-value injection
        if n > 0 && rng.chance(0.3) {
            let i = rng.index(n);
            v[i] = 0.0;
        }
        if n > 1 && rng.chance(0.3) {
            let i = rng.index(n);
            let j = rng.index(n);
            v[i] = v[j]; // force a duplicate magnitude
        }
        if n > 0 && rng.chance(0.2) {
            let i = rng.index(n);
            v[i] = self.vals.end;
        }
        v
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        let n = v.len();
        if n > self.len.start {
            // halve
            out.push(v[..(self.len.start.max(n / 2))].to_vec());
            // drop first/last element
            if n >= 1 + self.len.start {
                out.push(v[1..].to_vec());
                out.push(v[..n - 1].to_vec());
            }
        }
        // zero out values
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|_| 0.0).collect());
        }
        out
    }
}

pub fn vec_f32(len: Range<usize>, vals: Range<f32>) -> VecF32Gen {
    VecF32Gen { len, vals }
}

/// Pair generator.
pub struct PairGen<A, B>(pub A, pub B);

impl<T1: Clone, T2: Clone, A: Gen<T1>, B: Gen<T2>> Gen<(T1, T2)> for PairGen<A, B> {
    fn generate(&self, rng: &mut Pcg64) -> (T1, T2) {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &(T1, T2)) -> Vec<(T1, T2)> {
        let mut out: Vec<(T1, T2)> = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

pub fn pair<T1, T2, A: Gen<T1>, B: Gen<T2>>(a: A, b: B) -> PairGen<A, B> {
    PairGen(a, b)
}

/// Map a generator through a function (no shrinking through the map).
pub struct MapGen<T, G, F> {
    inner: G,
    f: F,
    _marker: std::marker::PhantomData<fn() -> T>,
}

impl<T, U, G: Gen<T>, F: Fn(T) -> U> Gen<U> for MapGen<T, G, F> {
    fn generate(&self, rng: &mut Pcg64) -> U {
        (self.f)(self.inner.generate(rng))
    }
}

pub fn map<T, U, G: Gen<T>, F: Fn(T) -> U>(g: G, f: F) -> MapGen<T, G, F> {
    MapGen {
        inner: g,
        f,
        _marker: std::marker::PhantomData,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("reverse twice is identity", 50, vec_f32(0..64, -10.0..10.0), |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property `always false` failed")]
    fn failing_property_panics_with_seed() {
        forall("always false", 5, usize_in(0..10), |_| false);
    }

    #[test]
    fn shrinking_minimizes_vec() {
        // Property: no element equals the max bound. The shrinker should
        // find a small counterexample; we just verify the panic message
        // contains "minimized".
        let result = std::panic::catch_unwind(|| {
            forall("no max", 100, vec_f32(0..50, 0.0..4.0), |v| {
                !v.iter().any(|&x| x >= 4.0)
            });
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("minimized"), "got: {msg}");
    }

    #[test]
    fn pair_gen_generates_both() {
        forall(
            "pair ranges",
            50,
            pair(usize_in(1..10), f64_in(0.5..2.0)),
            |&(n, x)| (1..10).contains(&n) && (0.5..2.0).contains(&x),
        );
    }

    #[test]
    fn usize_gen_respects_range() {
        forall("usize range", 200, usize_in(3..17), |&n| (3..17).contains(&n));
    }

    #[test]
    fn map_gen_applies() {
        forall("map doubles", 50, map(usize_in(0..10), |n| n * 2), |&n| n % 2 == 0);
    }
}
