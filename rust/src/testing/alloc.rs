//! Per-thread allocation counting for allocation-regression tests.
//!
//! [`CountingAlloc`] wraps the system allocator and bumps a thread-local
//! counter on every `alloc`/`alloc_zeroed`/`realloc` (frees are not
//! counted — the hot-path contract is about *acquiring* memory). The
//! counter is thread-local, so a test reads only its own allocations even
//! when the harness runs tests concurrently.
//!
//! The lib test harness installs it as the global allocator (see the
//! `cfg(test)` item below); benches that want allocs-per-step numbers
//! install it themselves:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: netsenseml::testing::alloc::CountingAlloc = CountingAlloc;
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // const-initialized: reading it never allocates, so the allocator
    // cannot recurse into itself, and `Cell<u64>` registers no TLS
    // destructor.
    static ALLOC_COUNT: Cell<u64> = const { Cell::new(0) };
}

/// System-allocator wrapper that counts allocation calls per thread.
pub struct CountingAlloc;

fn bump() {
    let _ = ALLOC_COUNT.try_with(|c| c.set(c.get() + 1));
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation count on the calling thread since it started (monotone;
/// meaningful only when [`CountingAlloc`] is the global allocator —
/// otherwise it stays 0).
pub fn thread_alloc_count() -> u64 {
    ALLOC_COUNT.try_with(|c| c.get()).unwrap_or(0)
}

// The lib's own test binary runs with the counting allocator so the
// zero-alloc hot-path regression tests can assert; every other build
// (release lib, binaries, benches, integration tests) keeps the plain
// system allocator unless it opts in.
#[cfg(test)]
#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_threads_allocations() {
        let before = thread_alloc_count();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_alloc_count();
        assert!(after > before, "Vec::with_capacity must register");
        drop(v);
        // A no-op loop registers nothing.
        let before = thread_alloc_count();
        let mut acc = 0u64;
        for i in 0..100u64 {
            acc = acc.wrapping_add(i);
        }
        std::hint::black_box(acc);
        assert_eq!(thread_alloc_count(), before);
    }
}
