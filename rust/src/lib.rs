//! # NetSenseML
//!
//! Reproduction of *NetSenseML: Network-Adaptive Compression for Efficient
//! Distributed Machine Learning* (Wang et al., CS.DC 2025) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the distributed-training coordinator: network
//!   sensing ([`sensing`]), adaptive compression ([`compress`]), collectives
//!   ([`collectives`]) over an event-driven network simulator ([`netsim`]),
//!   and the DDP training loop ([`coordinator`]).
//! - **L2** — JAX model (`python/compile/model.py`) AOT-lowered to HLO text.
//! - **L1** — Pallas kernels (`python/compile/kernels/`) inside the L2 graph.
//!
//! The rust binary loads `artifacts/*.hlo.txt` via the PJRT C API
//! ([`runtime`], behind the `pjrt` feature) and never calls Python at run
//! time.
//!
//! ## Dual-backend transport
//!
//! Every byte the coordinator moves goes through the [`transport`] seam:
//! simulated runs drive [`transport::GroupTransport`] over [`netsim`],
//! while `netsenseml live` trains over *real* sockets — rank-level
//! [`transport::Transport`] endpoints (in-process loopback or a TCP mesh
//! with rank-0 rendezvous), length-prefixed frames, real ring collectives
//! ([`transport::collective`]), optional token-bucket shaping
//! ([`transport::ShapedTransport`]) — with the Algorithm-1 controller fed
//! by *measured* RTTs ([`experiments::live`]).
//!
//! ## The gradient hot path
//!
//! Gradients travel as **fused buckets through a pipelined exchange**: the
//! flat gradient is cut into fixed-size buckets
//! ([`compress::bucket::BucketLayout`]), each bucket runs Algorithm 2 with
//! its own error-feedback residual
//! ([`compress::bucket::BucketedCompressor`]), transport stages are
//! coalesced to the sensed BDP
//! ([`sensing::RatioController::recommended_bucket_bytes`]), and the
//! coordinator compresses bucket *k+1* while bucket *k* is in flight on
//! the simulated link ([`coordinator::pipeline_exchange`], riding the
//! barrier-free [`collectives::StagedAllGather`]). The monolithic
//! compress-then-send path remains as the baseline (and the default when
//! no `[pipeline]` config is given).
//!
//! ## Fault tolerance
//!
//! The worker group is *elastic* ([`fault`]): an epoch-numbered
//! [`fault::Membership`] view per rank, deadline-aware transports, a
//! degraded collective that rebuilds the ring over survivors and replays
//! the interrupted round ([`fault::ElasticExchange`]), deterministic
//! chaos injection ([`fault::FaultInjector`]) mirrored on the simulator
//! ([`fault::sim_trajectory`]), and compressor-state checkpoints
//! ([`fault::Checkpoint`]) so a rejoining rank resumes bit-identically.
//!
//! ## Observability
//!
//! Runtime telemetry lives in [`obs`]: a lock-free metrics registry with a
//! Prometheus-text exporter ([`obs::metrics`]), per-rank tracing spans
//! exportable as Perfetto-loadable Chrome trace JSON ([`obs::trace`]), and
//! a controller decision journal cross-checkable against netsim replays
//! ([`obs::journal`]) — all recording allocation-free on the fused hot
//! paths (the counting-allocator gates run with telemetry on).
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the module-by-module
//! system inventory, `EXPERIMENTS.md` for the experiment ↔ paper-figure
//! index, and `ROADMAP.md` for open items.

pub mod collectives;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod fault;
pub mod netsim;
pub mod obs;
pub mod runtime;
pub mod sensing;
pub mod testing;
pub mod trainer;
pub mod transport;
pub mod util;
