//! # NetSenseML
//!
//! Reproduction of *NetSenseML: Network-Adaptive Compression for Efficient
//! Distributed Machine Learning* (Wang et al., CS.DC 2025) as a three-layer
//! rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the distributed-training coordinator: network
//!   sensing ([`sensing`]), adaptive compression ([`compress`]), collectives
//!   ([`collectives`]) over an event-driven network simulator ([`netsim`]),
//!   and the DDP training loop ([`coordinator`]).
//! - **L2** — JAX model (`python/compile/model.py`) AOT-lowered to HLO text.
//! - **L1** — Pallas kernels (`python/compile/kernels/`) inside the L2 graph.
//!
//! The rust binary loads `artifacts/*.hlo.txt` via the PJRT C API
//! ([`runtime`]) and never calls Python at run time.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for the paper-vs-measured results.

pub mod collectives;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod netsim;
pub mod runtime;
pub mod sensing;
pub mod testing;
pub mod trainer;
pub mod util;
