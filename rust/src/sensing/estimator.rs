//! EBB / BtlBw / RTprop / BDP estimation (paper Eq. (1)–(2), Fig. 2).
//!
//! Per gradient-transmission interval `i` the coordinator observes the
//! payload size and its transfer time ("RTT" in the paper's terminology):
//!
//! - `EBB_i = data_size_i / RTT_i`  (estimated bottleneck bandwidth)
//! - `BtlBw = max(EBB)` over a sliding window (bandwidth filter)
//! - `RTprop = min(RTT)` over a sliding window (propagation filter)
//! - `BDP = BtlBw × RTprop`
//!
//! Windows are indexed by interval count (like BBR's "round trips"), so
//! stale observations age out as conditions change — this is what lets the
//! estimator track the degrading/fluctuating scenarios (Figs. 7–8).

use crate::netsim::time::SimTime;
use crate::util::stats::{WindowedMax, WindowedMin};

/// Estimator tunables.
#[derive(Clone, Debug)]
pub struct EstimatorConfig {
    /// BtlBw filter window, in observation intervals.
    pub btlbw_window: u64,
    /// RTprop filter window, in observation intervals.
    pub rtprop_window: u64,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            // BBR uses ~10 RTT for bandwidth and ~10 s for RTprop; in
            // interval units we keep bandwidth reactive and RTprop long.
            btlbw_window: 10,
            rtprop_window: 50,
        }
    }
}

/// A point-in-time estimate of the network state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NetworkEstimate {
    /// Bottleneck bandwidth, bytes per second.
    pub btlbw_bytes_per_sec: f64,
    /// Propagation delay estimate.
    pub rtprop: SimTime,
    /// Bandwidth-delay product, bytes.
    pub bdp_bytes: f64,
}

/// Streaming estimator over (data_size, RTT) observations.
#[derive(Clone, Debug)]
pub struct BandwidthEstimator {
    config: EstimatorConfig,
    btlbw: WindowedMax,
    rtprop: WindowedMin,
    interval: u64,
    observations: u64,
}

impl BandwidthEstimator {
    pub fn new(config: EstimatorConfig) -> Self {
        BandwidthEstimator {
            btlbw: WindowedMax::new(config.btlbw_window),
            rtprop: WindowedMin::new(config.rtprop_window),
            config,
            interval: 0,
            observations: 0,
        }
    }

    /// Record interval `i`'s observation (Algorithm 1 lines 8–12).
    pub fn observe(&mut self, data_size_bytes: u64, rtt: SimTime) {
        assert!(rtt > SimTime::ZERO, "non-positive RTT");
        self.interval += 1;
        self.observations += 1;
        let ebb = data_size_bytes as f64 / rtt.as_secs_f64(); // Eq. (1)
        self.btlbw.update(self.interval, ebb);
        self.rtprop.update(self.interval, rtt.as_secs_f64());
    }

    /// Number of observations so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Current estimate, if at least one observation is in the windows.
    pub fn estimate(&self) -> Option<NetworkEstimate> {
        let btlbw = self.btlbw.get()?;
        let rtprop_s = self.rtprop.get()?;
        Some(NetworkEstimate {
            btlbw_bytes_per_sec: btlbw,
            rtprop: SimTime::from_secs_f64(rtprop_s),
            bdp_bytes: btlbw * rtprop_s, // Eq. (2)
        })
    }

    /// True when the latest RTT is "excessive" relative to RTprop — the
    /// startup-exit condition (paper §4.1: "until excessive RTT is
    /// detected", mirroring BBR's pipe-full test). `last_rtt > factor ×
    /// RTprop` with at least a couple of observations.
    pub fn rtt_excessive(&self, last_rtt: SimTime, factor: f64) -> bool {
        match self.estimate() {
            Some(est) if self.observations >= 2 => {
                last_rtt.as_secs_f64() > est.rtprop.as_secs_f64() * factor
            }
            _ => false,
        }
    }

    pub fn config(&self) -> &EstimatorConfig {
        &self.config
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> BandwidthEstimator {
        BandwidthEstimator::new(EstimatorConfig::default())
    }

    #[test]
    fn empty_estimator_has_no_estimate() {
        assert!(est().estimate().is_none());
    }

    #[test]
    fn single_observation_defines_all_three() {
        let mut e = est();
        // 1 MB in 100 ms → 10 MB/s
        e.observe(1_000_000, SimTime::from_millis(100));
        let s = e.estimate().unwrap();
        assert!((s.btlbw_bytes_per_sec - 10e6).abs() < 1.0);
        assert_eq!(s.rtprop, SimTime::from_millis(100));
        assert!((s.bdp_bytes - 1e6).abs() < 1.0);
    }

    #[test]
    fn btlbw_takes_max_rtprop_takes_min() {
        let mut e = est();
        e.observe(1_000_000, SimTime::from_millis(100)); // 10 MB/s
        e.observe(500_000, SimTime::from_millis(20)); // 25 MB/s, lower RTT
        e.observe(100_000, SimTime::from_millis(50)); // 2 MB/s
        let s = e.estimate().unwrap();
        assert!((s.btlbw_bytes_per_sec - 25e6).abs() < 1.0);
        assert_eq!(s.rtprop, SimTime::from_millis(20));
    }

    #[test]
    fn old_observations_age_out() {
        let cfg = EstimatorConfig {
            btlbw_window: 3,
            rtprop_window: 3,
        };
        let mut e = BandwidthEstimator::new(cfg);
        e.observe(1_000_000, SimTime::from_millis(10)); // 100 MB/s burst
        for _ in 0..5 {
            e.observe(100_000, SimTime::from_millis(50)); // 2 MB/s steady
        }
        let s = e.estimate().unwrap();
        // The 100 MB/s sample (and its 10 ms RTT) must have aged out.
        assert!((s.btlbw_bytes_per_sec - 2e6).abs() < 1.0, "{s:?}");
        assert_eq!(s.rtprop, SimTime::from_millis(50));
    }

    #[test]
    fn converges_to_ground_truth_on_simulated_link() {
        // Drive the estimator with the netsim and check it recovers the
        // configured ground truth (stronger than the paper's testbed can).
        use crate::netsim::topology::StarTopology;
        use crate::netsim::NetSim;
        let bw_bps = 200e6; // 200 Mbps
        let prop = SimTime::from_millis(20);
        let mut sim = NetSim::quiet(StarTopology::constant(2, bw_bps, prop));
        let mut e = est();
        // Ramp payload sizes from 100 kB to 10 MB (like startup).
        let mut size = 100_000u64;
        for _ in 0..15 {
            let r = sim.transfer(0, 1, size);
            sim.advance_to(r.arrival);
            e.observe(size, r.rtt());
            size = (size as f64 * 1.5) as u64;
        }
        let s = e.estimate().unwrap();
        // Ground truth: two hops of 200 Mbps in series = 12.5 MB/s
        // effective on payload (store-and-forward halves throughput for
        // large messages), RTprop = 2×20 ms + small serialization floor.
        let truth_bw = bw_bps / 8.0 / 2.0;
        let rel = (s.btlbw_bytes_per_sec - truth_bw).abs() / truth_bw;
        assert!(rel < 0.15, "btlbw {} vs {truth_bw}", s.btlbw_bytes_per_sec);
        assert!(
            s.rtprop >= SimTime::from_millis(40) && s.rtprop <= SimTime::from_millis(60),
            "rtprop {}",
            s.rtprop
        );
    }

    #[test]
    fn rtt_excessive_logic() {
        let mut e = est();
        assert!(!e.rtt_excessive(SimTime::from_millis(500), 2.0));
        e.observe(1000, SimTime::from_millis(10));
        // needs ≥ 2 observations
        assert!(!e.rtt_excessive(SimTime::from_millis(100), 2.0));
        e.observe(1000, SimTime::from_millis(10));
        assert!(e.rtt_excessive(SimTime::from_millis(21), 2.0));
        assert!(!e.rtt_excessive(SimTime::from_millis(19), 2.0));
    }

    #[test]
    #[should_panic(expected = "non-positive RTT")]
    fn zero_rtt_rejected() {
        est().observe(100, SimTime::ZERO);
    }

    #[test]
    fn tracks_bandwidth_degradation() {
        // Feed 2 MB/s then degrade to 0.5 MB/s; estimate must follow after
        // the window slides.
        let mut e = BandwidthEstimator::new(EstimatorConfig {
            btlbw_window: 5,
            rtprop_window: 100,
        });
        for _ in 0..10 {
            e.observe(200_000, SimTime::from_millis(100)); // 2 MB/s
        }
        assert!((e.estimate().unwrap().btlbw_bytes_per_sec - 2e6).abs() < 1.0);
        for _ in 0..10 {
            e.observe(50_000, SimTime::from_millis(100)); // 0.5 MB/s
        }
        assert!((e.estimate().unwrap().btlbw_bytes_per_sec - 0.5e6).abs() < 1.0);
    }
}
