//! Algorithm 1 — Network Status Sensing and Adaptive Compression Ratio
//! Adjustment.
//!
//! Two phases:
//!
//! **Startup** (lines 1–5): `ratio ← 0.01`, then every step
//! `ratio ← min(1, ratio + β₁)` — a fast ramp, mirroring BBR's startup —
//! until packet loss or excessive RTT is detected, at which point the
//! controller enters the steady phase.
//!
//! **NetSense** (lines 6–19): after each gradient transmission interval the
//! estimator updates BtlBw/RTprop/BDP, and:
//! `data_size > 0.9 × BDP  ⇒  ratio ← max(0.005, ratio × α)`  (α = 0.5)
//! `otherwise              ⇒  ratio ← min(1, ratio + β₂)`      (β₂ = 0.01)
//!
//! A **lost** interval (a recv deadline, a dropped round, a membership
//! recovery — the signals [`crate::fault`] and the live exchange feed in)
//! is congestion evidence stronger than any BDP estimate: it triggers the
//! multiplicative backoff directly, even when the byte-count test alone
//! would have ramped up.
//!
//! The controller also advises the bucketed pipeline
//! ([`RatioController::recommended_bucket_bytes`]): transport stages are
//! sized to the sensed BDP, so in-flight units shrink under congestion.
//!
//! ```
//! use netsenseml::netsim::SimTime;
//! use netsenseml::sensing::{ControllerConfig, Phase, RatioController};
//!
//! let mut ctl = RatioController::new(ControllerConfig::default());
//! assert_eq!(ctl.phase(), Phase::Startup);
//! assert_eq!(ctl.ratio(), 0.01);
//! // Feed one clean interval observation: startup ramps the ratio.
//! let r = ctl.on_interval(1_000, SimTime::from_millis(10), false);
//! assert!(r > 0.01);
//! // 1 kB / 10 ms → BDP = 1 kB; stage sizing clamps to [floor, ceiling].
//! let stage = ctl.recommended_bucket_bytes(256, 1 << 20);
//! assert_eq!(stage, 1_000);
//! ```

use super::estimator::{BandwidthEstimator, EstimatorConfig, NetworkEstimate};
use crate::netsim::time::SimTime;

/// Controller tunables (paper defaults in `Default`).
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Initial compression ratio (Algorithm 1 line 2).
    pub initial_ratio: f64,
    /// Startup additive ramp β₁ per step.
    pub beta1: f64,
    /// Steady additive increase β₂ per interval.
    pub beta2: f64,
    /// Multiplicative decrease α on congestion.
    pub alpha: f64,
    /// Ratio floor (paper: 0.005).
    pub min_ratio: f64,
    /// BDP guard factor (paper: 0.9).
    pub bdp_guard: f64,
    /// RTT considered "excessive" at `rtt > factor × RTprop` (startup exit).
    pub excess_rtt_factor: f64,
    /// Cap on startup length, in intervals (safety net).
    pub max_startup_intervals: u64,
    pub estimator: EstimatorConfig,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            initial_ratio: 0.01,
            beta1: 0.05,
            beta2: 0.01,
            alpha: 0.5,
            min_ratio: 0.005,
            bdp_guard: 0.9,
            excess_rtt_factor: 1.5,
            max_startup_intervals: 50,
            estimator: EstimatorConfig::default(),
        }
    }
}

/// Which phase the controller is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Startup,
    NetSense,
}

/// Which Algorithm 1 branch an interval took.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Branch {
    /// Startup additive ramp (β₁).
    StartupRamp,
    /// Multiplicative decrease (α) — loss or BDP-guard violation.
    Backoff,
    /// Steady additive increase (β₂).
    Increase,
    /// No estimate yet / no adjustment this interval.
    Hold,
}

/// Everything one [`RatioController::on_interval`] call observed and
/// decided — the record the decision journal
/// ([`crate::obs::journal`]) persists per interval.
#[derive(Clone, Copy, Debug)]
pub struct Transition {
    /// 1-based interval counter (equals [`RatioController::intervals`]
    /// after the call).
    pub interval: u64,
    pub phase_before: Phase,
    pub phase_after: Phase,
    pub old_ratio: f64,
    pub new_ratio: f64,
    /// Payload bytes the observation covered.
    pub data_size_bytes: u64,
    /// Measured transfer time fed in.
    pub rtt: SimTime,
    /// Whether the interval lost something.
    pub lost: bool,
    /// Which branch fired.
    pub branch: Branch,
}

/// The Algorithm 1 state machine.
#[derive(Clone, Debug)]
pub struct RatioController {
    config: ControllerConfig,
    estimator: BandwidthEstimator,
    ratio: f64,
    phase: Phase,
    intervals: u64,
    /// Diagnostics: how often each branch fired.
    pub n_decreases: u64,
    pub n_increases: u64,
    /// The most recent interval's full transition record.
    last_transition: Option<Transition>,
    /// Branch taken by the current `on_interval` call (scratch).
    branch: Branch,
    /// Out-of-band congestion evidence ([`Self::note_congestion`])
    /// pending application to the next interval.
    pending_congestion: bool,
}

impl RatioController {
    pub fn new(config: ControllerConfig) -> Self {
        assert!(config.initial_ratio > 0.0 && config.initial_ratio <= 1.0);
        assert!(config.alpha > 0.0 && config.alpha < 1.0);
        assert!(config.min_ratio > 0.0);
        RatioController {
            estimator: BandwidthEstimator::new(config.estimator.clone()),
            ratio: config.initial_ratio,
            phase: Phase::Startup,
            intervals: 0,
            n_decreases: 0,
            n_increases: 0,
            last_transition: None,
            branch: Branch::Hold,
            pending_congestion: false,
            config,
        }
    }

    /// The compression ratio to use for the *next* transmission.
    pub fn ratio(&self) -> f64 {
        self.ratio
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn estimate(&self) -> Option<NetworkEstimate> {
        self.estimator.estimate()
    }

    pub fn intervals(&self) -> u64 {
        self.intervals
    }

    /// Feed interval `i`'s observation (the just-completed transmission:
    /// payload bytes and measured transfer time) and advance the state
    /// machine. Returns the ratio for the next interval.
    ///
    /// `lost` reports loss in the interval: packet loss, a recv deadline,
    /// or a round that needed a membership recovery — the live exchange
    /// and the failure detector ([`crate::fault`]) set it from measured
    /// events (it is the paper's alternative startup-exit trigger, and in
    /// the steady phase it forces the multiplicative backoff).
    pub fn on_interval(&mut self, data_size_bytes: u64, rtt: SimTime, lost: bool) -> f64 {
        let lost = lost || std::mem::take(&mut self.pending_congestion);
        self.intervals += 1;
        self.estimator.observe(data_size_bytes, rtt);
        let phase_before = self.phase;
        let old_ratio = self.ratio;
        self.branch = Branch::Hold;

        match self.phase {
            Phase::Startup => {
                let excessive = self
                    .estimator
                    .rtt_excessive(rtt, self.config.excess_rtt_factor);
                if lost || excessive || self.intervals >= self.config.max_startup_intervals {
                    self.phase = Phase::NetSense;
                    if lost {
                        // Loss at startup-exit: back off immediately.
                        self.backoff();
                    } else {
                        // Fall through to a NetSense-style adjustment this
                        // interval so congestion found at startup-exit is
                        // acted on immediately.
                        self.netsense_adjust(data_size_bytes);
                    }
                } else {
                    // Algorithm 1 line 5: quick ramp.
                    self.ratio = (self.ratio + self.config.beta1).min(1.0);
                    self.n_increases += 1;
                    self.branch = Branch::StartupRamp;
                }
            }
            Phase::NetSense => {
                if lost {
                    // Loss outranks the BDP test: an interval that needed
                    // a recovery (or dropped data) is congestion evidence
                    // no matter how small its payload was.
                    self.backoff();
                } else {
                    self.netsense_adjust(data_size_bytes);
                }
            }
        }
        self.last_transition = Some(Transition {
            interval: self.intervals,
            phase_before,
            phase_after: self.phase,
            old_ratio,
            new_ratio: self.ratio,
            data_size_bytes,
            rtt,
            lost,
            branch: self.branch,
        });
        self.ratio
    }

    /// The full record of the most recent [`Self::on_interval`] call —
    /// what was observed, which branch fired, and the old → new ratio.
    /// `None` before the first interval. The decision journal persists
    /// these; sensing itself stays telemetry-agnostic.
    pub fn last_transition(&self) -> Option<Transition> {
        self.last_transition
    }

    /// Register out-of-band congestion evidence — e.g. a `Congestion`
    /// verdict from the cluster analyzer ([`crate::obs::analyze`]) when a
    /// prior run's trace showed backoff-under-loss — to be treated as a
    /// lost interval by the *next* [`Self::on_interval`] call, then
    /// cleared. The live loop deliberately does not self-feed this
    /// (measured loss already reaches `on_interval` directly, and the
    /// loop must stay deterministic against its netsim mirror); it exists
    /// for operators and offline replay tooling priming a controller from
    /// a previous run's verdicts.
    pub fn note_congestion(&mut self) {
        self.pending_congestion = true;
    }

    /// Multiplicative decrease (Algorithm 1 line 16) — the backoff branch.
    fn backoff(&mut self) {
        self.ratio = (self.ratio * self.config.alpha).max(self.config.min_ratio);
        self.n_decreases += 1;
        self.branch = Branch::Backoff;
    }

    /// Transport-stage size the bucketed pipeline should use right now:
    /// one sensed BDP, clamped to `[floor_bytes, ceil_bytes]`. Keeping each
    /// in-flight unit near the BDP bounds its transfer time near RTprop, so
    /// under congestion (shrinking BDP) the pipeline ships smaller buckets
    /// and the sensing loop stays responsive; with no estimate yet the
    /// ceiling is used (optimistic, like the startup ramp).
    pub fn recommended_bucket_bytes(&self, floor_bytes: u64, ceil_bytes: u64) -> u64 {
        let floor = floor_bytes.min(ceil_bytes);
        match self.estimator.estimate() {
            Some(est) if est.bdp_bytes.is_finite() => {
                (est.bdp_bytes as u64).clamp(floor, ceil_bytes)
            }
            _ => ceil_bytes,
        }
    }

    fn netsense_adjust(&mut self, data_size_bytes: u64) {
        let Some(est) = self.estimator.estimate() else {
            return;
        };
        // Algorithm 1 lines 15–19 / Eq. (3).
        if (data_size_bytes as f64) > self.config.bdp_guard * est.bdp_bytes {
            self.backoff();
        } else {
            self.ratio = (self.ratio + self.config.beta2).min(1.0);
            self.n_increases += 1;
            self.branch = Branch::Increase;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;
    use crate::netsim::NetSim;
    use crate::testing::prop::*;

    fn ctl() -> RatioController {
        RatioController::new(ControllerConfig::default())
    }

    #[test]
    fn starts_in_startup_at_initial_ratio() {
        let c = ctl();
        assert_eq!(c.phase(), Phase::Startup);
        assert_eq!(c.ratio(), 0.01);
    }

    #[test]
    fn startup_ramps_additively() {
        let mut c = ctl();
        // Constant small RTT → no congestion signal → keep ramping.
        let r1 = c.on_interval(1000, SimTime::from_millis(10), false);
        assert!((r1 - 0.06).abs() < 1e-12);
        let r2 = c.on_interval(1000, SimTime::from_millis(10), false);
        assert!((r2 - 0.11).abs() < 1e-12);
        assert_eq!(c.phase(), Phase::Startup);
    }

    #[test]
    fn excessive_rtt_exits_startup() {
        let mut c = ctl();
        c.on_interval(1000, SimTime::from_millis(10), false);
        c.on_interval(1000, SimTime::from_millis(10), false);
        // RTT jumps 5× → excessive → NetSense.
        c.on_interval(100_000, SimTime::from_millis(50), false);
        assert_eq!(c.phase(), Phase::NetSense);
    }

    #[test]
    fn loss_exits_startup() {
        let mut c = ctl();
        c.on_interval(1000, SimTime::from_millis(10), true);
        assert_eq!(c.phase(), Phase::NetSense);
    }

    #[test]
    fn startup_capped() {
        let cfg = ControllerConfig {
            max_startup_intervals: 5,
            ..Default::default()
        };
        let mut c = RatioController::new(cfg);
        for _ in 0..5 {
            c.on_interval(1000, SimTime::from_millis(10), false);
        }
        assert_eq!(c.phase(), Phase::NetSense);
    }

    #[test]
    fn netsense_multiplicative_decrease_on_congestion() {
        let mut c = ctl();
        // Two clean startup intervals establish RTprop = 10 ms and ramp the
        // ratio to 0.11 (well above the 0.005 floor).
        c.on_interval(1000, SimTime::from_millis(10), false);
        c.on_interval(1000, SimTime::from_millis(10), false);
        let before = c.ratio();
        assert!((before - 0.11).abs() < 1e-12);
        // 3× RTT is excessive → exits startup; BDP ≈ 1.67 kB and the 5 kB
        // payload exceeds the 0.9 guard → multiplicative decrease.
        let after = c.on_interval(5000, SimTime::from_millis(30), false);
        assert_eq!(c.phase(), Phase::NetSense);
        assert!((after - before * 0.5).abs() < 1e-12);
    }

    /// The satellite fix: a *lost* interval (recv deadline, membership
    /// recovery) must trigger the multiplicative backoff in the steady
    /// phase, even when the payload-vs-BDP test alone would have ramped
    /// the ratio up.
    #[test]
    fn netsense_lost_interval_triggers_backoff() {
        let mut c = ctl();
        c.on_interval(1_000_000, SimTime::from_millis(100), true); // → NetSense, BDP = 1 MB
        // Ramp a few clean under-BDP intervals so the ratio is well off
        // the floor and the no-loss branch is provably "increase" (few
        // enough that the 10 MB/s anchor stays inside the BtlBw window).
        for _ in 0..5 {
            c.on_interval(100_000, SimTime::from_millis(100), false);
        }
        assert_eq!(c.phase(), Phase::NetSense);
        let before = c.ratio();
        let decreases_before = c.n_decreases;
        // Same tiny payload — but lost. Must back off multiplicatively.
        let after = c.on_interval(100_000, SimTime::from_millis(100), true);
        assert!((after - (before * 0.5).max(0.005)).abs() < 1e-12, "{before} → {after}");
        assert_eq!(c.n_decreases, decreases_before + 1);
        // And the next clean interval resumes the additive climb.
        let resumed = c.on_interval(100_000, SimTime::from_millis(100), false);
        assert!((resumed - (after + 0.01)).abs() < 1e-12);
    }

    /// The transition record mirrors exactly what `on_interval` did —
    /// the observability layer journals these verbatim.
    #[test]
    fn last_transition_records_each_branch() {
        let mut c = ctl();
        assert!(c.last_transition().is_none());
        // Startup ramp.
        let r1 = c.on_interval(1000, SimTime::from_millis(10), false);
        let t = c.last_transition().unwrap();
        assert_eq!(t.interval, 1);
        assert_eq!(t.branch, Branch::StartupRamp);
        assert_eq!((t.phase_before, t.phase_after), (Phase::Startup, Phase::Startup));
        assert_eq!(t.old_ratio, 0.01);
        assert_eq!(t.new_ratio, r1);
        assert_eq!(t.data_size_bytes, 1000);
        assert_eq!(t.rtt, SimTime::from_millis(10));
        assert!(!t.lost);
        // Loss exits startup via backoff.
        let r2 = c.on_interval(1000, SimTime::from_millis(10), true);
        let t = c.last_transition().unwrap();
        assert_eq!(t.branch, Branch::Backoff);
        assert_eq!((t.phase_before, t.phase_after), (Phase::Startup, Phase::NetSense));
        assert!(t.lost);
        assert_eq!((t.old_ratio, t.new_ratio), (r1, r2));
        // Clean under-BDP interval → additive increase.
        let r3 = c.on_interval(100, SimTime::from_millis(10), false);
        let t = c.last_transition().unwrap();
        assert_eq!(t.branch, Branch::Increase);
        assert_eq!((t.old_ratio, t.new_ratio), (r2, r3));
    }

    /// `note_congestion()` makes the next interval loss-equivalent (one
    /// multiplicative backoff, recorded as lost in the transition), then
    /// clears — the interval after that resumes the additive climb.
    #[test]
    fn noted_congestion_backs_off_exactly_one_interval() {
        let mut c = ctl();
        c.on_interval(1_000_000, SimTime::from_millis(100), true); // → NetSense, BDP = 1 MB
        for _ in 0..5 {
            c.on_interval(100_000, SimTime::from_millis(100), false);
        }
        let before = c.ratio();
        let decreases_before = c.n_decreases;
        c.note_congestion();
        // A clean, under-BDP observation — but the noted verdict outranks it.
        let after = c.on_interval(100_000, SimTime::from_millis(100), false);
        assert!((after - (before * 0.5).max(0.005)).abs() < 1e-12, "{before} → {after}");
        assert_eq!(c.n_decreases, decreases_before + 1);
        let t = c.last_transition().unwrap();
        assert_eq!(t.branch, Branch::Backoff);
        assert!(t.lost, "noted congestion must be journaled as a lost interval");
        // Cleared: the next clean interval increases again.
        let resumed = c.on_interval(100_000, SimTime::from_millis(100), false);
        assert!((resumed - (after + 0.01)).abs() < 1e-12);
        assert!(!c.last_transition().unwrap().lost);
    }

    #[test]
    fn netsense_additive_increase_when_underutilized() {
        let mut c = ctl();
        c.on_interval(1_000_000, SimTime::from_millis(100), true); // BDP = 1 MB
        let before = c.ratio();
        // 100 kB ≤ 0.9 MB → ratio += β₂.
        let after = c.on_interval(100_000, SimTime::from_millis(100), false);
        assert!((after - (before + 0.01)).abs() < 1e-12);
    }

    #[test]
    fn ratio_floor_is_0005() {
        let mut c = ctl();
        c.on_interval(1_000_000, SimTime::from_millis(100), true);
        for _ in 0..20 {
            // persist congestion
            c.on_interval(10_000_000, SimTime::from_millis(1000), false);
        }
        assert!((c.ratio() - 0.005).abs() < 1e-12);
    }

    #[test]
    fn ratio_cap_is_one() {
        // Keep BtlBw anchored high (long window + one bandwidth-probing
        // sample) so small payloads sit under the BDP guard and the ratio
        // climbs additively all the way to the cap.
        let cfg = ControllerConfig {
            estimator: EstimatorConfig {
                btlbw_window: 10_000,
                rtprop_window: 10_000,
            },
            ..Default::default()
        };
        let mut c = RatioController::new(cfg);
        // 100 MB / 100 ms → BtlBw 1 GB/s, RTprop 0.1 s → BDP 100 MB.
        c.on_interval(100_000_000, SimTime::from_millis(100), true);
        for _ in 0..200 {
            c.on_interval(1_000, SimTime::from_millis(100), false);
        }
        assert_eq!(c.ratio(), 1.0);
    }

    #[test]
    fn recommended_bucket_tracks_bdp() {
        let mut c = ctl();
        // No estimate yet → optimistic ceiling.
        assert_eq!(c.recommended_bucket_bytes(1_000, 8_000_000), 8_000_000);
        // 1 MB / 100 ms → BtlBw 10 MB/s, RTprop 0.1 s → BDP 1 MB.
        c.on_interval(1_000_000, SimTime::from_millis(100), false);
        assert_eq!(c.recommended_bucket_bytes(1_000, 8_000_000), 1_000_000);
        // Clamped by the floor and the ceiling.
        assert_eq!(c.recommended_bucket_bytes(2_000_000, 8_000_000), 2_000_000);
        assert_eq!(c.recommended_bucket_bytes(1_000, 500_000), 500_000);
        // Congestion: same payload, 10× RTT → EBB collapses; after the
        // BtlBw window ages the old sample out, the BDP (and with it the
        // recommended stage) must shrink.
        for _ in 0..20 {
            c.on_interval(1_000_000, SimTime::from_secs_f64(1.0), false);
        }
        let shrunk = c.recommended_bucket_bytes(1_000, 8_000_000);
        assert!(shrunk < 1_000_000 + 1, "stage did not shrink: {shrunk}");
    }

    #[test]
    fn property_ratio_always_in_bounds() {
        forall(
            "ratio ∈ [0.005, 1] under arbitrary observations",
            100,
            vec_f32(1..100, 0.0..1.0),
            |obs| {
                let mut c = ctl();
                for (i, &x) in obs.iter().enumerate() {
                    let bytes = (x as f64 * 10_000_000.0) as u64 + 1;
                    let rtt = SimTime::from_micros((x * 500_000.0) as u64 + 100);
                    c.on_interval(bytes, rtt, i % 17 == 3);
                    let r = c.ratio();
                    if !(0.005..=1.0).contains(&r) {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// End-to-end closed loop on the simulator: the controller must settle
    /// near the ratio whose payload ≈ BDP, and its payloads must not
    /// persistently exceed the guard.
    #[test]
    fn closed_loop_converges_on_simulated_link() {
        let model_bytes = 46_200_000u64; // ResNet18's 46.2 MB gradients
        let mut sim = NetSim::quiet(StarTopology::constant(
            2,
            mbps(200.0),
            SimTime::from_millis(20),
        ));
        let mut c = ctl();
        let mut last_ratios = Vec::new();
        for step in 0..300 {
            let ratio = c.ratio();
            // payload model: sparse COO, 8 bytes per surviving element
            let payload = ((model_bytes / 4) as f64 * ratio * 8.0) as u64;
            let r = sim.transfer(0, 1, payload);
            sim.advance_to(r.arrival);
            // inter-step compute gap
            sim.advance_by(SimTime::from_millis(50));
            c.on_interval(payload, r.rtt(), false);
            if step >= 250 {
                last_ratios.push(c.ratio());
            }
        }
        assert_eq!(c.phase(), Phase::NetSense);
        let est = c.estimate().unwrap();
        // Steady-state payload should hover near (not wildly above) BDP.
        let mean_ratio = last_ratios.iter().sum::<f64>() / last_ratios.len() as f64;
        let payload = (model_bytes / 4) as f64 * mean_ratio * 8.0;
        assert!(
            payload < 3.0 * est.bdp_bytes,
            "payload {payload:.0} should be near BDP {:.0}",
            est.bdp_bytes
        );
        assert!(
            payload > 0.2 * est.bdp_bytes,
            "payload {payload:.0} collapsed vs BDP {:.0}",
            est.bdp_bytes
        );
        // And the controller must have exercised both branches.
        assert!(c.n_decreases > 0 && c.n_increases > 0);
    }

    #[test]
    fn adapts_downward_when_bandwidth_degrades() {
        use crate::netsim::link::LinkConfig;
        use crate::netsim::schedule::BandwidthSchedule;
        let sched = BandwidthSchedule::piecewise(vec![
            (SimTime::ZERO, mbps(1000.0)),
            (SimTime::from_secs_f64(30.0), mbps(100.0)),
        ]);
        let cfg = LinkConfig::new(sched, SimTime::from_millis(20));
        let mut sim = NetSim::quiet(StarTopology::uniform(2, cfg));
        let mut c = ctl();
        let model_elems = 11_500_000f64;
        let ratio_at = |c: &RatioController| c.ratio();
        let mut ratio_before_degrade = 0.0;
        for _ in 0..600 {
            let ratio = ratio_at(&c);
            let payload = (model_elems * ratio * 8.0) as u64;
            let r = sim.transfer(0, 1, payload);
            sim.advance_to(r.arrival);
            sim.advance_by(SimTime::from_millis(50));
            c.on_interval(payload, r.rtt(), false);
            if sim.now() < SimTime::from_secs_f64(30.0) {
                ratio_before_degrade = c.ratio();
            }
            if sim.now() > SimTime::from_secs_f64(120.0) {
                break;
            }
        }
        let ratio_after = c.ratio();
        assert!(
            ratio_after < ratio_before_degrade,
            "ratio should fall after degradation: {ratio_before_degrade} → {ratio_after}"
        );
    }
}
