//! Network status sensing (the paper's §4.1): the BBR-inspired estimator
//! and the Algorithm 1 compression-ratio controller.
//!
//! - [`estimator`] — per-interval (data_size, RTT) observations →
//!   EBB = data_size / RTT, windowed BtlBw = max(EBB), RTprop = min(RTT),
//!   BDP = BtlBw × RTprop.
//! - [`controller`] — the two-phase ratio state machine: *startup* (ratio
//!   0.01, fast additive ramp β₁ until excess RTT) and *NetSense*
//!   (multiplicative decrease ×α when `data_size > 0.9·BDP`, additive
//!   increase +β₂ otherwise, clamped to [0.005, 1]).
//!
//! The sensing layer consumes only observables a real deployment has —
//! bytes sent and measured transfer times — never simulator ground truth.

pub mod controller;
pub mod estimator;

pub use controller::{Branch, ControllerConfig, Phase, RatioController, Transition};
pub use estimator::{BandwidthEstimator, EstimatorConfig, NetworkEstimate};
