//! Virtual time: nanosecond-resolution simulation clock.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time (nanoseconds since simulation start).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> SimTime {
        assert!(s >= 0.0 && s.is_finite(), "invalid time {s}");
        SimTime((s * 1e9).round() as u64)
    }

    pub fn from_millis(ms: u64) -> SimTime {
        SimTime(ms * 1_000_000)
    }

    pub fn from_micros(us: u64) -> SimTime {
        SimTime(us * 1_000)
    }

    pub fn from_nanos(ns: u64) -> SimTime {
        SimTime(ns)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        assert!(self.0 >= rhs.0, "SimTime underflow: {self:?} - {rhs:?}");
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s >= 1.0 {
            write!(f, "{s:.3}s")
        } else if s >= 1e-3 {
            write!(f, "{:.3}ms", s * 1e3)
        } else {
            write!(f, "{:.1}µs", s * 1e6)
        }
    }
}

/// Duration of serializing `bytes` onto a link of `bits_per_sec`.
pub fn serialization_time(bytes: u64, bits_per_sec: f64) -> SimTime {
    assert!(bits_per_sec > 0.0, "non-positive bandwidth");
    SimTime(((bytes as f64 * 8.0 / bits_per_sec) * 1e9).round() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(SimTime::from_secs_f64(1.5).as_nanos(), 1_500_000_000);
        assert_eq!(SimTime::from_millis(3).as_secs_f64(), 0.003);
        assert_eq!(SimTime::from_micros(7).as_nanos(), 7_000);
        assert!((SimTime::from_nanos(12).as_millis_f64() - 1.2e-5).abs() < 1e-12);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime::from_millis(10);
        let b = SimTime::from_millis(4);
        assert_eq!((a + b).as_nanos(), 14_000_000);
        assert_eq!((a - b).as_nanos(), 6_000_000);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
        let mut c = a;
        c += b;
        assert_eq!(c, SimTime::from_millis(14));
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        let _ = SimTime::from_millis(1) - SimTime::from_millis(2);
    }

    #[test]
    fn serialization_math() {
        // 1250 bytes at 10 Mbps = 1 ms
        let t = serialization_time(1250, 10e6);
        assert_eq!(t, SimTime::from_millis(1));
        // 0 bytes takes 0 time
        assert_eq!(serialization_time(0, 1e9), SimTime::ZERO);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", SimTime::from_secs_f64(2.0)), "2.000s");
        assert_eq!(format!("{}", SimTime::from_millis(5)), "5.000ms");
        assert_eq!(format!("{}", SimTime::from_micros(40)), "40.0µs");
    }
}
