//! Competing-traffic generators — the simulator's stand-in for the paper's
//! parallel `iperf3` processes (scenario 3): best-effort flows that occupy
//! link capacity and force the training traffic to share the bottleneck.

use super::link::Link;
use super::time::SimTime;
use crate::util::rng::Pcg64;

/// Which simplex link a generator targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkRef {
    /// Worker `w`'s uplink (worker → switch).
    Up(usize),
    /// Worker `w`'s downlink (switch → worker).
    Down(usize),
}

/// Traffic shape.
#[derive(Clone, Debug)]
pub enum TrafficPattern {
    /// iperf-like: alternate ON (sending at `rate_bps` in `tick`-sized
    /// chunks) and OFF periods.
    OnOff {
        on: SimTime,
        off: SimTime,
        rate_bps: f64,
        tick: SimTime,
    },
    /// Poisson message arrivals: exponential inter-arrival at
    /// `msgs_per_sec`, each message `mean_msg_bytes` (exponential sizes).
    Poisson {
        msgs_per_sec: f64,
        mean_msg_bytes: f64,
    },
    /// Constant-rate background load.
    Constant { rate_bps: f64, tick: SimTime },
}

/// A competing traffic source bound to a set of links.
#[derive(Clone, Debug)]
pub struct CompetingTraffic {
    pub pattern: TrafficPattern,
    pub targets: Vec<LinkRef>,
    rng: Pcg64,
    next_fire: SimTime,
    /// Start offset; the generator is silent before this.
    start: SimTime,
    /// For OnOff: where we are in the on/off cycle.
    cycle_started: SimTime,
    on_phase: bool,
    pub injected_bytes: u64,
}

impl CompetingTraffic {
    pub fn new(pattern: TrafficPattern, targets: Vec<LinkRef>, seed: u64) -> Self {
        assert!(!targets.is_empty());
        let mut t = CompetingTraffic {
            pattern,
            targets,
            rng: Pcg64::new(seed, TRAFFIC_STREAM),
            next_fire: SimTime::ZERO,
            start: SimTime::ZERO,
            cycle_started: SimTime::ZERO,
            on_phase: true,
            injected_bytes: 0,
        };
        t.next_fire = t.start;
        t
    }

    pub fn starting_at(mut self, start: SimTime) -> Self {
        self.start = start;
        self.next_fire = start;
        self.cycle_started = start;
        self
    }

    /// Time of the next injection this source wants to make.
    pub fn next_time(&self) -> SimTime {
        self.next_fire
    }

    /// Fire the injection due at `next_time()`, mutating the targeted
    /// links, and schedule the next one.
    pub fn fire(&mut self, now: SimTime, uplinks: &mut [Link], downlinks: &mut [Link]) {
        debug_assert!(now >= self.next_fire);
        match self.pattern.clone() {
            TrafficPattern::OnOff {
                on,
                off,
                rate_bps,
                tick,
            } => {
                // Advance the on/off cycle to `now`.
                let cycle = on + off;
                let since = now.saturating_sub(self.cycle_started);
                let pos = SimTime(since.as_nanos() % cycle.as_nanos().max(1));
                self.on_phase = pos < on;
                if self.on_phase {
                    let bytes = (rate_bps * tick.as_secs_f64() / 8.0) as u64;
                    self.inject(now, bytes, uplinks, downlinks);
                    self.next_fire = now + tick;
                } else {
                    // Sleep until the next ON edge.
                    let to_edge = cycle - pos;
                    self.next_fire = now + to_edge;
                }
            }
            TrafficPattern::Poisson {
                msgs_per_sec,
                mean_msg_bytes,
            } => {
                let bytes = (self.rng.exponential(1.0 / mean_msg_bytes)).max(64.0) as u64;
                self.inject(now, bytes, uplinks, downlinks);
                let dt = self.rng.exponential(msgs_per_sec);
                self.next_fire = now + SimTime::from_secs_f64(dt);
            }
            TrafficPattern::Constant { rate_bps, tick } => {
                let bytes = (rate_bps * tick.as_secs_f64() / 8.0) as u64;
                self.inject(now, bytes, uplinks, downlinks);
                self.next_fire = now + tick;
            }
        }
    }

    fn inject(&mut self, now: SimTime, bytes: u64, uplinks: &mut [Link], downlinks: &mut [Link]) {
        for &t in &self.targets {
            let link = match t {
                LinkRef::Up(w) => &mut uplinks[w],
                LinkRef::Down(w) => &mut downlinks[w],
            };
            link.send_best_effort(now, bytes);
            self.injected_bytes += bytes;
        }
    }
}

/// PCG stream id reserved for traffic generators.
const TRAFFIC_STREAM: u64 = 0x00c0_ffee_7a41_11c0;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::LinkConfig;
    use crate::netsim::schedule::{mbps, BandwidthSchedule};

    fn links(n: usize) -> (Vec<Link>, Vec<Link>) {
        let cfg = LinkConfig::new(
            BandwidthSchedule::constant(mbps(100.0)),
            SimTime::from_millis(1),
        );
        (
            (0..n).map(|_| Link::new(cfg.clone())).collect(),
            (0..n).map(|_| Link::new(cfg.clone())).collect(),
        )
    }

    #[test]
    fn constant_pattern_injects_at_rate() {
        let (mut up, mut down) = links(2);
        let mut t = CompetingTraffic::new(
            TrafficPattern::Constant {
                rate_bps: mbps(50.0),
                tick: SimTime::from_millis(10),
            },
            vec![LinkRef::Up(0)],
            1,
        );
        for _ in 0..100 {
            let now = t.next_time();
            t.fire(now, &mut up, &mut down);
        }
        // 100 ticks × 10 ms × 50 Mbps = 6.25 MB
        let expect = (mbps(50.0) * 0.01 / 8.0) as u64 * 100;
        assert_eq!(t.injected_bytes, expect);
        assert_eq!(up[0].stats.delivered_bytes + up[0].stats.dropped_bytes, expect);
        assert_eq!(down[0].stats.delivered_bytes, 0);
    }

    #[test]
    fn onoff_is_silent_during_off() {
        let (mut up, mut down) = links(1);
        let mut t = CompetingTraffic::new(
            TrafficPattern::OnOff {
                on: SimTime::from_millis(100),
                off: SimTime::from_millis(100),
                rate_bps: mbps(10.0),
                tick: SimTime::from_millis(10),
            },
            vec![LinkRef::Up(0)],
            2,
        );
        // Drive for one full second; injections should only land in ON halves.
        let mut fired_at = Vec::new();
        while t.next_time() < SimTime::from_secs_f64(1.0) {
            let now = t.next_time();
            let before = t.injected_bytes;
            t.fire(now, &mut up, &mut down);
            if t.injected_bytes > before {
                fired_at.push(now);
            }
        }
        assert!(!fired_at.is_empty());
        for at in fired_at {
            let pos_ms = (at.as_nanos() % 200_000_000) / 1_000_000;
            assert!(pos_ms < 100, "injection during OFF at {at}");
        }
    }

    #[test]
    fn poisson_mean_rate_approximately_right() {
        let (mut up, mut down) = links(1);
        let mut t = CompetingTraffic::new(
            TrafficPattern::Poisson {
                msgs_per_sec: 1000.0,
                mean_msg_bytes: 10_000.0,
            },
            vec![LinkRef::Down(0)],
            3,
        );
        let horizon = SimTime::from_secs_f64(10.0);
        let mut count = 0u64;
        while t.next_time() < horizon {
            let now = t.next_time();
            t.fire(now, &mut up, &mut down);
            count += 1;
        }
        // ~10k messages expected; allow ±10%
        assert!((9_000..11_000).contains(&count), "count {count}");
        let mean_bytes = t.injected_bytes as f64 / count as f64;
        assert!((8_000.0..12_000.0).contains(&mean_bytes), "mean {mean_bytes}");
    }

    #[test]
    fn starting_at_delays_first_fire() {
        let t = CompetingTraffic::new(
            TrafficPattern::Constant {
                rate_bps: 1e6,
                tick: SimTime::from_millis(1),
            },
            vec![LinkRef::Up(0)],
            4,
        )
        .starting_at(SimTime::from_secs_f64(5.0));
        assert_eq!(t.next_time(), SimTime::from_secs_f64(5.0));
    }

    #[test]
    fn deterministic_for_seed() {
        let run = |seed| {
            let (mut up, mut down) = links(1);
            let mut t = CompetingTraffic::new(
                TrafficPattern::Poisson {
                    msgs_per_sec: 100.0,
                    mean_msg_bytes: 1000.0,
                },
                vec![LinkRef::Up(0)],
                seed,
            );
            for _ in 0..100 {
                let now = t.next_time();
                t.fire(now, &mut up, &mut down);
            }
            (t.injected_bytes, t.next_time())
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
