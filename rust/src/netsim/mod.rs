//! Event-driven network simulator.
//!
//! This is the substrate that replaces the paper's ESXi testbed (8 workers
//! behind a bandwidth-shaped switch, Fig. 4): links with finite bandwidth,
//! propagation delay and drop-tail byte-bounded queues; a star topology;
//! message flows whose completion times emerge from serialization +
//! queueing + propagation; competing traffic generators (the paper's iperf3
//! processes); and time-varying bandwidth schedules (the paper's scenarios
//! 2 and 3 link shaping).
//!
//! Design notes:
//! - **Virtual time** in nanoseconds ([`time::SimTime`]); the simulator is
//!   single-threaded and deterministic for a given seed.
//! - The unit simulated is a *message* (a gradient bucket / control frame)
//!   fragmented into MTU-sized packets; per-packet queueing produces the
//!   RTT-inflation-under-load behaviour that NetSenseML's sensing relies on
//!   (Fig. 2 of the paper).
//! - Ground truth (configured BtlBw / RTprop) is available to tests only;
//!   the coordinator sees nothing but observed (bytes, RTT) pairs.

pub mod event;
pub mod link;
pub mod schedule;
pub mod sim;
pub mod time;
pub mod topology;
pub mod traffic;

pub use event::EventQueue;
pub use link::{Link, LinkConfig, LinkStats};
pub use schedule::BandwidthSchedule;
pub use sim::{NetSim, NetSimConfig, TransferResult};
pub use time::SimTime;
pub use topology::{NodeId, StarTopology, SWITCH};
pub use traffic::{CompetingTraffic, TrafficPattern};
