//! The simulator's event queue: a binary heap of (time, seq, payload) with
//! FIFO tie-breaking so same-timestamp events run in insertion order —
//! required for determinism.

use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    at: SimTime,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first, then
        // lowest-seq-first for ties.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic time-ordered event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
            now: SimTime::ZERO,
        }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `event` at absolute time `at`. Panics if `at` is in the past.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(
            at >= self.now,
            "scheduling into the past: {at:?} < now {:?}",
            self.now
        );
        self.heap.push(Entry {
            at,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` `delay` after now.
    pub fn schedule_in(&mut self, delay: SimTime, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.at >= self.now);
            self.now = e.at;
            (e.at, e.event)
        })
    }

    /// Time of the next pending event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(5), "c");
        q.schedule_at(SimTime::from_millis(1), "a");
        q.schedule_at(SimTime::from_millis(3), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_are_fifo() {
        let mut q = EventQueue::new();
        let t = SimTime::from_millis(2);
        for i in 0..10 {
            q.schedule_at(t, i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(7), ());
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime::from_millis(7));
        q.schedule_in(SimTime::from_millis(3), ());
        assert_eq!(q.peek_time(), Some(SimTime::from_millis(10)));
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime::from_millis(5), ());
        q.pop();
        q.schedule_at(SimTime::from_millis(1), ());
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<u8> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_at(SimTime::from_millis(1), 0);
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }
}
