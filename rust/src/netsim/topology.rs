//! The paper's evaluation topology (Fig. 4): N worker nodes in a star
//! around a switch, each worker attached by an uplink (worker→switch) and a
//! downlink (switch→worker). Bottlenecks are created by shaping individual
//! links, exactly as the paper shapes "the link bandwidth of two
//! connections to the switch".

use super::link::{Link, LinkConfig};
use super::schedule::BandwidthSchedule;
use super::time::SimTime;

/// Worker identifier (0-based). The switch is [`SWITCH`].
pub type NodeId = usize;

/// Sentinel node id for the switch.
pub const SWITCH: NodeId = usize::MAX;

/// Star topology: `n` workers, each with an uplink and downlink to the
/// switch.
#[derive(Clone, Debug)]
pub struct StarTopology {
    pub uplinks: Vec<Link>,
    pub downlinks: Vec<Link>,
}

impl StarTopology {
    /// Uniform topology: all links share the same config.
    pub fn uniform(n: usize, config: LinkConfig) -> Self {
        assert!(n >= 1);
        StarTopology {
            uplinks: (0..n).map(|_| Link::new(config.clone())).collect(),
            downlinks: (0..n).map(|_| Link::new(config.clone())).collect(),
        }
    }

    /// The paper's shaping setup: all links fast except the listed
    /// `shaped` workers, whose up+down links get `shaped_config`.
    pub fn shaped(
        n: usize,
        fast_config: LinkConfig,
        shaped: &[NodeId],
        shaped_config: LinkConfig,
    ) -> Self {
        let mut t = StarTopology::uniform(n, fast_config);
        for &w in shaped {
            assert!(w < n, "shaped worker {w} out of range");
            t.uplinks[w] = Link::new(shaped_config.clone());
            t.downlinks[w] = Link::new(shaped_config.clone());
        }
        t
    }

    /// Convenience: uniform star with constant bandwidth and delay.
    pub fn constant(n: usize, bits_per_sec: f64, propagation: SimTime) -> Self {
        StarTopology::uniform(
            n,
            LinkConfig::new(BandwidthSchedule::constant(bits_per_sec), propagation),
        )
    }

    pub fn n_workers(&self) -> usize {
        self.uplinks.len()
    }

    pub fn reset(&mut self) {
        for l in self.uplinks.iter_mut().chain(self.downlinks.iter_mut()) {
            l.reset();
        }
    }

    /// Total dropped bytes across all links (best-effort traffic).
    pub fn total_dropped_bytes(&self) -> u64 {
        self.uplinks
            .iter()
            .chain(self.downlinks.iter())
            .map(|l| l.stats.dropped_bytes)
            .sum()
    }

    /// Total delivered bytes across all links.
    pub fn total_delivered_bytes(&self) -> u64 {
        self.uplinks
            .iter()
            .chain(self.downlinks.iter())
            .map(|l| l.stats.delivered_bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::Offer;
    use crate::netsim::schedule::mbps;

    #[test]
    fn uniform_has_2n_links() {
        let t = StarTopology::constant(8, mbps(1000.0), SimTime::from_millis(1));
        assert_eq!(t.n_workers(), 8);
        assert_eq!(t.uplinks.len(), 8);
        assert_eq!(t.downlinks.len(), 8);
    }

    #[test]
    fn shaped_links_are_slower() {
        let fast = LinkConfig::new(BandwidthSchedule::constant(mbps(10_000.0)), SimTime::ZERO);
        let slow = LinkConfig::new(BandwidthSchedule::constant(mbps(200.0)), SimTime::ZERO);
        let mut t = StarTopology::shaped(4, fast, &[1, 2], slow);
        let bytes = 2_500_000; // 2.5 MB
        let fast_arrival = match t.uplinks[0].send_reliable(SimTime::ZERO, bytes) {
            Offer::Accepted { arrival, .. } => arrival,
            _ => panic!(),
        };
        let slow_arrival = match t.uplinks[1].send_reliable(SimTime::ZERO, bytes) {
            Offer::Accepted { arrival, .. } => arrival,
            _ => panic!(),
        };
        assert!(slow_arrival.as_secs_f64() > fast_arrival.as_secs_f64() * 10.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn shaped_rejects_bad_worker() {
        let cfg = LinkConfig::new(BandwidthSchedule::constant(1e6), SimTime::ZERO);
        StarTopology::shaped(2, cfg.clone(), &[5], cfg);
    }

    #[test]
    fn byte_accounting() {
        let mut t = StarTopology::constant(2, mbps(100.0), SimTime::ZERO);
        t.uplinks[0].send_reliable(SimTime::ZERO, 1000);
        t.downlinks[1].send_reliable(SimTime::ZERO, 500);
        assert_eq!(t.total_delivered_bytes(), 1500);
        assert_eq!(t.total_dropped_bytes(), 0);
        t.reset();
        assert_eq!(t.total_delivered_bytes(), 0);
    }
}
