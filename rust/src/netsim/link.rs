//! A simplex link: FIFO store-and-forward server with a (possibly
//! time-varying) bandwidth, fixed propagation delay, and a byte-bounded
//! drop-tail buffer for best-effort traffic.
//!
//! Reliable transfers (gradient traffic rides TCP in the paper) are never
//! dropped — they wait behind the backlog (backpressure), which is exactly
//! what inflates the sensed RTT under congestion. Best-effort injections
//! (competing iperf-like traffic) are dropped when the backlog exceeds the
//! buffer, bounding how far a overloaded link's queue can grow.

use super::schedule::BandwidthSchedule;
use super::time::SimTime;

/// Static configuration of a link.
#[derive(Clone, Debug)]
pub struct LinkConfig {
    pub schedule: BandwidthSchedule,
    /// One-way propagation delay.
    pub propagation: SimTime,
    /// Drop-tail buffer for best-effort traffic, in bytes of backlog.
    pub buffer_bytes: u64,
}

impl LinkConfig {
    pub fn new(schedule: BandwidthSchedule, propagation: SimTime) -> Self {
        LinkConfig {
            schedule,
            propagation,
            // Default: ~1 BDP-ish generous switch buffer (4 MB).
            buffer_bytes: 4 << 20,
        }
    }

    pub fn with_buffer(mut self, bytes: u64) -> Self {
        self.buffer_bytes = bytes;
        self
    }
}

/// Counters exposed for experiments and tests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LinkStats {
    pub delivered_msgs: u64,
    pub delivered_bytes: u64,
    pub dropped_msgs: u64,
    pub dropped_bytes: u64,
    /// Maximum backlog (bytes queued ahead of an arriving message) observed.
    pub max_backlog_bytes: u64,
    /// Total time the link spent serving (busy), for utilization.
    pub busy_time: SimTime,
}

/// Simplex link state.
#[derive(Clone, Debug)]
pub struct Link {
    pub config: LinkConfig,
    /// Time until which previously accepted traffic occupies the server.
    busy_until: SimTime,
    pub stats: LinkStats,
}

/// Outcome of offering a message to a link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Offer {
    /// Message accepted; carries (start_serialize, arrival_at_far_end).
    Accepted { start: SimTime, arrival: SimTime },
    /// Best-effort message dropped (buffer full).
    Dropped,
}

impl Link {
    pub fn new(config: LinkConfig) -> Self {
        Link {
            config,
            busy_until: SimTime::ZERO,
            stats: LinkStats::default(),
        }
    }

    /// Current backlog, in *time* (how far busy_until runs ahead of `now`).
    pub fn backlog_time(&self, now: SimTime) -> SimTime {
        self.busy_until.saturating_sub(now)
    }

    /// Approximate backlog in bytes at the current rate.
    pub fn backlog_bytes(&self, now: SimTime) -> u64 {
        let rate = self.config.schedule.rate_at(now);
        (self.backlog_time(now).as_secs_f64() * rate / 8.0) as u64
    }

    /// Offer a **reliable** message: always accepted, waits behind backlog.
    /// Returns the arrival time at the far end of the link.
    pub fn send_reliable(&mut self, now: SimTime, bytes: u64) -> Offer {
        let backlog = self.backlog_bytes(now);
        self.stats.max_backlog_bytes = self.stats.max_backlog_bytes.max(backlog);
        let start = self.busy_until.max(now);
        let done = self.config.schedule.finish_time(start, bytes);
        self.busy_until = done;
        self.stats.delivered_msgs += 1;
        self.stats.delivered_bytes += bytes;
        self.stats.busy_time += done - start;
        Offer::Accepted {
            start,
            arrival: done + self.config.propagation,
        }
    }

    /// Offer a **best-effort** message: dropped if backlog exceeds buffer.
    pub fn send_best_effort(&mut self, now: SimTime, bytes: u64) -> Offer {
        let backlog = self.backlog_bytes(now);
        if backlog.saturating_add(bytes) > self.config.buffer_bytes {
            self.stats.dropped_msgs += 1;
            self.stats.dropped_bytes += bytes;
            return Offer::Dropped;
        }
        self.send_reliable(now, bytes)
    }

    /// Ground-truth rate right now (tests / reporting only — the
    /// coordinator must not call this).
    pub fn true_rate_at(&self, now: SimTime) -> f64 {
        self.config.schedule.rate_at(now)
    }

    /// Reset dynamic state but keep configuration (new experiment run).
    pub fn reset(&mut self) {
        self.busy_until = SimTime::ZERO;
        self.stats = LinkStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;

    fn link_100mbps_1ms() -> Link {
        Link::new(LinkConfig::new(
            BandwidthSchedule::constant(mbps(100.0)),
            SimTime::from_millis(1),
        ))
    }

    #[test]
    fn idle_link_latency_is_serialization_plus_propagation() {
        let mut l = link_100mbps_1ms();
        // 1.25 MB at 100 Mbps = 100 ms serialize + 1 ms prop
        match l.send_reliable(SimTime::ZERO, 1_250_000) {
            Offer::Accepted { start, arrival } => {
                assert_eq!(start, SimTime::ZERO);
                assert_eq!(arrival, SimTime::from_millis(101));
            }
            _ => panic!("dropped"),
        }
    }

    #[test]
    fn fifo_queueing_delays_second_message() {
        let mut l = link_100mbps_1ms();
        l.send_reliable(SimTime::ZERO, 1_250_000); // occupies [0, 100ms]
        match l.send_reliable(SimTime::from_millis(10), 125_000) {
            Offer::Accepted { start, arrival } => {
                assert_eq!(start, SimTime::from_millis(100));
                assert_eq!(arrival, SimTime::from_millis(111));
            }
            _ => panic!("dropped"),
        }
    }

    #[test]
    fn backlog_drains_over_time() {
        let mut l = link_100mbps_1ms();
        l.send_reliable(SimTime::ZERO, 1_250_000);
        assert!(l.backlog_time(SimTime::from_millis(50)) == SimTime::from_millis(50));
        assert_eq!(l.backlog_time(SimTime::from_millis(200)), SimTime::ZERO);
        // backlog_bytes ≈ 50ms * 100Mbps / 8 = 625_000 B
        let bb = l.backlog_bytes(SimTime::from_millis(50));
        assert!((bb as i64 - 625_000).unsigned_abs() < 1_000, "{bb}");
    }

    #[test]
    fn best_effort_drops_when_buffer_full() {
        let mut l = Link::new(
            LinkConfig::new(
                BandwidthSchedule::constant(mbps(100.0)),
                SimTime::from_millis(1),
            )
            .with_buffer(1_000_000),
        );
        // Fill ~1.25 MB of backlog with a reliable message.
        l.send_reliable(SimTime::ZERO, 1_250_000);
        match l.send_best_effort(SimTime::ZERO, 500_000) {
            Offer::Dropped => {}
            other => panic!("expected drop, got {other:?}"),
        }
        assert_eq!(l.stats.dropped_msgs, 1);
        assert_eq!(l.stats.dropped_bytes, 500_000);
        // After drain, best-effort is accepted again.
        match l.send_best_effort(SimTime::from_millis(200), 500_000) {
            Offer::Accepted { .. } => {}
            other => panic!("expected accept, got {other:?}"),
        }
    }

    #[test]
    fn reliable_never_drops() {
        let mut l = Link::new(
            LinkConfig::new(
                BandwidthSchedule::constant(mbps(1.0)),
                SimTime::from_millis(1),
            )
            .with_buffer(10),
        );
        for _ in 0..100 {
            match l.send_reliable(SimTime::ZERO, 1_000_000) {
                Offer::Accepted { .. } => {}
                Offer::Dropped => panic!("reliable dropped"),
            }
        }
        assert_eq!(l.stats.dropped_msgs, 0);
        assert_eq!(l.stats.delivered_msgs, 100);
    }

    #[test]
    fn stats_track_delivery_and_busy_time() {
        let mut l = link_100mbps_1ms();
        l.send_reliable(SimTime::ZERO, 1_250_000);
        l.send_reliable(SimTime::ZERO, 1_250_000);
        assert_eq!(l.stats.delivered_bytes, 2_500_000);
        assert_eq!(l.stats.busy_time, SimTime::from_millis(200));
        assert!(l.stats.max_backlog_bytes > 0);
    }

    #[test]
    fn reset_clears_dynamic_state() {
        let mut l = link_100mbps_1ms();
        l.send_reliable(SimTime::ZERO, 1_250_000);
        l.reset();
        assert_eq!(l.stats, LinkStats::default());
        assert_eq!(l.backlog_time(SimTime::ZERO), SimTime::ZERO);
    }

    #[test]
    fn degrading_schedule_slows_transfers() {
        let sched = BandwidthSchedule::piecewise(vec![
            (SimTime::ZERO, mbps(100.0)),
            (SimTime::from_secs_f64(1.0), mbps(10.0)),
        ]);
        let mut l = Link::new(LinkConfig::new(sched, SimTime::ZERO));
        // At t=2s (in the 10 Mbps regime) 1.25 MB takes 1 s.
        match l.send_reliable(SimTime::from_secs_f64(2.0), 1_250_000) {
            Offer::Accepted { arrival, .. } => {
                assert!((arrival.as_secs_f64() - 3.0).abs() < 1e-6);
            }
            _ => panic!(),
        }
    }
}
