//! The simulator facade: a star topology + competing traffic + a virtual
//! clock, with the transfer primitives the collectives are built on.
//!
//! Semantics: reliable worker↔worker transfers are store-and-forward through
//! the switch (uplink of the source, then downlink of the destination), with
//! FIFO queueing behind any backlog — including backlog created by competing
//! best-effort traffic, which is injected in event order as virtual time
//! advances.

use super::link::Offer;
use super::time::SimTime;
use super::topology::{NodeId, StarTopology};
use super::traffic::CompetingTraffic;

/// Configuration for a [`NetSim`].
#[derive(Clone, Debug)]
pub struct NetSimConfig {
    pub topology: StarTopology,
    pub traffic: Vec<CompetingTraffic>,
}

/// Result of one reliable worker→worker transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferResult {
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: u64,
    /// When the transfer was offered to the network.
    pub sent_at: SimTime,
    /// When the last byte arrived at `dst`.
    pub arrival: SimTime,
}

impl TransferResult {
    /// The "RTT" observable of the paper: the transfer completion time of
    /// this interval's data (Algorithm 1 line 8 measures exactly this).
    pub fn rtt(&self) -> SimTime {
        self.arrival - self.sent_at
    }
}

/// Result of a parallel phase of transfers.
#[derive(Clone, Debug, Default)]
pub struct PhaseResult {
    pub transfers: Vec<TransferResult>,
    /// Completion time of the slowest transfer in the phase.
    pub makespan: SimTime,
}

/// The network simulator.
pub struct NetSim {
    pub topology: StarTopology,
    traffic: Vec<CompetingTraffic>,
    now: SimTime,
}

impl NetSim {
    pub fn new(config: NetSimConfig) -> Self {
        NetSim {
            topology: config.topology,
            traffic: config.traffic,
            now: SimTime::ZERO,
        }
    }

    /// Simulator with no competing traffic.
    pub fn quiet(topology: StarTopology) -> Self {
        NetSim {
            topology,
            traffic: Vec::new(),
            now: SimTime::ZERO,
        }
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Advance virtual time to `t`, injecting competing-traffic events due
    /// in `(now, t]` in timestamp order.
    pub fn advance_to(&mut self, t: SimTime) {
        assert!(t >= self.now, "time going backwards: {t} < {}", self.now);
        loop {
            // Earliest pending traffic event ≤ t.
            let next = self
                .traffic
                .iter()
                .enumerate()
                .map(|(i, tr)| (tr.next_time(), i))
                .min();
            match next {
                Some((at, i)) if at <= t => {
                    let fire_at = at.max(self.now);
                    self.traffic[i].fire(
                        fire_at,
                        &mut self.topology.uplinks,
                        &mut self.topology.downlinks,
                    );
                }
                _ => break,
            }
        }
        self.now = t;
    }

    /// Advance by a delta (e.g. local compute time between sync rounds).
    pub fn advance_by(&mut self, dt: SimTime) {
        self.advance_to(self.now + dt);
    }

    /// One reliable worker→worker transfer starting now. Does **not**
    /// advance the clock — use [`NetSim::phase`] or advance explicitly.
    pub fn transfer(&mut self, src: NodeId, dst: NodeId, bytes: u64) -> TransferResult {
        let at = self.now;
        self.transfer_at(src, dst, bytes, at)
    }

    /// Like [`NetSim::transfer`], but offered to the network at `start`
    /// (clamped to `now`) **without advancing the public clock** — the
    /// event-loop primitive for pipelined bucket exchanges, where payload
    /// *k+1* becomes ready (its compression finishes) while payload *k* is
    /// still in flight. Competing-traffic events due before the offer are
    /// injected first so FIFO ordering stays correct; callers should issue
    /// transfers in roughly non-decreasing `start` order per link.
    pub fn transfer_at(
        &mut self,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        start: SimTime,
    ) -> TransferResult {
        assert!(src < self.topology.n_workers() && dst < self.topology.n_workers());
        assert_ne!(src, dst, "self-transfer");
        let sent_at = start.max(self.now);
        self.inject_traffic_until(sent_at);
        // Uplink: src → switch.
        let at_switch = match self.topology.uplinks[src].send_reliable(sent_at, bytes) {
            Offer::Accepted { arrival, .. } => arrival,
            Offer::Dropped => unreachable!("reliable transfers are never dropped"),
        };
        // Competing traffic that lands on the downlink before the message
        // reaches the switch must be queued ahead of it (FIFO).
        self.inject_traffic_until(at_switch);
        // Downlink: switch → dst (store-and-forward).
        let arrival = match self.topology.downlinks[dst].send_reliable(at_switch, bytes) {
            Offer::Accepted { arrival, .. } => arrival,
            Offer::Dropped => unreachable!(),
        };
        TransferResult {
            src,
            dst,
            bytes,
            sent_at,
            arrival,
        }
    }

    /// Inject traffic events up to `t` WITHOUT moving the public clock —
    /// used for correct FIFO interleaving inside multi-hop transfers.
    fn inject_traffic_until(&mut self, t: SimTime) {
        loop {
            let next = self
                .traffic
                .iter()
                .enumerate()
                .map(|(i, tr)| (tr.next_time(), i))
                .min();
            match next {
                Some((at, i)) if at <= t => {
                    let fire_at = at.max(self.now);
                    self.traffic[i].fire(
                        fire_at,
                        &mut self.topology.uplinks,
                        &mut self.topology.downlinks,
                    );
                }
                _ => break,
            }
        }
    }

    /// A parallel phase: all `transfers` start now; the clock advances to
    /// the slowest arrival. This is the building block for collectives
    /// (each ring step is one phase).
    pub fn phase(&mut self, transfers: &[(NodeId, NodeId, u64)]) -> PhaseResult {
        let mut results = Vec::with_capacity(transfers.len());
        for &(src, dst, bytes) in transfers {
            results.push(self.transfer(src, dst, bytes));
        }
        let makespan = results
            .iter()
            .map(|r| r.arrival)
            .max()
            .unwrap_or(self.now);
        self.advance_to(makespan);
        PhaseResult {
            transfers: results,
            makespan,
        }
    }

    /// Reset all dynamic state (links, clock). Traffic generators keep
    /// their configuration but restart their schedules.
    pub fn reset(&mut self) {
        self.topology.reset();
        self.now = SimTime::ZERO;
        // Traffic generators are restarted by rebuilding their start state:
        // their next_fire is monotonic, so a reset sim requires fresh
        // generators — callers that need that rebuild the NetSim instead.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::link::LinkConfig;
    use crate::netsim::schedule::{mbps, BandwidthSchedule};
    use crate::netsim::traffic::{LinkRef, TrafficPattern};

    fn star(n: usize, bw_mbps: f64, prop_ms: u64) -> StarTopology {
        StarTopology::constant(n, mbps(bw_mbps), SimTime::from_millis(prop_ms))
    }

    #[test]
    fn single_transfer_time_is_two_hops() {
        let mut sim = NetSim::quiet(star(2, 100.0, 1));
        // 1.25 MB: serialize 100 ms on uplink + 1 ms prop, again on downlink.
        let r = sim.transfer(0, 1, 1_250_000);
        assert_eq!(r.rtt(), SimTime::from_millis(202));
    }

    #[test]
    fn transfer_at_future_start_matches_idle_transfer() {
        // Offering in the future on an idle link: same serialization, just
        // shifted; the clock does not move.
        let mut sim = NetSim::quiet(star(2, 100.0, 1));
        let r = sim.transfer_at(0, 1, 1_250_000, SimTime::from_millis(500));
        assert_eq!(r.sent_at, SimTime::from_millis(500));
        assert_eq!(r.rtt(), SimTime::from_millis(202));
        assert_eq!(sim.now(), SimTime::ZERO);
    }

    #[test]
    fn transfer_at_past_start_clamps_to_now() {
        let mut sim = NetSim::quiet(star(2, 100.0, 1));
        sim.advance_to(SimTime::from_secs_f64(1.0));
        let r = sim.transfer_at(0, 1, 1_250_000, SimTime::ZERO);
        assert_eq!(r.sent_at, SimTime::from_secs_f64(1.0));
    }

    #[test]
    fn staggered_transfer_at_queue_fifo_on_shared_link() {
        // Two messages on the same uplink offered at staggered future
        // times: the second serializes behind the first.
        let mut sim = NetSim::quiet(star(2, 100.0, 0));
        let a = sim.transfer_at(0, 1, 1_250_000, SimTime::from_millis(100));
        let b = sim.transfer_at(0, 1, 1_250_000, SimTime::from_millis(150));
        assert_eq!(a.arrival, SimTime::from_millis(300));
        // b queues on the uplink until 200 ms, then 100 ms per hop.
        assert_eq!(b.arrival, SimTime::from_millis(400));
    }

    #[test]
    fn phase_advances_to_makespan() {
        let mut sim = NetSim::quiet(star(4, 100.0, 1));
        let res = sim.phase(&[(0, 1, 1_250_000), (2, 3, 2_500_000)]);
        assert_eq!(res.transfers.len(), 2);
        // slower transfer: 2.5 MB → 200 ms per hop + 2 ms prop
        assert_eq!(res.makespan, SimTime::from_millis(402));
        assert_eq!(sim.now(), res.makespan);
    }

    #[test]
    fn parallel_disjoint_transfers_do_not_interfere() {
        let mut sim = NetSim::quiet(star(4, 100.0, 0));
        let res = sim.phase(&[(0, 1, 1_250_000), (2, 3, 1_250_000)]);
        for t in &res.transfers {
            assert_eq!(t.rtt(), SimTime::from_millis(200));
        }
    }

    #[test]
    fn shared_downlink_serializes_fifo() {
        let mut sim = NetSim::quiet(star(3, 100.0, 0));
        // Both 0→2 and 1→2 share downlink of 2.
        let res = sim.phase(&[(0, 2, 1_250_000), (1, 2, 1_250_000)]);
        let rtts: Vec<_> = res.transfers.iter().map(|t| t.rtt()).collect();
        // First message: 200 ms. Second queues behind it on the downlink:
        // its uplink finishes at 100 ms, downlink busy until 200 ms, so it
        // arrives at 300 ms.
        assert_eq!(rtts[0], SimTime::from_millis(200));
        assert_eq!(rtts[1], SimTime::from_millis(300));
    }

    #[test]
    fn competing_traffic_inflates_rtt() {
        let topo = star(2, 100.0, 1);
        let quiet_rtt = {
            let mut sim = NetSim::quiet(topo.clone());
            sim.transfer(0, 1, 1_250_000).rtt()
        };
        let busy_rtt = {
            let traffic = CompetingTraffic::new(
                TrafficPattern::Constant {
                    rate_bps: mbps(50.0),
                    tick: SimTime::from_millis(10),
                },
                vec![LinkRef::Up(0)],
                1,
            );
            let mut sim = NetSim::new(NetSimConfig {
                topology: topo,
                traffic: vec![traffic],
            });
            // Let the competing flow build a backlog for 1 s.
            sim.advance_to(SimTime::from_secs_f64(1.0));
            sim.transfer(0, 1, 1_250_000).rtt()
        };
        assert!(
            busy_rtt > quiet_rtt,
            "busy {busy_rtt} should exceed quiet {quiet_rtt}"
        );
    }

    #[test]
    fn traffic_injection_is_capped_by_drop_tail() {
        // Offered load 2× capacity; backlog must stay bounded by the buffer.
        let cfg = LinkConfig::new(
            BandwidthSchedule::constant(mbps(10.0)),
            SimTime::from_millis(1),
        )
        .with_buffer(1 << 20);
        let topo = StarTopology::uniform(2, cfg);
        let traffic = CompetingTraffic::new(
            TrafficPattern::Constant {
                rate_bps: mbps(20.0),
                tick: SimTime::from_millis(5),
            },
            vec![LinkRef::Up(0)],
            2,
        );
        let mut sim = NetSim::new(NetSimConfig {
            topology: topo,
            traffic: vec![traffic],
        });
        sim.advance_to(SimTime::from_secs_f64(30.0));
        let up = &sim.topology.uplinks[0];
        assert!(up.stats.dropped_bytes > 0, "expected drops under overload");
        assert!(
            up.backlog_bytes(sim.now()) <= (1 << 20) + 65_536,
            "backlog should be bounded by buffer"
        );
    }

    #[test]
    fn advance_to_is_monotonic() {
        let mut sim = NetSim::quiet(star(2, 100.0, 1));
        sim.advance_to(SimTime::from_secs_f64(1.0));
        assert_eq!(sim.now(), SimTime::from_secs_f64(1.0));
        sim.advance_by(SimTime::from_secs_f64(0.5));
        assert_eq!(sim.now(), SimTime::from_secs_f64(1.5));
    }

    #[test]
    #[should_panic(expected = "time going backwards")]
    fn advance_backwards_panics() {
        let mut sim = NetSim::quiet(star(2, 100.0, 1));
        sim.advance_to(SimTime::from_secs_f64(1.0));
        sim.advance_to(SimTime::from_secs_f64(0.5));
    }

    #[test]
    fn conservation_delivered_plus_dropped_equals_offered() {
        let traffic = CompetingTraffic::new(
            TrafficPattern::Constant {
                rate_bps: mbps(200.0),
                tick: SimTime::from_millis(10),
            },
            vec![LinkRef::Up(0)],
            3,
        );
        let topo = star(2, 100.0, 1);
        let mut sim = NetSim::new(NetSimConfig {
            topology: topo,
            traffic: vec![traffic],
        });
        sim.advance_to(SimTime::from_secs_f64(10.0));
        let up = &sim.topology.uplinks[0];
        let offered = up.stats.delivered_bytes + up.stats.dropped_bytes;
        // All injected bytes are accounted as delivered or dropped.
        assert!(offered > 0);
    }

    #[test]
    fn rtt_grows_linearly_beyond_serialization_floor() {
        // Fig. 2 shape: for a FIFO path, RTT(S) = 2·(S/B) + 2·prop; doubling
        // S beyond the floor roughly doubles RTT − 2·prop.
        let mut sim = NetSim::quiet(star(2, 100.0, 5));
        let r1 = sim.transfer(0, 1, 1_250_000);
        let mut sim2 = NetSim::quiet(star(2, 100.0, 5));
        let r2 = sim2.transfer(0, 1, 2_500_000);
        let prop2 = SimTime::from_millis(10);
        let ser1 = r1.rtt() - prop2;
        let ser2 = r2.rtt() - prop2;
        assert_eq!(ser2.as_nanos(), 2 * ser1.as_nanos());
    }
}
