//! Time-varying link bandwidth: piecewise-constant schedules.
//!
//! Scenario 1 uses [`BandwidthSchedule::constant`]; scenario 2 (Fig. 7) uses
//! [`BandwidthSchedule::stepped`] (2000 → 200 Mbps in −200 Mbps steps);
//! scenario 3 composes a constant schedule with competing traffic
//! ([`super::traffic`]) and optional [`BandwidthSchedule::piecewise`] shaping.

use super::time::SimTime;

/// Megabits per second → bits per second.
pub fn mbps(x: f64) -> f64 {
    x * 1e6
}

/// Gigabits per second → bits per second.
pub fn gbps(x: f64) -> f64 {
    x * 1e9
}

/// A piecewise-constant bandwidth schedule. Segment `i` is active on
/// `[starts[i], starts[i+1])`; the last segment extends to infinity.
#[derive(Clone, Debug)]
pub struct BandwidthSchedule {
    /// Segment start times, strictly increasing, `starts[0] == 0`.
    starts: Vec<SimTime>,
    /// Bits per second for each segment; all positive.
    rates: Vec<f64>,
}

impl BandwidthSchedule {
    /// Constant bandwidth forever.
    pub fn constant(bits_per_sec: f64) -> Self {
        assert!(bits_per_sec > 0.0);
        BandwidthSchedule {
            starts: vec![SimTime::ZERO],
            rates: vec![bits_per_sec],
        }
    }

    /// Explicit piecewise schedule from `(start, bits_per_sec)` pairs.
    pub fn piecewise(segments: Vec<(SimTime, f64)>) -> Self {
        assert!(!segments.is_empty(), "empty schedule");
        assert_eq!(segments[0].0, SimTime::ZERO, "first segment must start at 0");
        let mut starts = Vec::with_capacity(segments.len());
        let mut rates = Vec::with_capacity(segments.len());
        for (i, &(t, r)) in segments.iter().enumerate() {
            assert!(r > 0.0, "non-positive rate in segment {i}");
            if i > 0 {
                assert!(t > starts[i - 1], "segment starts must increase");
            }
            starts.push(t);
            rates.push(r);
        }
        BandwidthSchedule { starts, rates }
    }

    /// The paper's scenario-2 shape: start at `from_bps`, step by
    /// `step_bps` every `interval` until reaching `to_bps` (inclusive),
    /// then hold. `step_bps` may be negative (degradation) or positive.
    pub fn stepped(from_bps: f64, to_bps: f64, step_bps: f64, interval: SimTime) -> Self {
        assert!(step_bps != 0.0 && interval > SimTime::ZERO);
        assert!(
            (to_bps - from_bps) * step_bps >= 0.0,
            "step direction must move from → to"
        );
        let mut segments = vec![(SimTime::ZERO, from_bps)];
        let mut bw = from_bps;
        let mut t = SimTime::ZERO;
        loop {
            let next = bw + step_bps;
            let done = if step_bps < 0.0 { next < to_bps } else { next > to_bps };
            if done {
                break;
            }
            bw = next;
            t += interval;
            segments.push((t, bw));
        }
        BandwidthSchedule::piecewise(segments)
    }

    /// Bandwidth (bits/s) in effect at time `t`.
    pub fn rate_at(&self, t: SimTime) -> f64 {
        // Binary search for the last start <= t.
        let idx = match self.starts.binary_search(&t) {
            Ok(i) => i,
            Err(0) => 0,
            Err(i) => i - 1,
        };
        self.rates[idx]
    }

    /// Time at which a transmission of `bytes` finishes if it starts at
    /// `start` and consumes the link's full (time-varying) rate.
    pub fn finish_time(&self, start: SimTime, bytes: u64) -> SimTime {
        let mut remaining_bits = bytes as f64 * 8.0;
        let mut t = start;
        if remaining_bits <= 0.0 {
            return start;
        }
        loop {
            let seg = match self.starts.binary_search(&t) {
                Ok(i) => i,
                Err(0) => 0,
                Err(i) => i - 1,
            };
            let rate = self.rates[seg];
            let seg_end = self.starts.get(seg + 1).copied();
            let dt_to_end = match seg_end {
                Some(e) if e > t => (e - t).as_secs_f64(),
                Some(_) => 0.0,
                None => f64::INFINITY,
            };
            let bits_in_seg = rate * dt_to_end;
            if bits_in_seg >= remaining_bits || seg_end.is_none() {
                let dt = remaining_bits / rate;
                return t + SimTime::from_secs_f64(dt);
            }
            remaining_bits -= bits_in_seg;
            t = seg_end.unwrap();
        }
    }

    /// Bytes the link can carry over `[t0, t1)` at full rate — the
    /// capacity integral, the analytic inverse of
    /// [`BandwidthSchedule::finish_time`] (property-tested against it).
    /// Gives the link-limited lower bound on transfer time under
    /// time-varying bandwidth.
    pub fn bytes_between(&self, t0: SimTime, t1: SimTime) -> f64 {
        assert!(t1 >= t0, "bytes_between: t1 < t0");
        let mut bits = 0.0;
        let mut t = t0;
        while t < t1 {
            let seg = match self.starts.binary_search(&t) {
                Ok(i) => i,
                Err(0) => 0,
                Err(i) => i - 1,
            };
            let seg_end = self
                .starts
                .get(seg + 1)
                .copied()
                .filter(|&e| e < t1)
                .unwrap_or(t1);
            bits += self.rates[seg] * (seg_end - t).as_secs_f64();
            t = seg_end;
        }
        bits / 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_rate() {
        let s = BandwidthSchedule::constant(mbps(100.0));
        assert_eq!(s.rate_at(SimTime::ZERO), 100e6);
        assert_eq!(s.rate_at(SimTime::from_secs_f64(1e6)), 100e6);
        // 12.5 MB at 100 Mbps = 1 s
        let fin = s.finish_time(SimTime::from_secs_f64(2.0), 12_500_000);
        assert!((fin.as_secs_f64() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_finish_immediately() {
        let s = BandwidthSchedule::constant(mbps(1.0));
        assert_eq!(s.finish_time(SimTime::from_millis(5), 0), SimTime::from_millis(5));
    }

    #[test]
    fn piecewise_rate_lookup() {
        let s = BandwidthSchedule::piecewise(vec![
            (SimTime::ZERO, mbps(100.0)),
            (SimTime::from_secs_f64(10.0), mbps(50.0)),
        ]);
        assert_eq!(s.rate_at(SimTime::from_secs_f64(5.0)), 100e6);
        assert_eq!(s.rate_at(SimTime::from_secs_f64(10.0)), 50e6);
        assert_eq!(s.rate_at(SimTime::from_secs_f64(20.0)), 50e6);
    }

    #[test]
    fn finish_time_spans_segments() {
        // 100 Mbps for 1 s, then 50 Mbps. Transfer 25 MB starting at t=0:
        // first second carries 12.5 MB, remaining 12.5 MB at 50 Mbps takes 2 s.
        let s = BandwidthSchedule::piecewise(vec![
            (SimTime::ZERO, mbps(100.0)),
            (SimTime::from_secs_f64(1.0), mbps(50.0)),
        ]);
        let fin = s.finish_time(SimTime::ZERO, 25_000_000);
        assert!((fin.as_secs_f64() - 3.0).abs() < 1e-6, "{fin}");
    }

    #[test]
    fn stepped_descends() {
        let s = BandwidthSchedule::stepped(
            mbps(2000.0),
            mbps(200.0),
            -mbps(200.0),
            SimTime::from_secs_f64(60.0),
        );
        assert_eq!(s.rate_at(SimTime::ZERO), mbps(2000.0));
        assert_eq!(s.rate_at(SimTime::from_secs_f64(61.0)), mbps(1800.0));
        // after 9 steps → 200 Mbps, holds forever
        assert_eq!(s.rate_at(SimTime::from_secs_f64(60.0 * 9.0)), mbps(200.0));
        assert_eq!(s.rate_at(SimTime::from_secs_f64(1e5)), mbps(200.0));
    }

    #[test]
    fn stepped_ascending_works_too() {
        let s = BandwidthSchedule::stepped(
            mbps(100.0),
            mbps(300.0),
            mbps(100.0),
            SimTime::from_secs_f64(1.0),
        );
        assert_eq!(s.rate_at(SimTime::from_secs_f64(0.5)), mbps(100.0));
        assert_eq!(s.rate_at(SimTime::from_secs_f64(2.5)), mbps(300.0));
    }

    #[test]
    fn finish_time_consistent_with_rate_integral() {
        let s = BandwidthSchedule::stepped(
            mbps(1000.0),
            mbps(200.0),
            -mbps(200.0),
            SimTime::from_secs_f64(2.0),
        );
        // Verify finish_time by numerically integrating the rate.
        let start = SimTime::from_secs_f64(1.0);
        let bytes = 2_000_000_000u64; // 2 GB, spans all steps
        let fin = s.finish_time(start, bytes);
        let mut bits = 0.0;
        let mut t = start.as_secs_f64();
        let dt: f64 = 1e-3;
        while t < fin.as_secs_f64() {
            bits += s.rate_at(SimTime::from_secs_f64(t)) * dt.min(fin.as_secs_f64() - t);
            t += dt;
        }
        let rel = (bits - bytes as f64 * 8.0).abs() / (bytes as f64 * 8.0);
        assert!(rel < 1e-2, "rel err {rel}");
    }

    #[test]
    #[should_panic]
    fn piecewise_rejects_nonzero_first_start() {
        BandwidthSchedule::piecewise(vec![(SimTime::from_millis(1), 1e6)]);
    }

    #[test]
    fn bytes_between_constant_rate() {
        let s = BandwidthSchedule::constant(mbps(100.0));
        // 100 Mbps over 1 s = 12.5 MB
        let b = s.bytes_between(SimTime::from_secs_f64(2.0), SimTime::from_secs_f64(3.0));
        assert!((b - 12_500_000.0).abs() < 1.0, "{b}");
        assert_eq!(s.bytes_between(SimTime::ZERO, SimTime::ZERO), 0.0);
    }

    #[test]
    fn bytes_between_spans_segments() {
        let s = BandwidthSchedule::piecewise(vec![
            (SimTime::ZERO, mbps(100.0)),
            (SimTime::from_secs_f64(1.0), mbps(50.0)),
        ]);
        // [0.5, 2.5): 0.5 s at 100 Mbps + 1.5 s at 50 Mbps = 6.25 + 9.375 MB
        let b = s.bytes_between(
            SimTime::from_secs_f64(0.5),
            SimTime::from_secs_f64(2.5),
        );
        assert!((b - 15_625_000.0).abs() < 1.0, "{b}");
    }

    #[test]
    fn bytes_between_is_inverse_of_finish_time() {
        let s = BandwidthSchedule::stepped(
            mbps(1000.0),
            mbps(200.0),
            -mbps(200.0),
            SimTime::from_secs_f64(2.0),
        );
        let start = SimTime::from_secs_f64(1.0);
        let bytes = 500_000_000u64;
        let fin = s.finish_time(start, bytes);
        let carried = s.bytes_between(start, fin);
        let rel = (carried - bytes as f64).abs() / bytes as f64;
        assert!(rel < 1e-6, "rel err {rel}");
    }
}
