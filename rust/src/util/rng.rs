//! Deterministic pseudo-random number generation (PCG64-DXSM) plus the
//! distributions the simulator and tests need.
//!
//! Every stochastic component in the crate takes an explicit seed so that
//! experiments are reproducible bit-for-bit (`DESIGN.md` §5).

/// A PCG64-DXSM generator: 128-bit LCG state with a double-xorshift-multiply
/// output permutation. Small, fast, and statistically solid for simulation.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Single-argument constructor for when stream separation is not needed.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // DXSM output function on the *current* state, then advance.
        let mut hi = (self.state >> 64) as u64;
        let lo = ((self.state as u64) | 1) as u64;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi = hi.wrapping_mul(lo);
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        hi
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` using Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (uses one cached value would add
    /// state; we just burn two uniforms — this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fill a slice with standard-normal f32s (used for synthetic gradients).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = mean + std * self.normal() as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(9, 1);
        let mut b = Pcg64::new(9, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::seeded(4);
        let mut counts = [0u32; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // expected 10_000; allow 5% deviation
            assert!((9_500..10_500).contains(&c), "count {c}");
        }
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Pcg64::seeded(5);
        for _ in 0..100 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(6);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::seeded(7);
        let n = 200_000;
        let lambda = 2.5;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(8);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left input ordered");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..50 {
            let s = r.sample_indices(100, 17);
            assert_eq!(s.len(), 17);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 17);
            assert!(s.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn range_u64_inclusive() {
        let mut r = Pcg64::seeded(10);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range_u64(3, 7);
            assert!((3..=7).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 7;
        }
        assert!(seen_lo && seen_hi);
    }
}
