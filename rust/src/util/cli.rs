//! Tiny command-line parser: subcommands, `--flag`, `--key value` /
//! `--key=value` options, positionals, typed getters, and generated help.

use std::collections::BTreeMap;
use std::fmt;

/// Declarative spec for one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` for boolean flags (no value).
    pub flag: bool,
    pub default: Option<&'static str>,
}

/// Declarative spec for a subcommand.
#[derive(Clone, Debug)]
pub struct CmdSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
    /// Names of expected positional args (for help only; extras allowed).
    pub positionals: Vec<&'static str>,
}

/// Parsed arguments for a matched subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<f64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected a number, got `{s}`"))),
        }
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<usize>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected an integer, got `{s}`"))),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<u64>()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: expected an integer, got `{s}`"))),
        }
    }
}

/// A CLI application: a set of subcommands.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<CmdSpec>,
}

impl Cli {
    /// Parse `argv[1..]`. Returns `Ok(None)` if help was requested (already
    /// printed to stdout by the caller via [`Cli::help`]).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let cmd_name = argv
            .first()
            .ok_or_else(|| CliError(format!("missing subcommand\n\n{}", self.help())))?;
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError(self.help()));
        }
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                CliError(format!("unknown subcommand `{cmd_name}`\n\n{}", self.help()))
            })?;
        let mut args = Args {
            command: spec.name.to_string(),
            ..Default::default()
        };
        // seed defaults
        for opt in &spec.opts {
            if let Some(d) = opt.default {
                args.values.insert(opt.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.cmd_help(spec)));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let opt = spec.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    CliError(format!(
                        "unknown option `--{name}` for `{}`\n\n{}",
                        spec.name,
                        self.cmd_help(spec)
                    ))
                })?;
                if opt.flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag `--{name}` takes no value")));
                    }
                    args.flags.insert(name, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("`--{name}` needs a value")))?
                        }
                    };
                    args.values.insert(name, val);
                }
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Top-level help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.bin, self.about, self.bin);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.help));
        }
        s.push_str(&format!("\nRun `{} <command> --help` for command options.\n", self.bin));
        s
    }

    /// Per-command help text.
    pub fn cmd_help(&self, spec: &CmdSpec) -> String {
        let mut s = format!("{} {} — {}\n\nUSAGE:\n  {} {}", self.bin, spec.name, spec.help, self.bin, spec.name);
        for p in &spec.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [options]\n\nOPTIONS:\n");
        for o in &spec.opts {
            let mut left = format!("--{}", o.name);
            if !o.flag {
                left.push_str(" <v>");
            }
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {:<24} {}{}\n", left, o.help, default));
        }
        s
    }
}

/// Shorthand for building an option spec.
pub fn opt(name: &'static str, help: &'static str, default: Option<&'static str>) -> OptSpec {
    OptSpec {
        name,
        help,
        flag: false,
        default,
    }
}

/// Shorthand for building a boolean flag spec.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        flag: true,
        default: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "netsenseml",
            about: "test",
            commands: vec![CmdSpec {
                name: "train",
                help: "run training",
                opts: vec![
                    opt("model", "model name", Some("resnet18")),
                    opt("steps", "step count", None),
                    flag("verbose", "log more"),
                ],
                positionals: vec!["config"],
            }],
        }
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&sv(&["train"])).unwrap();
        assert_eq!(a.get("model"), Some("resnet18"));
        let a = cli().parse(&sv(&["train", "--model", "vgg16"])).unwrap();
        assert_eq!(a.get("model"), Some("vgg16"));
        let a = cli().parse(&sv(&["train", "--model=vgg16"])).unwrap();
        assert_eq!(a.get("model"), Some("vgg16"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = cli()
            .parse(&sv(&["train", "cfg.toml", "--verbose"]))
            .unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["cfg.toml"]);
        assert!(!cli().parse(&sv(&["train"])).unwrap().flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = cli().parse(&sv(&["train", "--steps", "100"])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), Some(100));
        let a = cli().parse(&sv(&["train", "--steps", "abc"])).unwrap();
        assert!(a.get_usize("steps").is_err());
        let a = cli().parse(&sv(&["train"])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), None);
    }

    #[test]
    fn errors() {
        assert!(cli().parse(&sv(&[])).is_err());
        assert!(cli().parse(&sv(&["nope"])).is_err());
        assert!(cli().parse(&sv(&["train", "--bogus", "1"])).is_err());
        assert!(cli().parse(&sv(&["train", "--model"])).is_err());
        assert!(cli().parse(&sv(&["train", "--verbose=1"])).is_err());
    }

    #[test]
    fn help_lists_commands() {
        let h = cli().help();
        assert!(h.contains("train"));
        assert!(h.contains("run training"));
    }
}
