//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for metric dumps. Supports the full JSON
//! grammar except `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj[key]` convenience; `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // ---- writer ----------------------------------------------------------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    } else {
        // JSON has no inf/nan; emit null (matches python json.dumps default-ish behavior of erroring; we choose null)
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- convenience constructors -------------------------------------------

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from `(key, value)` pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// ---- parser ---------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"m":{"shapes":[[2,3],[4]],"name":"grad_step","ok":true,"x":1.5}}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{0001}".into());
        let s = j.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 7, "s": "x", "b": false, "a": []}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
        assert!(j.get("missing").is_none());
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }

    #[test]
    fn obj_builder() {
        let j = obj(vec![("a", 1usize.into()), ("b", "x".into())]);
        assert_eq!(j.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn integers_write_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }
}
