//! TOML-subset parser for experiment configuration files.
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer / float / bool / homogeneous-array values, `#` comments,
//! and bare or quoted keys. Flattened into `section.sub.key` paths — exactly
//! the surface `config/` needs. Unsupported TOML (multi-line strings, tables
//! in arrays, datetimes) is rejected with a line-numbered error.

use std::collections::BTreeMap;
use std::fmt;

/// A TOML scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    /// Numeric coercion: ints read as floats too.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: dotted-path → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut entries = BTreeMap::new();
        let mut prefix = String::new();
        for (i, raw) in src.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let inner = rest.strip_suffix(']').ok_or_else(|| TomlError {
                    line: lineno,
                    message: "unterminated section header".into(),
                })?;
                let inner = inner.trim();
                if inner.is_empty() || inner.starts_with('[') {
                    return Err(TomlError {
                        line: lineno,
                        message: "unsupported or empty section header".into(),
                    });
                }
                prefix = inner.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| TomlError {
                line: lineno,
                message: "expected `key = value`".into(),
            })?;
            let key = parse_key(line[..eq].trim()).map_err(|m| TomlError {
                line: lineno,
                message: m,
            })?;
            let val_src = line[eq + 1..].trim();
            let value = parse_value(val_src).map_err(|m| TomlError {
                line: lineno,
                message: m,
            })?;
            let path = if prefix.is_empty() {
                key
            } else {
                format!("{prefix}.{key}")
            };
            if entries.insert(path.clone(), value).is_some() {
                return Err(TomlError {
                    line: lineno,
                    message: format!("duplicate key `{path}`"),
                });
            }
        }
        Ok(TomlDoc { entries })
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(TomlValue::as_str)
    }

    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(TomlValue::as_i64)
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(TomlValue::as_f64)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(TomlValue::as_bool)
    }

    /// Keys under a section prefix (e.g. `"net"` → `net.*`).
    pub fn section_keys<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let want = format!("{prefix}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&want))
            .map(|k| k.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => {
                escaped = !escaped;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = false;
    }
    line
}

fn parse_key(s: &str) -> Result<String, String> {
    if s.is_empty() {
        return Err("empty key".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated quoted key".to_string())?;
        return Ok(inner.to_string());
    }
    if s.chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
    {
        Ok(s.to_string())
    } else {
        Err(format!("invalid bare key `{s}`"))
    }
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        return parse_basic_string(rest).map(TomlValue::Str);
    }
    if let Some(rest) = s.strip_prefix('\'') {
        let inner = rest
            .strip_suffix('\'')
            .ok_or_else(|| "unterminated literal string".to_string())?;
        if inner.contains('\'') {
            return Err("unexpected quote inside literal string".into());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        return parse_array(s);
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn parse_basic_string(rest: &str) -> Result<String, String> {
    // `rest` is everything after the opening quote; the closing quote must
    // end the value (single-line only).
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let tail: String = chars.collect();
                if !tail.trim().is_empty() {
                    return Err("trailing data after string".into());
                }
                return Ok(out);
            }
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('r') => out.push('\r'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(format!("bad escape `\\{other:?}`")),
            },
            c => out.push(c),
        }
    }
    Err("unterminated string".into())
}

fn parse_array(s: &str) -> Result<TomlValue, String> {
    let inner = s
        .strip_prefix('[')
        .and_then(|x| x.strip_suffix(']'))
        .ok_or_else(|| "unterminated array".to_string())?;
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    let bytes = inner.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' => in_str = !in_str,
            b'[' if !in_str => depth += 1,
            b']' if !in_str => depth = depth.saturating_sub(1),
            b',' if !in_str && depth == 0 => {
                let piece = inner[start..i].trim();
                if !piece.is_empty() {
                    items.push(parse_value(piece)?);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    let piece = inner[start..].trim();
    if !piece.is_empty() {
        items.push(parse_value(piece)?);
    }
    Ok(TomlValue::Arr(items))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let doc = TomlDoc::parse(
            r#"
# experiment
seed = 42
name = "resnet18"
[net]
bandwidth_mbps = 200.5
workers = 8
shaped = true
[net.queue]
bytes = 1_000_000
"#,
        )
        .unwrap();
        assert_eq!(doc.get_i64("seed"), Some(42));
        assert_eq!(doc.get_str("name"), Some("resnet18"));
        assert_eq!(doc.get_f64("net.bandwidth_mbps"), Some(200.5));
        assert_eq!(doc.get_i64("net.workers"), Some(8));
        assert_eq!(doc.get_bool("net.shaped"), Some(true));
        assert_eq!(doc.get_i64("net.queue.bytes"), Some(1_000_000));
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
    }

    #[test]
    fn arrays() {
        let doc = TomlDoc::parse(r#"bw = [200, 500, 800]"#).unwrap();
        let arr = doc.get("bw").unwrap().as_arr().unwrap();
        assert_eq!(
            arr.iter().map(|v| v.as_i64().unwrap()).collect::<Vec<_>>(),
            vec![200, 500, 800]
        );
        let doc = TomlDoc::parse(r#"s = ["a", "b,c"]"#).unwrap();
        let arr = doc.get("s").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_str(), Some("b,c"));
    }

    #[test]
    fn comments_and_strings() {
        let doc = TomlDoc::parse("x = \"a # not comment\" # real comment").unwrap();
        assert_eq!(doc.get_str("x"), Some("a # not comment"));
    }

    #[test]
    fn string_escapes() {
        let doc = TomlDoc::parse(r#"x = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(doc.get_str("x"), Some("a\nb\t\"c\""));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = TomlDoc::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = TomlDoc::parse("[unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn floats_and_exponents() {
        let doc = TomlDoc::parse("a = 1.5\nb = 2e3\nc = -0.25").unwrap();
        assert_eq!(doc.get_f64("a"), Some(1.5));
        assert_eq!(doc.get_f64("b"), Some(2000.0));
        assert_eq!(doc.get_f64("c"), Some(-0.25));
    }

    #[test]
    fn section_keys_iterates() {
        let doc = TomlDoc::parse("[a]\nx = 1\ny = 2\n[b]\nz = 3").unwrap();
        let keys: Vec<_> = doc.section_keys("a").collect();
        assert_eq!(keys, vec!["a.x", "a.y"]);
    }
}
