//! Leveled, timestamped stderr logger with a global verbosity switch.
//!
//! Deliberately minimal: the experiment runners print their structured
//! results to stdout; the logger carries progress/diagnostics on stderr.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global verbosity (messages above this level are dropped).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// True if `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit a log line (used by the macros; prefer those).
pub fn emit(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    let secs = t0.elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{secs:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
        assert!(enabled(Level::Info));
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Debug);
        log_error!("e {}", 1);
        log_warn!("w");
        log_info!("i {}", "x");
        log_debug!("d");
        set_level(Level::Info);
    }
}
