//! `anyhow`-compatible error substrate for the offline build (the same
//! pattern as the other [`crate::util`] replacements): a context-chained
//! [`Error`], the [`Result`] alias, the [`Context`] extension trait for
//! `Result`/`Option`, and the [`anyhow!`](crate::anyhow) /
//! [`bail!`](crate::bail) macros.
//!
//! Display semantics mirror `anyhow`: `{e}` prints the outermost message,
//! `{e:#}` prints the full `outer: inner: ...` chain, and `{e:?}` prints
//! the chain as `Caused by` paragraphs.
//!
//! ```
//! use netsenseml::util::error::{Context, Result};
//! use netsenseml::{anyhow, bail};
//!
//! fn parse(s: &str) -> Result<u32> {
//!     if s.is_empty() {
//!         bail!("empty input");
//!     }
//!     s.parse::<u32>().with_context(|| format!("parsing `{s}`"))
//! }
//!
//! assert_eq!(parse("42").unwrap(), 42);
//! let e = parse("x").unwrap_err();
//! assert_eq!(format!("{e}"), "parsing `x`");
//! assert!(format!("{e:#}").contains("invalid digit"));
//! let _ = anyhow!("standalone {}", "error");
//! ```

use std::fmt;

/// A chain of error messages, outermost context first.
#[derive(Clone)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, msg: impl fmt::Display) -> Error {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like `anyhow::Error`, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulted to [`Error`], as in `anyhow`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| e.into().context(msg))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (drop-in for `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`] (drop-in for `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

// Path-based re-exports so call sites can `use crate::util::error::{anyhow,
// bail}` alongside the types.
pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_outermost_only() {
        let e: Error = Error::msg("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(read().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(7u32).context("fine").unwrap(), 7);
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {} at {}", 3, "site");
        assert_eq!(format!("{e}"), "bad value 3 at site");
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope: {}", 1 + 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(format!("{}", f(true).unwrap_err()), "nope: 2");
    }

    #[test]
    fn source_chain_is_captured() {
        #[derive(Debug)]
        struct Outer(std::io::Error);
        impl fmt::Display for Outer {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "outer layer")
            }
        }
        impl std::error::Error for Outer {
            fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
                Some(&self.0)
            }
        }
        let e: Error = Outer(io_err()).into();
        assert_eq!(format!("{e:#}"), "outer layer: file missing");
    }
}
