//! Offline-environment substrates: the small, dependency-free replacements
//! for the crates that are unavailable in this build environment
//! (`anyhow`, `rand`, `serde_json`, `toml`, `clap`, `criterion`, logging).
//!
//! Each submodule is a self-contained, tested implementation of exactly the
//! surface the rest of the crate needs — see `DESIGN.md` §2. [`poller`]
//! is the same idea applied to async I/O: a thread-per-core epoll event
//! loop built on a thin FFI shim instead of `mio`/`tokio`.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod log;
pub mod poller;
pub mod rng;
pub mod stats;
pub mod toml;
