//! Thread-per-core epoll event loop — the shared I/O substrate behind
//! the socket transports.
//!
//! Before this module, [`crate::transport::TcpTransport`] spawned one
//! blocking reader thread per peer (O(N²) threads across an N-worker
//! box) and [`crate::transport::ShapedTransport`] burned whole threads in
//! `std::thread::sleep` to pace tokens. The poller replaces both with a
//! **fixed pool of event-loop threads** (default `min(cores, 8)`, see
//! [`configure_threads`]) that own every registered socket:
//!
//! - **Reads** run as per-connection state machines: the loop parses the
//!   8-byte length prefix incrementally ([`parse_frame_header`]), grows a
//!   pooled payload buffer in `READ_CHUNK_BYTES` steps as bytes
//!   actually arrive, and hands each complete frame to the owning
//!   [`ConnHandle`] through a mutex-protected inbox. Consumed frame
//!   buffers are recycled back to the loop, so the steady state allocates
//!   nothing on either side.
//! - **Writes** stay on the *caller's* thread (vectored, zero-copy); the
//!   loop only arms `EPOLLOUT` on demand ([`ConnHandle::request_writable`])
//!   and signals the caller's write gate when the kernel buffer drains.
//! - **Timers** ([`sleep_until`]) let shaping and fault layers express
//!   pacing deadlines as event-loop timers instead of sleeping threads.
//!
//! A dead socket fails fast: the loop marks the connection's inbox dead
//! and wakes every waiter immediately, so a pending
//! [`ConnHandle::recv_frame_into`] returns a named error instead of
//! parking out its timeout.
//!
//! Everything here is dependency-free: the epoll/eventfd surface is a
//! thin private FFI shim over the libc symbols the platform already
//! links (the same approach the rest of the crate takes to missing
//! crates — see `DESIGN.md` §3.13).

use crate::transport::frame::{parse_frame_header, READ_CHUNK_BYTES};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::fs::File;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::fd::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Raw epoll/eventfd bindings — the only FFI in the crate's I/O path.
/// Constants and the (packed on x86-64) event layout match the Linux ABI.
mod sys {
    /// One readiness record, ABI-compatible with `struct epoll_event`.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLRDHUP: u32 = 0x2000;

    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;

    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EFD_CLOEXEC: i32 = 0o2000000;
    pub const EFD_NONBLOCK: i32 = 0o4000;

    extern "C" {
        pub fn epoll_create1(flags: i32) -> i32;
        pub fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        pub fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        pub fn eventfd(initval: u32, flags: i32) -> i32;
    }
}

/// The `data` token reserved for each loop's wake eventfd.
const WAKE_TOKEN: u64 = u64::MAX;

/// Hard ceiling on the pool size — beyond this, context switching is the
/// thread-per-peer problem all over again.
const MAX_THREADS: usize = 64;

/// Recycled payload buffers kept per connection: enough to cover the
/// frames in flight between a loop's `push_back` and the caller's pop,
/// small enough that an idle connection pins at most a few buffers.
const RECYCLE_POOL_CAP: usize = 4;

/// How long a blocked sender waits on its write gate before re-probing
/// the socket regardless — correctness never depends on the `EPOLLOUT`
/// wakeup arriving (level-triggered epoll re-reports writability, and
/// the retry costs one `EAGAIN` in the worst case).
const WRITE_RETRY_EVERY: Duration = Duration::from_millis(50);

/// An owned epoll instance (the fd closes with the wrapper).
struct Epoll {
    file: File,
}

impl Epoll {
    fn new() -> io::Result<Epoll> {
        let fd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        // SAFETY: `fd` is a freshly created epoll descriptor we own.
        Ok(Epoll { file: unsafe { File::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events, data: token };
        // SAFETY: `ev` outlives the call; the kernel copies it.
        let rc = unsafe { sys::epoll_ctl(self.file.as_raw_fd(), op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// `epoll_wait` with EINTR retry. `timeout_ms < 0` blocks.
    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            // SAFETY: `events` is a valid, writable slice for the call.
            let rc = unsafe {
                sys::epoll_wait(
                    self.file.as_raw_fd(),
                    events.as_mut_ptr(),
                    events.len() as i32,
                    timeout_ms,
                )
            };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// A nonblocking eventfd used to kick a loop out of `epoll_wait`.
struct EventFd {
    file: File,
}

impl EventFd {
    fn new() -> io::Result<EventFd> {
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        // SAFETY: `fd` is a freshly created eventfd we own.
        Ok(EventFd { file: unsafe { File::from_raw_fd(fd) } })
    }

    /// Wake the owning loop (cheap, thread-safe, coalescing).
    fn notify(&self) {
        let _ = (&self.file).write(&1u64.to_ne_bytes());
    }

    /// De-assert readability (level-triggered epoll would spin otherwise).
    fn drain(&self) {
        let mut buf = [0u8; 8];
        while (&self.file).read(&mut buf).is_ok() {}
    }
}

/// A one-shot-per-signal wait flag: `signal` latches it, `wait_timeout`
/// consumes it. Used for write-readiness handoff and poller timers.
#[derive(Default)]
pub struct Gate {
    state: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    /// A fresh, unsignalled gate.
    pub fn new() -> Gate {
        Gate { state: Mutex::new(false), cv: Condvar::new() }
    }

    /// Latch the gate open and wake every waiter.
    pub fn signal(&self) {
        *self.state.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Wait until signalled or `dur` elapses; consumes the signal.
    /// Returns `true` if the gate was signalled.
    pub fn wait_timeout(&self, dur: Duration) -> bool {
        let deadline = Instant::now() + dur;
        let mut open = self.state.lock().unwrap();
        loop {
            if *open {
                *open = false;
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = self.cv.wait_timeout(open, deadline - now).unwrap();
            open = guard;
        }
    }
}

/// Why a blocking receive returned without a frame.
#[derive(Debug)]
pub enum RecvError {
    /// No frame arrived within the caller's deadline (connection alive).
    TimedOut,
    /// The event loop declared the connection dead (peer close, read
    /// error, or a corrupt frame header) — sticky: every subsequent
    /// receive returns the same message.
    Closed(String),
}

/// Complete frames queued for the caller plus the recycling pool flowing
/// the other way. One mutex covers both so a frame handoff and a buffer
/// return are each a single lock.
struct Inbox {
    frames: VecDeque<Vec<u8>>,
    pool: Vec<Vec<u8>>,
    /// `Some(reason)` once the loop declares the connection dead.
    dead: Option<String>,
}

/// The caller ⇄ loop rendezvous for one connection.
struct ConnShared {
    inbox: Mutex<Inbox>,
    /// Signalled when a frame lands or the connection dies.
    avail: Condvar,
    /// Signalled on `EPOLLOUT` (and on death, to unblock stuck senders).
    wgate: Gate,
}

impl ConnShared {
    fn new() -> ConnShared {
        ConnShared {
            inbox: Mutex::new(Inbox {
                frames: VecDeque::with_capacity(8),
                pool: Vec::with_capacity(RECYCLE_POOL_CAP),
                dead: None,
            }),
            avail: Condvar::new(),
            wgate: Gate::new(),
        }
    }

    /// Loop-side: declare the connection dead and wake everyone.
    fn mark_dead(&self, reason: String) {
        let mut inbox = self.inbox.lock().unwrap();
        if inbox.dead.is_none() {
            inbox.dead = Some(reason);
        }
        drop(inbox);
        self.avail.notify_all();
        self.wgate.signal();
    }
}

/// Commands a caller thread hands to a loop thread (paired with an
/// eventfd notify so the loop services them promptly).
enum Cmd {
    /// Adopt a socket: register `EPOLLIN` and start its read machine.
    Register { token: u64, stream: TcpStream, shared: Arc<ConnShared> },
    /// Arm `EPOLLOUT` for a blocked sender.
    WantWrite { token: u64 },
    /// Forget a connection (its [`ConnHandle`] was dropped).
    Deregister { token: u64 },
    /// Signal `gate` at `deadline` — the shaping/fault layers' pacing
    /// primitive ([`sleep_until`]).
    Timer { deadline: Instant, gate: Arc<Gate> },
}

/// A pending [`Cmd::Timer`], min-ordered by deadline in the loop's heap.
struct TimerEnt {
    deadline: Instant,
    /// Tie-breaker so the heap ordering is total without comparing gates.
    seq: u64,
    gate: Arc<Gate>,
}

impl PartialEq for TimerEnt {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for TimerEnt {}
impl PartialOrd for TimerEnt {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEnt {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.deadline, self.seq).cmp(&(other.deadline, other.seq))
    }
}

/// Per-connection read-state machine, owned by exactly one loop thread.
/// Parses `[u32 magic][u32 len][payload]` incrementally: the header fills
/// byte-by-byte into a stack array, the payload grows a pooled buffer in
/// `READ_CHUNK_BYTES` steps as bytes arrive (a lying length prefix can
/// reserve at most one chunk beyond what the stream delivers — the same
/// contract as `read_frame_into`).
struct Conn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    hdr: [u8; 8],
    hdr_filled: usize,
    payload: Vec<u8>,
    payload_len: usize,
    payload_filled: usize,
    in_payload: bool,
    /// `EPOLLOUT` currently armed for this connection.
    want_write: bool,
}

impl Conn {
    fn new(stream: TcpStream, shared: Arc<ConnShared>) -> Conn {
        Conn {
            stream,
            shared,
            hdr: [0u8; 8],
            hdr_filled: 0,
            payload: Vec::new(),
            payload_len: 0,
            payload_filled: 0,
            in_payload: false,
            want_write: false,
        }
    }

    /// Pull every byte the kernel has buffered, completing as many frames
    /// as arrive. `None` = still healthy (hit `WouldBlock`);
    /// `Some(reason)` = the connection is dead.
    fn drain_readable(&mut self) -> Option<String> {
        loop {
            if !self.in_payload {
                match self.stream.read(&mut self.hdr[self.hdr_filled..]) {
                    Ok(0) => return Some("peer closed the connection".to_string()),
                    Ok(k) => {
                        self.hdr_filled += k;
                        if self.hdr_filled == 8 {
                            match parse_frame_header(&self.hdr) {
                                Ok(len) => {
                                    self.payload_len = len;
                                    self.payload_filled = 0;
                                    self.payload.clear();
                                    self.in_payload = true;
                                    if len == 0 {
                                        self.complete_frame();
                                    }
                                }
                                Err(e) => return Some(e.to_string()),
                            }
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return None,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Some(e.to_string()),
                }
            } else {
                let want = (self.payload_filled + READ_CHUNK_BYTES).min(self.payload_len);
                if self.payload.len() < want {
                    self.payload.resize(want, 0);
                }
                match self.stream.read(&mut self.payload[self.payload_filled..want]) {
                    Ok(0) => return Some("peer closed mid-frame".to_string()),
                    Ok(k) => {
                        self.payload_filled += k;
                        if self.payload_filled == self.payload_len {
                            self.complete_frame();
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => return None,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Some(e.to_string()),
                }
            }
        }
    }

    /// Hand the completed payload to the inbox, pull a recycled buffer
    /// for the next frame, and reset the state machine.
    fn complete_frame(&mut self) {
        self.payload.truncate(self.payload_len);
        let frame = std::mem::take(&mut self.payload);
        let mut inbox = self.shared.inbox.lock().unwrap();
        inbox.frames.push_back(frame);
        if let Some(mut recycled) = inbox.pool.pop() {
            recycled.clear();
            self.payload = recycled;
        }
        drop(inbox);
        self.shared.avail.notify_all();
        self.in_payload = false;
        self.hdr_filled = 0;
        self.payload_len = 0;
        self.payload_filled = 0;
    }
}

/// The caller-visible half of one loop thread: its command queue and the
/// eventfd that kicks it out of `epoll_wait`.
struct LoopHandle {
    cmds: Mutex<Vec<Cmd>>,
    wake: EventFd,
}

impl LoopHandle {
    fn send(&self, cmd: Cmd) {
        self.cmds.lock().unwrap().push(cmd);
        self.wake.notify();
    }
}

/// A registered connection as seen by its owning transport: receive
/// completed frames, and coordinate write-readiness for the caller-side
/// vectored write path. Dropping the handle deregisters the socket from
/// its loop.
pub struct ConnHandle {
    shared: Arc<ConnShared>,
    home: Arc<LoopHandle>,
    token: u64,
}

impl ConnHandle {
    /// Block until a complete frame is available, copying its payload
    /// into `out` (cleared first; §Perf: zero allocations once `out` and
    /// the recycle pool have capacity). Fails fast with
    /// [`RecvError::Closed`] the moment the event loop declares the
    /// connection dead — even mid-wait — and with
    /// [`RecvError::TimedOut`] after `timeout` otherwise.
    pub fn recv_frame_into(&self, out: &mut Vec<u8>, timeout: Duration) -> Result<(), RecvError> {
        let deadline = Instant::now() + timeout;
        let mut inbox = self.shared.inbox.lock().unwrap();
        loop {
            if let Some(frame) = inbox.frames.pop_front() {
                out.clear();
                out.extend_from_slice(&frame);
                if inbox.pool.len() < RECYCLE_POOL_CAP {
                    inbox.pool.push(frame);
                }
                return Ok(());
            }
            if let Some(reason) = &inbox.dead {
                return Err(RecvError::Closed(reason.clone()));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::TimedOut);
            }
            let (guard, _) = self.shared.avail.wait_timeout(inbox, deadline - now).unwrap();
            inbox = guard;
        }
    }

    /// Ask the loop to arm `EPOLLOUT`; the write gate is signalled when
    /// the socket drains (or the connection dies).
    pub fn request_writable(&self) {
        self.home.send(Cmd::WantWrite { token: self.token });
    }

    /// Wait for the write gate, bounded to `WRITE_RETRY_EVERY` — senders
    /// re-probe the socket regardless, so a lost wakeup costs one retry,
    /// never a hang.
    pub fn wait_writable(&self) -> bool {
        self.shared.wgate.wait_timeout(WRITE_RETRY_EVERY)
    }

    /// Whether the loop has declared this connection dead.
    pub fn is_dead(&self) -> bool {
        self.shared.inbox.lock().unwrap().dead.is_some()
    }
}

impl Drop for ConnHandle {
    fn drop(&mut self) {
        self.home.send(Cmd::Deregister { token: self.token });
    }
}

/// The process-global event-loop pool. Created lazily on first use
/// ([`Poller::global`]); threads are detached and live for the process.
pub struct Poller {
    loops: Vec<Arc<LoopHandle>>,
    next_loop: AtomicUsize,
    next_token: AtomicU64,
}

static GLOBAL: OnceLock<Poller> = OnceLock::new();
static DESIRED_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the pool size the global poller will use *when it is first
/// created* (`[transport] poller_threads` / `--poller-threads`). `0`
/// keeps the default `min(cores, 8)`. A no-op once the pool exists —
/// sizing is a process-level decision, not per-run.
pub fn configure_threads(n: usize) {
    DESIRED_THREADS.store(n, Ordering::Relaxed);
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
}

impl Poller {
    /// The lazily-created global pool.
    pub fn global() -> &'static Poller {
        GLOBAL.get_or_init(|| {
            let want = DESIRED_THREADS.load(Ordering::Relaxed);
            let n = if want > 0 { want.min(MAX_THREADS) } else { default_threads() };
            Poller::new(n)
        })
    }

    fn new(n: usize) -> Poller {
        let mut loops = Vec::with_capacity(n);
        for i in 0..n {
            let epoll = Epoll::new().expect("epoll_create1 failed");
            let wake = EventFd::new().expect("eventfd failed");
            epoll
                .ctl(sys::EPOLL_CTL_ADD, wake.file.as_raw_fd(), sys::EPOLLIN, WAKE_TOKEN)
                .expect("registering wake eventfd failed");
            let handle = Arc::new(LoopHandle { cmds: Mutex::new(Vec::new()), wake });
            let thread_handle = Arc::clone(&handle);
            std::thread::Builder::new()
                .name(format!("ns-poller-{i}"))
                .spawn(move || run_loop(epoll, thread_handle))
                .expect("spawning poller thread failed");
            loops.push(handle);
        }
        Poller { loops, next_loop: AtomicUsize::new(0), next_token: AtomicU64::new(0) }
    }

    /// Number of event-loop threads in the pool.
    pub fn pool_size(&self) -> usize {
        self.loops.len()
    }

    /// Adopt a connected socket: switch it nonblocking, assign it to a
    /// loop round-robin, and return the caller-side handle. The caller
    /// keeps its own (now nonblocking) stream for the write path; the
    /// clone handed over here feeds the loop's read machine.
    pub fn register(&self, stream: TcpStream) -> io::Result<ConnHandle> {
        stream.set_nonblocking(true)?;
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        debug_assert!(token != WAKE_TOKEN);
        let idx = self.next_loop.fetch_add(1, Ordering::Relaxed) % self.loops.len();
        let shared = Arc::new(ConnShared::new());
        let home = Arc::clone(&self.loops[idx]);
        home.send(Cmd::Register { token, stream, shared: Arc::clone(&shared) });
        Ok(ConnHandle { shared, home, token })
    }
}

/// Block the calling thread until `deadline`, expressed as an event-loop
/// timer: the poller signals a gate at the deadline, and the caller's
/// own clock-checked gate wait makes the precision independent of
/// epoll's millisecond granularity. This is what
/// [`crate::transport::ShapedTransport`] and
/// [`crate::fault::FaultInjector`] pace with instead of
/// `std::thread::sleep` — deadline-based, so a refill can never
/// over-sleep in coarse chunks.
pub fn sleep_until(deadline: Instant) {
    if deadline <= Instant::now() {
        return;
    }
    let gate = Arc::new(Gate::new());
    let poller = Poller::global();
    poller.loops[0].send(Cmd::Timer { deadline, gate: Arc::clone(&gate) });
    loop {
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        gate.wait_timeout(deadline - now);
    }
}

/// Readiness bits that mean "try reading": data, peer half-close, error.
const READ_BITS: u32 = sys::EPOLLIN | sys::EPOLLERR | sys::EPOLLHUP | sys::EPOLLRDHUP;

/// One event-loop thread: wait → record metrics → service readiness →
/// drain commands → fire due timers.
fn run_loop(epoll: Epoll, handle: Arc<LoopHandle>) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut timers: BinaryHeap<Reverse<TimerEnt>> = BinaryHeap::new();
    let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 64];
    let mut pending: Vec<Cmd> = Vec::new();
    let mut timer_seq: u64 = 0;
    // Connections with EPOLLOUT armed on this loop — exported as the
    // write-queue-depth gauge (summed across loops it is approximate;
    // per-loop it is exact, and in practice one loop dominates).
    let mut armed_writes: u64 = 0;

    loop {
        let timeout_ms: i32 = match timers.peek() {
            None => -1,
            Some(Reverse(t)) => {
                let now = Instant::now();
                if t.deadline <= now {
                    0
                } else {
                    // Round up so we never wake a hair early and busy-spin.
                    let d = t.deadline - now;
                    (d.as_millis().min(60_000) as i32).saturating_add(1)
                }
            }
        };
        let n = match epoll.wait(&mut events, timeout_ms) {
            Ok(n) => n,
            Err(_) => continue,
        };
        let hot = crate::obs::hot();
        hot.poller_wakeups_total.inc();
        hot.poller_ready_events.observe(n as u64);

        let mut drain_cmds = false;
        for ev in events.iter().take(n) {
            // Copy the (possibly packed) record before touching fields.
            let ev = *ev;
            let token = ev.data;
            let bits = ev.events;
            if token == WAKE_TOKEN {
                handle.wake.drain();
                drain_cmds = true;
                continue;
            }
            let mut died: Option<String> = None;
            if let Some(conn) = conns.get_mut(&token) {
                if bits & READ_BITS != 0 {
                    died = conn.drain_readable();
                }
                if died.is_none() && bits & sys::EPOLLOUT != 0 && conn.want_write {
                    // Disarm until the next WantWrite — level-triggered
                    // EPOLLOUT on an idle socket would spin otherwise.
                    let _ = epoll.ctl(
                        sys::EPOLL_CTL_MOD,
                        conn.stream.as_raw_fd(),
                        sys::EPOLLIN | sys::EPOLLRDHUP,
                        token,
                    );
                    conn.want_write = false;
                    armed_writes = armed_writes.saturating_sub(1);
                    hot.poller_write_queue_depth.set(armed_writes as f64);
                    conn.shared.wgate.signal();
                }
            }
            if let Some(reason) = died {
                if let Some(conn) = conns.remove(&token) {
                    // The caller still holds a clone of this file
                    // description, so dropping our fd does NOT remove the
                    // epoll registration — delete explicitly.
                    let _ = epoll.del(conn.stream.as_raw_fd());
                    if conn.want_write {
                        armed_writes = armed_writes.saturating_sub(1);
                        hot.poller_write_queue_depth.set(armed_writes as f64);
                    }
                    conn.shared.mark_dead(reason);
                }
            }
        }

        if drain_cmds {
            {
                let mut queue = handle.cmds.lock().unwrap();
                std::mem::swap(&mut *queue, &mut pending);
            }
            for cmd in pending.drain(..) {
                match cmd {
                    Cmd::Register { token, stream, shared } => {
                        let fd = stream.as_raw_fd();
                        if let Err(e) =
                            epoll.ctl(sys::EPOLL_CTL_ADD, fd, sys::EPOLLIN | sys::EPOLLRDHUP, token)
                        {
                            shared.mark_dead(format!("epoll register failed: {e}"));
                            continue;
                        }
                        conns.insert(token, Conn::new(stream, shared));
                    }
                    Cmd::WantWrite { token } => {
                        if let Some(conn) = conns.get_mut(&token) {
                            if !conn.want_write
                                && epoll
                                    .ctl(
                                        sys::EPOLL_CTL_MOD,
                                        conn.stream.as_raw_fd(),
                                        sys::EPOLLIN | sys::EPOLLRDHUP | sys::EPOLLOUT,
                                        token,
                                    )
                                    .is_ok()
                            {
                                conn.want_write = true;
                                armed_writes += 1;
                                hot.poller_write_queue_depth.set(armed_writes as f64);
                            }
                        }
                        // A dead/unknown token needs nothing: death already
                        // signalled the write gate.
                    }
                    Cmd::Deregister { token } => {
                        if let Some(conn) = conns.remove(&token) {
                            let _ = epoll.del(conn.stream.as_raw_fd());
                            if conn.want_write {
                                armed_writes = armed_writes.saturating_sub(1);
                                hot.poller_write_queue_depth.set(armed_writes as f64);
                            }
                        }
                    }
                    Cmd::Timer { deadline, gate } => {
                        timer_seq += 1;
                        timers.push(Reverse(TimerEnt { deadline, seq: timer_seq, gate }));
                    }
                }
            }
        }

        let now = Instant::now();
        while let Some(Reverse(t)) = timers.peek() {
            if t.deadline > now {
                break;
            }
            if let Some(Reverse(due)) = timers.pop() {
                due.gate.signal();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::write_frame;
    use std::net::TcpListener;

    fn local_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = listener.accept().unwrap();
        (a, b)
    }

    #[test]
    fn gate_latches_and_consumes() {
        let gate = Gate::new();
        assert!(!gate.wait_timeout(Duration::from_millis(5)));
        gate.signal();
        assert!(gate.wait_timeout(Duration::from_millis(5)));
        // Consumed: a second wait times out again.
        assert!(!gate.wait_timeout(Duration::from_millis(5)));
    }

    #[test]
    fn registered_conn_delivers_frames_in_order() {
        let (mut tx, rx) = local_pair();
        let conn = Poller::global().register(rx).unwrap();
        write_frame(&mut tx, b"first").unwrap();
        write_frame(&mut tx, b"").unwrap();
        write_frame(&mut tx, &[7u8; 100_000]).unwrap();
        let mut buf = Vec::new();
        conn.recv_frame_into(&mut buf, Duration::from_secs(5)).unwrap();
        assert_eq!(buf, b"first");
        conn.recv_frame_into(&mut buf, Duration::from_secs(5)).unwrap();
        assert_eq!(buf, b"");
        conn.recv_frame_into(&mut buf, Duration::from_secs(5)).unwrap();
        assert_eq!(buf, vec![7u8; 100_000]);
        // Nothing further queued.
        match conn.recv_frame_into(&mut buf, Duration::from_millis(20)) {
            Err(RecvError::TimedOut) => {}
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    /// Satellite: a dead socket must fail pending receives immediately,
    /// not park out the recv timeout.
    #[test]
    fn dead_socket_fails_pending_recv_fast() {
        let (mut tx, rx) = local_pair();
        let conn = Poller::global().register(rx).unwrap();
        write_frame(&mut tx, b"delivered before death").unwrap();
        let waiter = std::thread::spawn(move || {
            let mut buf = Vec::new();
            conn.recv_frame_into(&mut buf, Duration::from_secs(30)).unwrap();
            assert_eq!(buf, b"delivered before death");
            // Now wait again with a huge timeout while the peer dies.
            let start = Instant::now();
            let err = conn.recv_frame_into(&mut buf, Duration::from_secs(30)).unwrap_err();
            (start.elapsed(), err, conn)
        });
        std::thread::sleep(Duration::from_millis(50));
        drop(tx);
        let (elapsed, err, conn) = waiter.join().unwrap();
        assert!(
            elapsed < Duration::from_secs(5),
            "recv parked {elapsed:?} instead of failing fast"
        );
        match err {
            RecvError::Closed(reason) => assert!(reason.contains("closed"), "reason: {reason}"),
            RecvError::TimedOut => panic!("expected Closed, got TimedOut"),
        }
        // Death is sticky.
        assert!(conn.is_dead());
        let mut buf = Vec::new();
        match conn.recv_frame_into(&mut buf, Duration::from_millis(10)) {
            Err(RecvError::Closed(_)) => {}
            other => panic!("expected sticky Closed, got {other:?}"),
        }
    }

    /// A corrupt header is a named death, not silent desync.
    #[test]
    fn corrupt_header_kills_connection_with_named_error() {
        let (mut tx, rx) = local_pair();
        let conn = Poller::global().register(rx).unwrap();
        tx.write_all(&[0xffu8; 8]).unwrap();
        let mut buf = Vec::new();
        let err = conn.recv_frame_into(&mut buf, Duration::from_secs(5)).unwrap_err();
        match err {
            RecvError::Closed(reason) => {
                assert!(reason.contains("bad frame magic"), "reason: {reason}")
            }
            RecvError::TimedOut => panic!("corrupt header timed out instead of failing"),
        }
    }

    /// The recycle pool round-trips buffers: after a warmup the caller's
    /// receives pop pooled buffers instead of allocating fresh ones.
    #[test]
    fn frame_buffers_recycle_through_the_pool() {
        let (mut tx, rx) = local_pair();
        let conn = Poller::global().register(rx).unwrap();
        let mut buf = Vec::new();
        for _ in 0..8 {
            write_frame(&mut tx, &[1u8; 4096]).unwrap();
            conn.recv_frame_into(&mut buf, Duration::from_secs(5)).unwrap();
            assert_eq!(buf.len(), 4096);
        }
        let pooled = conn.shared.inbox.lock().unwrap().pool.len();
        assert!(pooled > 0, "recycle pool never received a buffer");
    }

    #[test]
    fn sleep_until_is_accurate_without_oversleeping() {
        let start = Instant::now();
        sleep_until(start + Duration::from_millis(50));
        let elapsed = start.elapsed();
        assert!(elapsed >= Duration::from_millis(50), "woke early: {elapsed:?}");
        assert!(elapsed < Duration::from_millis(250), "overslept: {elapsed:?}");
        // A past deadline returns immediately.
        let start = Instant::now();
        sleep_until(start - Duration::from_millis(10));
        assert!(start.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn write_interest_gate_signals_on_writability() {
        let (tx, rx) = local_pair();
        // Register the *write* side so we can arm EPOLLOUT on it; keep the
        // read side alive so the connection stays healthy.
        let conn = Poller::global().register(tx).unwrap();
        conn.request_writable();
        // An idle socket is immediately writable (level-triggered), so the
        // gate must open promptly.
        assert!(conn.wait_writable(), "EPOLLOUT never signalled on an idle socket");
        drop(rx);
    }
}
