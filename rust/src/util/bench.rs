//! Micro-benchmark harness (criterion replacement for the offline build).
//!
//! Benches are compiled with `harness = false` and call [`Bench::run`] /
//! [`Bench::run_with_iters`]; the harness does warmup, adaptively picks an
//! iteration count to hit a time target, and reports mean/median/p99 with
//! optional throughput. `cargo bench` simply executes the binaries.

use std::hint::black_box;
use std::time::{Duration, Instant};

pub use std::hint::black_box as bb;

/// One benchmark's timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p99: Duration,
    pub min: Duration,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.elements
            .map(|e| e as f64 / self.mean.as_secs_f64())
    }
}

/// Harness configuration.
pub struct Bench {
    /// Minimum measurement time per benchmark.
    pub target: Duration,
    pub warmup: Duration,
    /// Max samples collected (each sample = one timed batch).
    pub samples: usize,
    results: Vec<BenchResult>,
    group: String,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // Honor NETSENSE_BENCH_FAST=1 for CI-style quick runs.
        let fast = std::env::var("NETSENSE_BENCH_FAST").ok().as_deref() == Some("1");
        Bench {
            target: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            samples: 32,
            results: Vec::new(),
            group: String::new(),
        }
    }

    /// Start a named group (prefix for subsequent benchmark names).
    pub fn group(&mut self, name: &str) -> &mut Self {
        self.group = name.to_string();
        eprintln!("\n== {name} ==");
        self
    }

    fn full_name(&self, name: &str) -> String {
        if self.group.is_empty() {
            name.to_string()
        } else {
            format!("{}/{}", self.group, name)
        }
    }

    /// Benchmark `f`, which performs one unit of work per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.run_inner(name, None, f)
    }

    /// Benchmark with a throughput annotation (`elements` per call of `f`).
    pub fn run_throughput<F: FnMut()>(&mut self, name: &str, elements: u64, f: F) -> &BenchResult {
        self.run_inner(name, Some(elements), f)
    }

    fn run_inner<F: FnMut()>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        mut f: F,
    ) -> &BenchResult {
        // Warmup and per-call estimate.
        let warm_start = Instant::now();
        let mut warm_calls = 0u64;
        while warm_start.elapsed() < self.warmup || warm_calls < 3 {
            f();
            warm_calls += 1;
            if warm_calls > 1_000_000 {
                break;
            }
        }
        let per_call = warm_start.elapsed().as_secs_f64() / warm_calls as f64;

        // Choose batch size so each sample takes ~target/samples.
        let per_sample = self.target.as_secs_f64() / self.samples as f64;
        let batch = ((per_sample / per_call.max(1e-9)).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        let bench_start = Instant::now();
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
            if bench_start.elapsed() > self.target * 4 {
                break; // overly slow benchmark; stop early with what we have
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = crate::util::stats::percentile_sorted(&samples, 0.5);
        let p99 = crate::util::stats::percentile_sorted(&samples, 0.99);
        let min = samples[0];
        let res = BenchResult {
            name: self.full_name(name),
            iters: total_iters,
            mean: Duration::from_secs_f64(mean),
            median: Duration::from_secs_f64(median),
            p99: Duration::from_secs_f64(p99),
            min: Duration::from_secs_f64(min),
            elements,
        };
        print_result(&res);
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Run `f` exactly once and report its wall time (for end-to-end
    /// experiment benches where one run is already seconds long).
    pub fn run_once<F: FnOnce()>(&mut self, name: &str, f: F) -> &BenchResult {
        let t = Instant::now();
        f();
        let d = t.elapsed();
        let res = BenchResult {
            name: self.full_name(name),
            iters: 1,
            mean: d,
            median: d,
            p99: d,
            min: d,
            elements: None,
        };
        print_result(&res);
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a closing summary table.
    pub fn finish(&self) {
        eprintln!("\n-- summary ({} benchmarks) --", self.results.len());
        for r in &self.results {
            print_result(r);
        }
    }
}

fn print_result(r: &BenchResult) {
    let tp = r
        .throughput_per_sec()
        .map(|t| format!("  {:>12}/s", human_count(t)))
        .unwrap_or_default();
    eprintln!(
        "{:<52} mean {:>12}  median {:>12}  p99 {:>12}  (n={}){}",
        r.name,
        human_time(r.mean),
        human_time(r.median),
        human_time(r.p99),
        r.iters,
        tp
    );
}

/// Format a duration with appropriate unit.
pub fn human_time(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a count with k/M/G suffix.
pub fn human_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Guard against the optimizer deleting the benchmarked work.
pub fn sink<T>(x: T) -> T {
    black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("NETSENSE_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.target = Duration::from_millis(20);
        b.warmup = Duration::from_millis(5);
        b.samples = 5;
        let mut acc = 0u64;
        let r = b
            .run("noop-ish", || {
                acc = sink(acc.wrapping_add(1));
            })
            .clone();
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.median <= r.p99);
        assert!(r.min <= r.median);
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("NETSENSE_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.target = Duration::from_millis(10);
        b.warmup = Duration::from_millis(2);
        b.samples = 4;
        let v = vec![1f32; 1024];
        let r = b
            .run_throughput("sum1k", 1024, || {
                sink(v.iter().sum::<f32>());
            })
            .clone();
        assert!(r.throughput_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human_time(Duration::from_secs(2)), "2.000 s");
        assert!(human_time(Duration::from_micros(1500)).contains("ms"));
        assert!(human_time(Duration::from_nanos(100)).contains("ns"));
        assert!(human_count(2_500_000.0).contains("M"));
        assert!(human_count(12.0).contains("12"));
    }

    #[test]
    fn run_once_records() {
        let mut b = Bench::new();
        let r = b.run_once("once", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.mean >= Duration::from_millis(1));
        assert_eq!(r.iters, 1);
    }
}
