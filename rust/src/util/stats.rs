//! Small statistics toolkit: summaries, percentiles, EWMA, and the
//! windowed max/min filters that BBR-style sensing depends on.

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        })
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, `q` in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Exponentially weighted moving average.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` is the weight of the newest observation, in `(0, 1]`.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        Ewma { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// A monotonic-deque windowed **maximum** over a sliding window keyed by an
/// arbitrary monotonically non-decreasing "time" (u64). This is the filter
/// BBR uses for BtlBw (and, mirrored, for RTprop).
#[derive(Clone, Debug)]
pub struct WindowedMax {
    window: u64,
    // (time, value); values strictly decreasing front→back.
    deque: std::collections::VecDeque<(u64, f64)>,
}

impl WindowedMax {
    pub fn new(window: u64) -> Self {
        assert!(window > 0);
        WindowedMax {
            window,
            deque: std::collections::VecDeque::new(),
        }
    }

    /// Insert observation `v` at time `t` and evict entries older than
    /// `t - window`. Times must be non-decreasing.
    pub fn update(&mut self, t: u64, v: f64) {
        while let Some(&(ft, _)) = self.deque.front() {
            if t.saturating_sub(ft) > self.window {
                self.deque.pop_front();
            } else {
                break;
            }
        }
        while let Some(&(_, bv)) = self.deque.back() {
            if bv <= v {
                self.deque.pop_back();
            } else {
                break;
            }
        }
        self.deque.push_back((t, v));
    }

    /// Current windowed max, if any observation is in the window.
    pub fn get(&self) -> Option<f64> {
        self.deque.front().map(|&(_, v)| v)
    }

    pub fn is_empty(&self) -> bool {
        self.deque.is_empty()
    }
}

/// Windowed **minimum** (dual of [`WindowedMax`]); BBR's RTprop filter.
#[derive(Clone, Debug)]
pub struct WindowedMin {
    inner: WindowedMax,
}

impl WindowedMin {
    pub fn new(window: u64) -> Self {
        WindowedMin {
            inner: WindowedMax::new(window),
        }
    }

    pub fn update(&mut self, t: u64, v: f64) {
        self.inner.update(t, -v);
    }

    pub fn get(&self) -> Option<f64> {
        self.inner.get().map(|v| -v)
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        for _ in 0..50 {
            e.update(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn windowed_max_evicts() {
        let mut w = WindowedMax::new(10);
        w.update(0, 5.0);
        w.update(1, 3.0);
        assert_eq!(w.get(), Some(5.0));
        w.update(11, 1.0); // t=0 entry is 11 old > 10 → evicted
        assert_eq!(w.get(), Some(3.0));
        w.update(12, 4.0);
        assert_eq!(w.get(), Some(4.0));
    }

    #[test]
    fn windowed_max_matches_naive() {
        let mut r = crate::util::rng::Pcg64::seeded(11);
        let window = 25u64;
        let mut w = WindowedMax::new(window);
        let mut hist: Vec<(u64, f64)> = Vec::new();
        let mut t = 0u64;
        for _ in 0..2000 {
            t += r.below(4);
            let v = r.f64() * 100.0;
            w.update(t, v);
            hist.push((t, v));
            let naive = hist
                .iter()
                .filter(|&&(ht, _)| t - ht <= window)
                .map(|&(_, hv)| hv)
                .fold(f64::MIN, f64::max);
            assert_eq!(w.get().unwrap(), naive);
        }
    }

    #[test]
    fn windowed_min_matches_naive() {
        let mut r = crate::util::rng::Pcg64::seeded(12);
        let window = 17u64;
        let mut w = WindowedMin::new(window);
        let mut hist: Vec<(u64, f64)> = Vec::new();
        let mut t = 0u64;
        for _ in 0..2000 {
            t += r.below(3);
            let v = r.f64() * 100.0;
            w.update(t, v);
            hist.push((t, v));
            let naive = hist
                .iter()
                .filter(|&&(ht, _)| t - ht <= window)
                .map(|&(_, hv)| hv)
                .fold(f64::MAX, f64::min);
            assert_eq!(w.get().unwrap(), naive);
        }
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::default();
        for &x in &xs {
            o.push(x);
        }
        let s = Summary::of(&xs).unwrap();
        assert!((o.mean() - s.mean).abs() < 1e-12);
        assert!((o.std() - s.std).abs() < 1e-12);
    }
}
