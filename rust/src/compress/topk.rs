//! Top-K selection by absolute value — the sparsification core of
//! Algorithm 2 step 3 ("TopK sparsification to eliminate gradients with
//! minimal absolute values").
//!
//! Two paths:
//! - [`top_k_indices`] — exact selection via iterative quickselect on a
//!   scratch buffer (average O(n)), no allocation churn in steady state.
//! - [`threshold_select`] — select by a magnitude threshold, used with
//!   [`kth_magnitude`] for threshold reuse across steps (the hot-path
//!   optimization: gradient magnitude distributions drift slowly, so last
//!   step's k-th magnitude is a good pre-filter for this step).

/// Number of elements to keep for a ratio over `n` elements, respecting the
/// paper's floor of at least one element when `n > 0` and ratio > 0.
pub fn k_for_ratio(n: usize, ratio: f64) -> usize {
    if n == 0 || ratio <= 0.0 {
        return 0;
    }
    (((n as f64) * ratio).round() as usize).clamp(1, n)
}

/// Exact top-k selection: returns the indices of the `k` largest |values|
/// (ties broken arbitrarily), in ascending index order.
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<u32> {
    let mut scratch = Vec::new();
    top_k_indices_with(values, k, &mut scratch)
}

/// [`top_k_indices`] with a caller-owned scratch buffer — avoids the fresh
/// ~12·n-byte pair allocation per call, but still allocates the returned
/// index vector. The fully allocation-free variant is
/// [`top_k_indices_into`].
pub fn top_k_indices_with(
    values: &[f32],
    k: usize,
    scratch: &mut Vec<(f32, u32)>,
) -> Vec<u32> {
    let mut out = Vec::with_capacity(k);
    top_k_indices_into(values, k, scratch, &mut out);
    out
}

/// [`top_k_indices`] writing into caller-owned buffers — the hot-path
/// variant (§Perf: zero allocations once `scratch` and `out` have
/// capacity). `out` is cleared and left holding the `k` selected indices
/// in ascending order.
pub fn top_k_indices_into(
    values: &[f32],
    k: usize,
    scratch: &mut Vec<(f32, u32)>,
    out: &mut Vec<u32>,
) {
    let n = values.len();
    assert!(k <= n, "k={k} > n={n}");
    assert!(n <= u32::MAX as usize, "tensor too large for u32 indices");
    out.clear();
    if k == 0 {
        return;
    }
    if k == n {
        out.extend(0..n as u32);
        return;
    }
    fill_scratch(values, scratch);
    quickselect_desc(scratch, k);
    // scratch[..k] now holds the top-k (unordered); collect + sort indices.
    out.extend(scratch[..k].iter().map(|&(_, i)| i));
    out.sort_unstable();
    debug_assert_eq!(out.len(), k);
}

fn fill_scratch(values: &[f32], scratch: &mut Vec<(f32, u32)>) {
    scratch.clear();
    scratch.reserve(values.len());
    scratch.extend(values.iter().enumerate().map(|(i, &v)| (v.abs(), i as u32)));
}

/// The k-th largest |value| (the selection threshold). `k >= 1`.
pub fn kth_magnitude(values: &[f32], k: usize) -> f32 {
    let mut scratch = Vec::new();
    kth_magnitude_with(values, k, &mut scratch)
}

/// [`kth_magnitude`] with caller-owned scratch (hot-path variant).
pub fn kth_magnitude_with(values: &[f32], k: usize, scratch: &mut Vec<(f32, u32)>) -> f32 {
    assert!(k >= 1 && k <= values.len());
    fill_scratch(values, scratch);
    quickselect_desc(scratch, k).0
}

/// Partition `scratch` so the `k` largest (by .0, descending) are in
/// `scratch[..k]`; returns the k-th element.
fn quickselect_desc(scratch: &mut [(f32, u32)], k: usize) -> (f32, u32) {
    debug_assert!(k >= 1 && k <= scratch.len());
    let mut lo = 0usize;
    let mut hi = scratch.len();
    let target = k - 1;
    // Simple deterministic xorshift for pivot choice (avoids adversarial
    // O(n²) on sorted inputs without pulling in an RNG).
    let mut state = 0x9e37_79b9_7f4a_7c15u64 ^ (scratch.len() as u64);
    loop {
        if hi - lo <= 16 {
            scratch[lo..hi].sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            return scratch[target];
        }
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        let pivot_idx = lo + (state as usize % (hi - lo));
        let pivot = scratch[pivot_idx].0;
        // 3-way partition (descending): [> pivot | == pivot | < pivot]
        let mut i = lo;
        let mut j = lo;
        let mut g = hi;
        while j < g {
            let v = scratch[j].0;
            if v > pivot {
                scratch.swap(i, j);
                i += 1;
                j += 1;
            } else if v < pivot {
                g -= 1;
                scratch.swap(j, g);
            } else {
                j += 1;
            }
        }
        // Now [lo, i) > pivot, [i, g) == pivot, [g, hi) < pivot.
        if target < i {
            hi = i;
        } else if target < g {
            return scratch[target];
        } else {
            lo = g;
        }
    }
}

/// Indices (ascending) of all values with |v| >= threshold.
///
/// Allocating convenience kept for tests and examples only — hot-path call
/// sites must use [`threshold_select_into`], which reuses a caller-owned
/// buffer (hidden from docs so new code can't pick it up by accident).
#[doc(hidden)]
pub fn threshold_select(values: &[f32], threshold: f32) -> Vec<u32> {
    let mut out = Vec::new();
    threshold_select_into(values, threshold, &mut out);
    out
}

/// Indices (ascending) of all values with |v| >= threshold, written into a
/// caller-owned buffer (hot-path variant: the steady-state pre-filter runs
/// every step, so its candidate set must not cost a fresh allocation per
/// call). Runs the runtime-dispatched SIMD scan
/// ([`super::simd::threshold_select_into`]); the scalar fallback is
/// bit-identical.
pub fn threshold_select_into(values: &[f32], threshold: f32, out: &mut Vec<u32>) {
    super::simd::threshold_select_into(values, threshold, out);
}

/// Threshold-reuse top-k: try `est_threshold` (e.g. last step's k-th
/// magnitude); if the candidate set is within `slack` of k, trim/accept it;
/// otherwise fall back to exact quickselect. Returns (indices, kth_mag).
pub fn top_k_with_threshold_hint(
    values: &[f32],
    k: usize,
    est_threshold: Option<f32>,
    slack: f64,
) -> (Vec<u32>, f32) {
    let mut scratch = Vec::new();
    top_k_with_threshold_hint_and_scratch(values, k, est_threshold, slack, &mut scratch)
}

/// [`top_k_with_threshold_hint`] with caller-owned quickselect scratch.
/// Still allocates the candidate/sub-tensor staging and the returned index
/// vector; the fully allocation-free variant is
/// [`top_k_with_threshold_hint_into`].
pub fn top_k_with_threshold_hint_and_scratch(
    values: &[f32],
    k: usize,
    est_threshold: Option<f32>,
    slack: f64,
    scratch: &mut Vec<(f32, u32)>,
) -> (Vec<u32>, f32) {
    let mut cand = Vec::new();
    let mut sub = Vec::new();
    let mut sub_keep = Vec::new();
    let mut out = Vec::new();
    let kth = top_k_with_threshold_hint_into(
        values,
        k,
        est_threshold,
        slack,
        scratch,
        &mut cand,
        &mut sub,
        &mut sub_keep,
        &mut out,
    );
    (out, kth)
}

/// [`top_k_with_threshold_hint`] with every buffer caller-owned — the
/// fused-hot-path variant (§Perf: zero allocations in steady state; both
/// the threshold-reuse fast path and its exact-quickselect fallback route
/// through `cand`/`sub`/`sub_keep` instead of collecting fresh vectors).
/// `out` is cleared and left holding exactly `k` indices in ascending
/// order; returns the realized k-th magnitude (the next step's hint).
#[allow(clippy::too_many_arguments)]
pub fn top_k_with_threshold_hint_into(
    values: &[f32],
    k: usize,
    est_threshold: Option<f32>,
    slack: f64,
    scratch: &mut Vec<(f32, u32)>,
    cand: &mut Vec<u32>,
    sub: &mut Vec<f32>,
    sub_keep: &mut Vec<u32>,
    out: &mut Vec<u32>,
) -> f32 {
    out.clear();
    if k == 0 {
        return f32::INFINITY;
    }
    if k >= values.len() {
        out.extend(0..values.len() as u32);
        return 0.0;
    }
    if let Some(th) = est_threshold {
        if th.is_finite() && th > 0.0 {
            threshold_select_into(values, th, cand);
            let hi = ((k as f64) * (1.0 + slack)) as usize;
            if cand.len() >= k && cand.len() <= hi.max(k + 1) {
                // Trim the candidate set down to exactly k by selecting
                // within it (much smaller than n). Always returning exactly
                // k keeps wire sizes deterministic — the contract
                // `predict_wire_bytes` relies on.
                sub.clear();
                sub.extend(cand.iter().map(|&i| values[i as usize]));
                top_k_indices_into(sub, k, scratch, sub_keep);
                out.extend(sub_keep.iter().map(|&j| cand[j as usize]));
                out.sort_unstable();
                // The k-th magnitude is the smallest selected |value| —
                // identical to a second quickselect over `sub`, without
                // re-filling the pair buffer (§Perf).
                return sub_keep
                    .iter()
                    .map(|&j| sub[j as usize].abs())
                    .fold(f32::MAX, f32::min);
            }
        }
    }
    // Single quickselect pass yields both the indices and the threshold.
    top_k_indices_into(values, k, scratch, out);
    out.iter()
        .map(|&i| values[i as usize].abs())
        .fold(f32::MAX, f32::min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::*;
    use crate::util::rng::Pcg64;

    /// Reference implementation: full sort.
    fn naive_top_k(values: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..values.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            values[b as usize]
                .abs()
                .partial_cmp(&values[a as usize].abs())
                .unwrap()
        });
        let mut out = idx[..k].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_naive_on_random_inputs() {
        let mut r = Pcg64::seeded(20);
        for trial in 0..50 {
            let n = 1 + r.index(300);
            let k = 1 + r.index(n);
            let mut v = vec![0f32; n];
            r.fill_normal_f32(&mut v, 0.0, 1.0);
            let fast = top_k_indices(&v, k);
            let slow = naive_top_k(&v, k);
            // With distinct magnitudes (almost surely), selections agree.
            let fast_mags: f32 = fast.iter().map(|&i| v[i as usize].abs()).sum();
            let slow_mags: f32 = slow.iter().map(|&i| v[i as usize].abs()).sum();
            assert!(
                (fast_mags - slow_mags).abs() < 1e-4 * slow_mags.max(1.0),
                "trial {trial}: mass mismatch"
            );
            assert_eq!(fast.len(), k);
        }
    }

    #[test]
    fn handles_duplicates() {
        let v = vec![1.0f32; 100];
        let idx = top_k_indices(&v, 10);
        assert_eq!(idx.len(), 10);
        // all magnitudes equal → any 10 indices are valid; check dedup+sorted
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
    }

    #[test]
    fn edge_cases() {
        assert!(top_k_indices(&[], 0).is_empty());
        assert_eq!(top_k_indices(&[3.0], 1), vec![0]);
        let v = [1.0f32, -5.0, 2.0];
        assert_eq!(top_k_indices(&v, 3), vec![0, 1, 2]);
        assert_eq!(top_k_indices(&v, 1), vec![1]); // |-5| largest
        assert!(top_k_indices(&v, 0).is_empty());
    }

    #[test]
    #[should_panic(expected = "k=5 > n=3")]
    fn k_larger_than_n_panics() {
        top_k_indices(&[1.0, 2.0, 3.0], 5);
    }

    #[test]
    fn k_for_ratio_floors_and_clamps() {
        assert_eq!(k_for_ratio(1000, 0.1), 100);
        assert_eq!(k_for_ratio(1000, 0.0), 0);
        assert_eq!(k_for_ratio(1000, 1e-9), 1); // floor at 1
        assert_eq!(k_for_ratio(1000, 2.0), 1000); // clamp at n
        assert_eq!(k_for_ratio(0, 0.5), 0);
    }

    #[test]
    fn kth_magnitude_is_selection_threshold() {
        let v = [0.1f32, -0.9, 0.5, 0.3, -0.7];
        assert_eq!(kth_magnitude(&v, 1), 0.9);
        assert_eq!(kth_magnitude(&v, 2), 0.7);
        assert_eq!(kth_magnitude(&v, 5), 0.1);
    }

    #[test]
    fn threshold_select_is_inclusive() {
        let v = [0.5f32, -0.5, 0.4, 0.6];
        assert_eq!(threshold_select(&v, 0.5), vec![0, 1, 3]);
        assert_eq!(threshold_select(&v, 0.61), Vec::<u32>::new());
        assert_eq!(threshold_select(&v, 0.0).len(), 4);
    }

    #[test]
    fn property_topk_selects_maximal_mass() {
        forall(
            "top-k mass >= any other k-subset (checked vs sorted)",
            100,
            vec_f32(1..200, -100.0..100.0),
            |v| {
                let k = (v.len() / 3).max(1);
                let idx = top_k_indices(v, k);
                if idx.len() != k {
                    return false;
                }
                let selected: f32 = idx.iter().map(|&i| v[i as usize].abs()).sum();
                let naive: f32 = naive_top_k(v, k)
                    .iter()
                    .map(|&i| v[i as usize].abs())
                    .sum();
                (selected - naive).abs() <= naive.max(1.0) * 1e-5
            },
        );
    }

    #[test]
    fn property_indices_sorted_unique_in_range() {
        forall(
            "indices sorted / unique / in range",
            100,
            vec_f32(1..300, -10.0..10.0),
            |v| {
                let k = (v.len() / 2).max(1);
                let idx = top_k_indices(v, k);
                idx.windows(2).all(|w| w[0] < w[1]) && idx.iter().all(|&i| (i as usize) < v.len())
            },
        );
    }

    #[test]
    fn threshold_hint_exact_when_distribution_stable() {
        let mut r = Pcg64::seeded(21);
        let mut v = vec![0f32; 10_000];
        r.fill_normal_f32(&mut v, 0.0, 1.0);
        let k = 500;
        let (_, kth) = top_k_with_threshold_hint(&v, k, None, 0.2);
        // Slightly perturb the tensor (next "step") and reuse the threshold.
        let mut v2 = v.clone();
        for x in v2.iter_mut() {
            *x += 0.01 * r.normal() as f32;
        }
        let (idx2, _) = top_k_with_threshold_hint(&v2, k, Some(kth), 0.2);
        // Exactly k, always (the wire-size determinism contract).
        assert_eq!(idx2.len(), k);
        let exact = naive_top_k(&v2, idx2.len());
        let got_mass: f32 = idx2.iter().map(|&i| v2[i as usize].abs()).sum();
        let best_mass: f32 = exact.iter().map(|&i| v2[i as usize].abs()).sum();
        assert!(got_mass >= best_mass * 0.999, "{got_mass} vs {best_mass}");
    }

    #[test]
    fn threshold_hint_falls_back_when_stale() {
        let v = vec![1.0f32; 100];
        // Hint way too high → candidate set empty → exact fallback.
        let (idx, _) = top_k_with_threshold_hint(&v, 10, Some(100.0), 0.2);
        assert_eq!(idx.len(), 10);
        // Hint way too low → candidate set = everything → exact fallback
        // still returns exactly k.
        let (idx, _) = top_k_with_threshold_hint(&v, 10, Some(1e-10), 0.2);
        assert_eq!(idx.len(), 10);
    }

    #[test]
    fn into_variants_match_allocating_variants() {
        // The caller-owned-buffer hot path must select identically to the
        // allocating API, with every buffer reused across calls.
        let mut r = Pcg64::seeded(22);
        let (mut scratch, mut cand, mut sub, mut sub_keep, mut out) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        let mut hint = None;
        for trial in 0..30 {
            let n = 1 + r.index(400);
            let k = 1 + r.index(n);
            let mut v = vec![0f32; n];
            r.fill_normal_f32(&mut v, 0.0, 1.0);
            top_k_indices_into(&v, k, &mut scratch, &mut out);
            assert_eq!(out, top_k_indices(&v, k), "trial {trial} top_k");
            threshold_select_into(&v, 0.5, &mut out);
            assert_eq!(out, threshold_select(&v, 0.5), "trial {trial} threshold");
            let kth = top_k_with_threshold_hint_into(
                &v, k, hint, 0.25, &mut scratch, &mut cand, &mut sub, &mut sub_keep, &mut out,
            );
            let (want_idx, want_kth) = top_k_with_threshold_hint(&v, k, hint, 0.25);
            assert_eq!(out, want_idx, "trial {trial} hinted indices");
            assert_eq!(kth, want_kth, "trial {trial} hinted kth");
            hint = Some(kth);
        }
    }

    #[test]
    fn adversarial_sorted_input_is_fast_enough() {
        // Guard against quadratic pivot behaviour: 1M sorted elements
        // should select in well under a second.
        let v: Vec<f32> = (0..1_000_000).map(|i| i as f32).collect();
        let t = std::time::Instant::now();
        let idx = top_k_indices(&v, 1000);
        assert_eq!(idx.len(), 1000);
        assert!(idx.contains(&999_999));
        assert!(
            t.elapsed() < std::time::Duration::from_secs(2),
            "quickselect too slow: {:?}",
            t.elapsed()
        );
    }
}
