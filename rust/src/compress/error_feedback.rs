//! Error feedback (memory-compensated compression): everything Algorithm 2
//! drops — pruned gradients, untransmitted (non-top-k) values, and
//! quantization error — is accumulated locally and re-injected into the
//! next step's gradient ("accumulate the local filtered gradients for
//! further aggregation and transmission", paper §4.2 step 3).
//!
//! Invariant (tested): `transmitted + residual == gradient + old_residual`
//! — compression never loses gradient mass, only delays it.

use super::quantize::{f16_bits_to_f32, f32_to_f16_bits, Precision};
use super::sparse::SparseGradient;

/// Per-worker error-feedback state for one flat gradient tensor.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(n: usize) -> Self {
        ErrorFeedback {
            residual: vec![0.0; n],
        }
    }

    pub fn len(&self) -> usize {
        self.residual.len()
    }

    pub fn is_empty(&self) -> bool {
        self.residual.is_empty()
    }

    /// Add the carried residual into `grad` (start of a step).
    pub fn compensate(&self, grad: &mut [f32]) {
        assert_eq!(grad.len(), self.residual.len());
        for (g, &r) in grad.iter_mut().zip(self.residual.iter()) {
            *g += r;
        }
    }

    /// Record what was actually transmitted: the new residual is
    /// `compensated_grad - decoded(transmitted)`.
    pub fn absorb(&mut self, compensated_grad: &[f32], transmitted: &SparseGradient) {
        assert_eq!(compensated_grad.len(), self.residual.len());
        assert_eq!(transmitted.n_total, self.residual.len());
        // Start from the full compensated gradient...
        self.residual.copy_from_slice(compensated_grad);
        // ...and subtract what made it onto the wire (at wire precision).
        for (&i, &v) in transmitted.indices.iter().zip(transmitted.values.iter()) {
            self.residual[i as usize] -= v;
        }
    }

    /// [`ErrorFeedback::absorb`] without a materialized payload, keeping
    /// the caller's compensated buffer intact (the fused hot path itself
    /// uses the swap-based [`ErrorFeedback::absorb_owned`] and gives the
    /// buffer up). The transmitted value at index `i` is
    /// recomputed as the wire-precision view of `compensated_grad[i]`,
    /// elementwise-identical to `gather → quantize_values → absorb`
    /// (including the `quantize_values` quirk of rounding only `F16`:
    /// bf16 payloads subtract the unrounded local value on both paths).
    pub fn absorb_selected(
        &mut self,
        compensated_grad: &[f32],
        indices: &[u32],
        precision: Precision,
    ) {
        assert_eq!(compensated_grad.len(), self.residual.len());
        self.residual.copy_from_slice(compensated_grad);
        match precision {
            Precision::F16 => {
                for &i in indices {
                    let v = f16_bits_to_f32(f32_to_f16_bits(compensated_grad[i as usize]));
                    self.residual[i as usize] -= v;
                }
            }
            Precision::F32 | Precision::Bf16 => {
                for &i in indices {
                    self.residual[i as usize] -= compensated_grad[i as usize];
                }
            }
        }
    }

    /// [`ErrorFeedback::absorb_selected`] that *takes* the compensated
    /// gradient instead of copying it: the caller's buffer becomes the
    /// new residual via a pointer swap (§Perf: kills a 2·n-float copy per
    /// step) and the old residual storage is handed back in `compensated`
    /// with unspecified contents (the fused path clears it next step).
    /// Residual values are bit-identical to [`ErrorFeedback::absorb`].
    pub fn absorb_owned(
        &mut self,
        compensated: &mut Vec<f32>,
        indices: &[u32],
        precision: Precision,
    ) {
        assert_eq!(compensated.len(), self.residual.len());
        std::mem::swap(&mut self.residual, compensated);
        match precision {
            Precision::F16 => {
                for &i in indices {
                    let v = self.residual[i as usize];
                    self.residual[i as usize] = v - f16_bits_to_f32(f32_to_f16_bits(v));
                }
            }
            Precision::F32 | Precision::Bf16 => {
                for &i in indices {
                    let v = self.residual[i as usize];
                    self.residual[i as usize] = v - v;
                }
            }
        }
    }

    /// L2 norm of the residual (reported as a compression-health metric).
    pub fn residual_norm(&self) -> f64 {
        self.residual
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Overwrite the residual from a snapshot (checkpoint restore — the
    /// rejoin path of [`crate::fault::Checkpoint`]).
    pub fn restore(&mut self, residual: &[f32]) {
        assert_eq!(
            residual.len(),
            self.residual.len(),
            "residual snapshot length mismatch"
        );
        self.residual.copy_from_slice(residual);
    }

    pub fn reset(&mut self) {
        self.residual.iter_mut().for_each(|x| *x = 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quantize::Precision;
    use crate::compress::topk::top_k_indices;
    use crate::testing::prop::*;
    use crate::util::rng::Pcg64;

    /// One compress step with error feedback; returns (transmitted, new grad
    /// view) for invariant checking.
    fn step(ef: &mut ErrorFeedback, grad: &[f32], k: usize) -> SparseGradient {
        let mut g = grad.to_vec();
        ef.compensate(&mut g);
        let idx = top_k_indices(&g, k);
        let mut s = SparseGradient::gather(&g, idx, Precision::F32);
        s.quantize_values();
        ef.absorb(&g, &s);
        s
    }

    #[test]
    fn conservation_invariant() {
        let mut r = Pcg64::seeded(40);
        let n = 256;
        let mut ef = ErrorFeedback::new(n);
        let mut total_injected = vec![0f64; n];
        let mut total_transmitted = vec![0f64; n];
        for _ in 0..20 {
            let mut grad = vec![0f32; n];
            r.fill_normal_f32(&mut grad, 0.0, 1.0);
            for (t, &g) in total_injected.iter_mut().zip(grad.iter()) {
                *t += g as f64;
            }
            let s = step(&mut ef, &grad, 16);
            for (&i, &v) in s.indices.iter().zip(s.values.iter()) {
                total_transmitted[i as usize] += v as f64;
            }
        }
        // injected == transmitted + residual, elementwise.
        for i in 0..n {
            let lhs = total_injected[i];
            let rhs = total_transmitted[i] + ef.residual()[i] as f64;
            assert!(
                (lhs - rhs).abs() < 1e-4,
                "elem {i}: injected {lhs} vs transmitted+residual {rhs}"
            );
        }
    }

    #[test]
    fn untransmitted_mass_eventually_flows() {
        // A small-but-persistent gradient component must eventually be
        // transmitted thanks to residual accumulation.
        let n = 10;
        let mut ef = ErrorFeedback::new(n);
        let mut seen_small = false;
        for iter in 0..50 {
            // Element 9 has a small *persistent* gradient; 0..9 are large
            // but sign-alternating over time (their residuals cancel), so
            // element 9's accumulated residual must eventually dominate.
            let sign = if iter % 2 == 0 { 1.0f32 } else { -1.0 };
            let mut grad = vec![10.0 * sign; n];
            grad[9] = 0.5;
            let s = step(&mut ef, &grad, 3);
            if s.indices.contains(&9) {
                seen_small = true;
                break;
            }
        }
        assert!(seen_small, "small gradient never transmitted");
    }

    #[test]
    fn compensate_adds_residual() {
        let mut ef = ErrorFeedback::new(3);
        let grad = vec![1.0f32, 1.0, 1.0];
        // transmit only element 0
        let mut g = grad.clone();
        ef.compensate(&mut g);
        let s = SparseGradient::gather(&g, vec![0], Precision::F32);
        ef.absorb(&g, &s);
        assert_eq!(ef.residual(), &[0.0, 1.0, 1.0]);
        // next step: residual doubles the untransmitted elements
        let mut g2 = grad.clone();
        ef.compensate(&mut g2);
        assert_eq!(g2, vec![1.0, 2.0, 2.0]);
    }

    #[test]
    fn quantization_error_is_captured() {
        let mut ef = ErrorFeedback::new(1);
        let g = vec![0.1234567f32]; // not representable in f16
        let mut gc = g.clone();
        ef.compensate(&mut gc);
        let mut s = SparseGradient::gather(&gc, vec![0], Precision::F16);
        s.quantize_values();
        ef.absorb(&gc, &s);
        // residual = original - quantized ≠ 0
        assert!(ef.residual()[0] != 0.0);
        assert!((ef.residual()[0] + s.values[0] - 0.1234567).abs() < 1e-7);
    }

    #[test]
    fn absorb_selected_matches_staged_absorb_bitwise() {
        let mut r = Pcg64::seeded(41);
        for prec in [Precision::F32, Precision::F16, Precision::Bf16] {
            let n = 128;
            let mut staged = ErrorFeedback::new(n);
            let mut fused = ErrorFeedback::new(n);
            for step in 0..10 {
                let mut grad = vec![0f32; n];
                r.fill_normal_f32(&mut grad, 0.0, 1.0);
                // Staged: compensate → gather → quantize_values → absorb.
                let mut gs = grad.clone();
                staged.compensate(&mut gs);
                let idx = top_k_indices(&gs, 16);
                let mut s = SparseGradient::gather(&gs, idx.clone(), prec);
                s.quantize_values();
                staged.absorb(&gs, &s);
                // Fused: compensate → absorb, no payload. Alternate the
                // copying and owning variants — both must match staged.
                let mut gf = grad.clone();
                fused.compensate(&mut gf);
                let idx_f = top_k_indices(&gf, 16);
                assert_eq!(idx_f, idx, "{prec:?} step {step}: selection diverged");
                if step % 2 == 0 {
                    fused.absorb_selected(&gf, &idx_f, prec);
                } else {
                    let mut owned = gf.clone();
                    fused.absorb_owned(&mut owned, &idx_f, prec);
                }
                for (i, (a, b)) in staged.residual().iter().zip(fused.residual()).enumerate()
                {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{prec:?} step {step} elem {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn property_residual_norm_decreases_with_larger_k() {
        forall(
            "larger k ⇒ smaller residual",
            50,
            vec_f32(32..128, -5.0..5.0),
            |v| {
                let run = |k: usize| {
                    let mut ef = ErrorFeedback::new(v.len());
                    step(&mut ef, v, k);
                    ef.residual_norm()
                };
                run(v.len() / 2) <= run(v.len() / 8) + 1e-9
            },
        );
    }

    #[test]
    fn reset_clears() {
        let mut ef = ErrorFeedback::new(4);
        step(&mut ef, &[1.0, 2.0, 3.0, 4.0], 1);
        assert!(ef.residual_norm() > 0.0);
        ef.reset();
        assert_eq!(ef.residual_norm(), 0.0);
    }
}
