//! Gradient compression stack (the paper's §4.2, Algorithm 2).
//!
//! Components:
//! - [`quantize`] — fp32 → fp16/bf16 value quantization (halves the wire
//!   format; the paper's "Adaptive Quantization" step).
//! - [`prune`] — magnitude-based model pruning: gradients of the smallest
//!   |weight| parameters are zeroed (recoverable; excluded from transport).
//! - [`topk`] — exact Top-K selection by |gradient| (quickselect) plus a
//!   threshold-reuse fast path for the steady state.
//! - [`sparse`] — the wire codec: COO (index, value) encoding with f32 or
//!   f16 values, and wire-size accounting. Both directions have a staged
//!   reference and a fused hot path: send-side
//!   [`sparse::encode_gathered_into`] (gather+quantize+encode, no
//!   [`SparseGradient`]) and receive-side [`sparse::decode_reduce_into`]
//!   (parse+dequantize+scatter straight into the dense accumulator, no
//!   [`SparseGradient`] either — bit-identical to decode → `add_into`).
//! - [`error_feedback`] — local residual accumulation of everything that
//!   was *not* transmitted, re-injected into the next step's gradient
//!   (memory-compensated compression).
//! - [`pipeline`] — Algorithm 2 end-to-end: adaptive quantization decision →
//!   pruning → Top-K sparsification → encoded payload. Two emit paths:
//!   the staged reference ([`NetSenseCompressor::compress`], materializes a
//!   [`SparseGradient`]) and the fused hot path
//!   ([`NetSenseCompressor::compress_frame_into`], single-pass
//!   select+quantize+encode straight into a reusable wire buffer —
//!   bit-identical, zero steady-state allocations).
//! - [`simd`] — runtime-dispatched (AVX2/SSE4.1/scalar) kernels for the
//!   four hot loops: fused compensate+L2, quantize/dequantize, the
//!   threshold scan, and the decode-side ascending-index check. Every
//!   level is bit-identical to the scalar reference.
//! - [`lossless`] — optional 3LC-style lossless stage (byte-plane packing
//!   + zero-run-length encoding) applied after quantization, negotiated
//!   per bucket so incompressible payloads ship raw (codec byte in the
//!   COO header).
//! - [`workspace`] — the per-worker arena of reusable scratch buffers the
//!   fused path runs on ([`Workspace`], [`WorkspacePool`]).
//! - [`bucket`] — split/fuse of flat gradients into fixed-size buckets with
//!   per-bucket error-feedback state, feeding the pipelined exchange
//!   ([`crate::coordinator::pipeline_exchange`]); buckets compress in
//!   parallel across a workspace pool
//!   ([`BucketedCompressor::compress_frames`]).

pub mod bucket;
pub mod error_feedback;
pub mod lossless;
pub mod pipeline;
pub mod prune;
pub mod quantize;
pub mod simd;
pub mod sparse;
pub mod topk;
pub mod workspace;

pub use bucket::{group_indices_by_bytes, BucketLayout, BucketedCompressor};
pub use error_feedback::ErrorFeedback;
pub use pipeline::{
    CompressionConfig, CompressionOutcome, CompressorState, FusedOutcome, NetSenseCompressor,
};
pub use quantize::{f32_to_f16_bits, f16_bits_to_f32, Precision};
pub use simd::{active_level, SimdLevel};
pub use sparse::{
    decode_reduce_frame_into, decode_reduce_into, DecodeReduceOutcome, SparseGradient,
    COO_HEADER_BYTES,
};
pub use workspace::{Workspace, WorkspacePool};
