//! Algorithm 2 end-to-end — `NetSenseCompression: quantization, pruning,
//! and sparsification`, with error feedback and the threshold-reuse top-k
//! fast path.
//!
//! Step 1 (adaptive quantization): if `ratio < tr_q` and `‖g‖₂ > tr_d`,
//! move values to 16-bit floats and double the ratio (same wire budget,
//! twice the surviving coordinates).
//! Step 2 (model pruning): zero gradients of the smallest-|weight|
//! parameters at rate `0.5 × (1 − ratio)`.
//! Step 3 (sparsification): Top-K by |gradient| at `ratio`, COO-encoded.
//!
//! For the bucketed pipelined exchange, one compressor runs per bucket —
//! see [`super::bucket`].
//!
//! ```
//! use netsenseml::compress::{CompressionConfig, NetSenseCompressor};
//!
//! let n = 16;
//! let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
//! let grads: Vec<f32> = (1..=n).map(|i| i as f32).collect();
//! let weights = vec![1.0f32; n];
//! let out = c.compress(&grads, &weights, 0.25);
//! assert_eq!(out.payload.nnz(), 4);          // top-4 of 16 at ratio 0.25
//! assert!(out.wire_bytes < out.dense_bytes); // smaller than dense f32
//! assert_eq!(out.payload.to_dense()[n - 1], 16.0); // largest survives
//! ```

use super::error_feedback::ErrorFeedback;
use super::lossless;
use super::prune::pruning_rate_for;
use super::quantize::Precision;
use super::simd;
use super::sparse::{encode_gathered_into, SparseGradient};
use super::topk::{
    k_for_ratio, kth_magnitude_with, top_k_with_threshold_hint_and_scratch,
    top_k_with_threshold_hint_into,
};
use super::workspace::Workspace;
use crate::transport::frame::encode_frame_header_into;

/// Tunables of Algorithm 2 (paper defaults).
#[derive(Clone, Debug)]
pub struct CompressionConfig {
    /// `tr_q`: quantization kicks in below this compression ratio.
    pub quant_ratio_threshold: f64,
    /// `tr_d`: minimum gradient L2 norm for quantization to be worthwhile.
    pub density_threshold: f64,
    /// Enable step 2 (pruning).
    pub enable_pruning: bool,
    /// Enable error feedback (residual accumulation).
    pub error_feedback: bool,
    /// Slack for threshold-reuse top-k (fraction of k).
    pub topk_slack: f64,
    /// Enable the 3LC-style lossless stage (byte-plane packing + zero-run
    /// length encoding) on the fused emit paths. Negotiated per payload:
    /// the packed candidate ships only when it is strictly smaller than
    /// the raw COO encoding, so incompressible buckets cost nothing but
    /// the encode attempt. Off by default — the raw wire stays
    /// bit-identical to the staged reference.
    pub lossless: bool,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig {
            quant_ratio_threshold: 0.05,
            density_threshold: 1e-3,
            enable_pruning: true,
            error_feedback: true,
            topk_slack: 0.25,
            lossless: false,
        }
    }
}

/// What one compression step did (diagnostics + experiment reporting).
#[derive(Clone, Debug)]
pub struct CompressionOutcome {
    pub payload: SparseGradient,
    pub quantized: bool,
    /// Ratio after the quantization adjustment (Algorithm 2 line 6).
    pub effective_ratio: f64,
    pub pruning_rate: f64,
    pub grad_l2: f64,
    pub wire_bytes: u64,
    /// Wire bytes a dense f32 transfer would have used.
    pub dense_bytes: u64,
}

/// What one *fused* compression step did — the payload never exists as a
/// [`SparseGradient`] on the send side, so this carries the metadata only
/// (the wire bytes land in the caller's buffer).
#[derive(Clone, Debug, Default)]
pub struct FusedOutcome {
    /// Selected coordinate count (== `k_for_ratio(n, effective_ratio)`).
    pub nnz: usize,
    pub quantized: bool,
    /// Ratio after the quantization adjustment (Algorithm 2 line 6).
    pub effective_ratio: f64,
    pub pruning_rate: f64,
    pub grad_l2: f64,
    /// Payload bytes actually emitted (frame header excluded). With the
    /// lossless stage off — or skipped as incompressible — this equals
    /// [`Self::raw_wire_bytes`] and is byte-exact against
    /// [`CompressionOutcome::wire_bytes`] and
    /// [`NetSenseCompressor::predict_wire_bytes`]; when the stage wins it
    /// is strictly smaller.
    pub wire_bytes: u64,
    /// Raw COO payload bytes (the lossless stage's input and
    /// [`NetSenseCompressor::predict_wire_bytes`]'s value — always
    /// `12 + nnz·(4 + precision.bytes())`).
    pub raw_wire_bytes: u64,
    /// Did the lossless stage win the negotiation for this payload?
    pub lossless: bool,
    /// Wire bytes a dense f32 transfer would have used.
    pub dense_bytes: u64,
    /// Wire precision of the payload values.
    pub precision: Precision,
}

/// Stateful Algorithm-2 compressor for one flat gradient tensor.
pub struct NetSenseCompressor {
    pub config: CompressionConfig,
    ef: ErrorFeedback,
    /// Last step's k-th magnitude, reused as a selection pre-filter.
    last_threshold: Option<f32>,
    scratch: Vec<f32>,
    /// Quickselect scratch, reused across steps (§Perf: saves a ~12·n-byte
    /// allocation + fill per selection).
    qs_scratch: Vec<(f32, u32)>,
    /// Cached pruning threshold on |weight| and the rate it was computed
    /// for. Weights drift slowly, so the exact quickselect over the weight
    /// vector is refreshed only when the rate moves or the cache ages out
    /// (§Perf iteration 2; exactness checked in tests to <0.1% mask skew).
    prune_cache: Option<(f64, f32)>,
    prune_cache_age: u32,
    /// Compensated gradient L2 of the most recent [`Self::compress`] call
    /// — lets [`Self::predict_wire_bytes`] honor the quantization-skip
    /// condition (`‖g‖₂ ≤ tr_d`) for near-zero tensors (e.g. a frozen
    /// layer's bucket) instead of assuming the density condition holds.
    last_grad_l2: Option<f64>,
}

/// Steps between exact refreshes of the pruning threshold.
const PRUNE_REFRESH_STEPS: u32 = 64;

impl NetSenseCompressor {
    pub fn new(n: usize, config: CompressionConfig) -> Self {
        NetSenseCompressor {
            config,
            ef: ErrorFeedback::new(n),
            last_threshold: None,
            scratch: Vec::with_capacity(n),
            qs_scratch: Vec::new(),
            prune_cache: None,
            prune_cache_age: 0,
            last_grad_l2: None,
        }
    }

    /// Pruning threshold for `rate` over `weights`, with caching.
    fn prune_threshold(&mut self, weights: &[f32], rate: f64) -> f32 {
        let mut qs = std::mem::take(&mut self.qs_scratch);
        let th = self.prune_threshold_with(weights, rate, &mut qs);
        self.qs_scratch = qs;
        th
    }

    /// [`Self::prune_threshold`] against a caller-owned quickselect buffer
    /// (the fused path routes every scratch through its [`Workspace`]).
    /// The threshold value is independent of which buffer performed the
    /// selection, so staged and fused calls share one cache.
    fn prune_threshold_with(
        &mut self,
        weights: &[f32],
        rate: f64,
        pairs: &mut Vec<(f32, u32)>,
    ) -> f32 {
        let stale = match self.prune_cache {
            None => true,
            Some((cached_rate, _)) => {
                (cached_rate - rate).abs() > 0.02 || self.prune_cache_age >= PRUNE_REFRESH_STEPS
            }
        };
        if stale {
            let n = weights.len();
            let n_prune = k_for_ratio(n, rate).min(n);
            let th = if n_prune == 0 {
                0.0
            } else if n_prune == n {
                f32::MAX
            } else {
                // Anything strictly below the (n−n_prune)-th magnitude is
                // pruned (same rule as PruneMask::smallest_weights).
                kth_magnitude_with(weights, n - n_prune, pairs)
            };
            self.prune_cache = Some((rate, th));
            self.prune_cache_age = 0;
        } else {
            self.prune_cache_age += 1;
        }
        self.prune_cache.unwrap().1
    }

    pub fn n(&self) -> usize {
        self.ef.len()
    }

    /// Residual L2 norm (compression-health metric).
    pub fn residual_norm(&self) -> f64 {
        self.ef.residual_norm()
    }

    /// Run Algorithm 2 on `grads` (length must match `n()`), given the
    /// current `weights` (for pruning) and the controller's `ratio`.
    pub fn compress(
        &mut self,
        grads: &[f32],
        weights: &[f32],
        ratio: f64,
    ) -> CompressionOutcome {
        let n = self.ef.len();
        assert_eq!(grads.len(), n, "gradient length mismatch");
        assert_eq!(weights.len(), n, "weight length mismatch");
        let ratio = ratio.clamp(0.0, 1.0);

        // Error-feedback compensation.
        self.scratch.clear();
        self.scratch.extend_from_slice(grads);
        if self.config.error_feedback {
            self.ef.compensate(&mut self.scratch);
        }

        // ---- Step 1: adaptive quantization --------------------------------
        let grad_l2 = l2(&self.scratch);
        self.last_grad_l2 = Some(grad_l2);
        let mut effective_ratio = ratio;
        let mut precision = Precision::F32;
        let mut quantized = false;
        if ratio < self.config.quant_ratio_threshold && grad_l2 > self.config.density_threshold {
            precision = Precision::F16;
            quantized = true;
            effective_ratio = (2.0 * ratio).min(1.0);
        }

        // ---- Step 2: model pruning ----------------------------------------
        let pruning_rate = if self.config.enable_pruning {
            pruning_rate_for(effective_ratio)
        } else {
            0.0
        };
        if pruning_rate > 0.0 {
            // Fused threshold application: zero the gradients of the
            // smallest-|weight| parameters in one pass (no mask alloc).
            let th = self.prune_threshold(weights, pruning_rate);
            for (g, &w) in self.scratch.iter_mut().zip(weights.iter()) {
                if w.abs() < th {
                    *g = 0.0;
                }
            }
        }

        // ---- Step 3: Top-K sparsification ----------------------------------
        let k = k_for_ratio(n, effective_ratio);
        // Temporarily move the quickselect scratch out to appease borrows.
        let mut qs = std::mem::take(&mut self.qs_scratch);
        let (indices, kth) = top_k_with_threshold_hint_and_scratch(
            &self.scratch,
            k,
            self.last_threshold,
            self.config.topk_slack,
            &mut qs,
        );
        self.qs_scratch = qs;
        self.last_threshold = Some(kth);
        let mut payload = SparseGradient::gather(&self.scratch, indices, precision);
        // Receiver sees wire-precision values; make the local view match so
        // the residual captures quantization error too.
        payload.quantize_values();

        if self.config.error_feedback {
            self.ef.absorb(&self.scratch, &payload);
        }

        CompressionOutcome {
            wire_bytes: payload.wire_bytes(),
            dense_bytes: 4 * n as u64,
            payload,
            quantized,
            effective_ratio,
            pruning_rate,
            grad_l2,
        }
    }

    /// Fused Algorithm 2 straight to wire bytes: one structure-preserving
    /// pass per stage — compensate+L2 fused into a single sweep, pruning
    /// applied in place, threshold-reuse top-k through the caller's
    /// [`Workspace`], then gather+quantize+COO-encode emitted directly
    /// into `out` (appended; exactly `outcome.wire_bytes` bytes). No
    /// [`SparseGradient`] is materialized and, once the workspace and
    /// `out` are warm, the step performs **zero heap allocations**.
    ///
    /// Bit-identical on the wire — and in every piece of compressor state
    /// (residual, threshold hint, prune cache) — to
    /// [`Self::compress`] + [`SparseGradient::encode`], which stays as the
    /// property-tested reference implementation.
    pub fn compress_payload_into(
        &mut self,
        grads: &[f32],
        weights: &[f32],
        ratio: f64,
        ws: &mut Workspace,
        out: &mut Vec<u8>,
    ) -> FusedOutcome {
        let mut outcome = self.fused_select(grads, weights, ratio, ws);
        if self.lossless_stage(ws, &mut outcome) {
            out.extend_from_slice(&ws.lossless);
        } else {
            let bytes = encode_gathered_into(&self.scratch, &ws.indices, outcome.precision, out);
            debug_assert_eq!(bytes, outcome.wire_bytes);
        }
        if self.config.error_feedback {
            // Swap, don't copy: scratch becomes the new residual.
            self.ef
                .absorb_owned(&mut self.scratch, &ws.indices, outcome.precision);
        }
        outcome
    }

    /// [`Self::compress_payload_into`] wrapped in the transport frame: the
    /// payload size is known the moment selection finishes, so the
    /// 8-byte length-prefixed header is written first and the payload
    /// streams in behind it — the full gradient→wire path with no
    /// intermediate buffer at all. Appends `8 + outcome.wire_bytes` bytes.
    pub fn compress_frame_into(
        &mut self,
        grads: &[f32],
        weights: &[f32],
        ratio: f64,
        ws: &mut Workspace,
        out: &mut Vec<u8>,
    ) -> FusedOutcome {
        let mut outcome = self.fused_select(grads, weights, ratio, ws);
        if self.lossless_stage(ws, &mut outcome) {
            out.reserve(8 + ws.lossless.len());
            encode_frame_header_into(ws.lossless.len(), out);
            out.extend_from_slice(&ws.lossless);
        } else {
            out.reserve(8 + outcome.wire_bytes as usize);
            encode_frame_header_into(outcome.wire_bytes as usize, out);
            let bytes = encode_gathered_into(&self.scratch, &ws.indices, outcome.precision, out);
            debug_assert_eq!(bytes, outcome.wire_bytes);
        }
        if self.config.error_feedback {
            // Swap, don't copy: scratch becomes the new residual.
            self.ef
                .absorb_owned(&mut self.scratch, &ws.indices, outcome.precision);
        }
        outcome
    }

    /// Steps 0–3 of the fused path: compensate (+L2 in the same sweep),
    /// quantization decision, in-place pruning, and top-k selection into
    /// `ws.indices`. Leaves the compensated/pruned gradient in
    /// `self.scratch` for the emit and absorb phases. Mirrors
    /// [`Self::compress`] operation-for-operation so both paths stay
    /// bit-identical.
    fn fused_select(
        &mut self,
        grads: &[f32],
        weights: &[f32],
        ratio: f64,
        ws: &mut Workspace,
    ) -> FusedOutcome {
        let n = self.ef.len();
        assert_eq!(grads.len(), n, "gradient length mismatch");
        assert_eq!(weights.len(), n, "weight length mismatch");
        let ratio = ratio.clamp(0.0, 1.0);

        // ---- Fused pass: error-feedback compensate + L2 ------------------
        // (The staged path walks the tensor three times here: copy,
        // compensate, norm. Both kernels use the same 8-lane-striped f64
        // accumulation at every dispatch level → same bits.)
        let l2_sq = if self.config.error_feedback {
            simd::compensate_sum_sq_extend(grads, self.ef.residual(), &mut self.scratch)
        } else {
            self.scratch.clear();
            self.scratch.extend_from_slice(grads);
            simd::sum_sq(&self.scratch)
        };
        let grad_l2 = l2_sq.sqrt();
        self.last_grad_l2 = Some(grad_l2);

        // ---- Step 1: adaptive quantization --------------------------------
        let mut effective_ratio = ratio;
        let mut precision = Precision::F32;
        let mut quantized = false;
        if ratio < self.config.quant_ratio_threshold && grad_l2 > self.config.density_threshold {
            precision = Precision::F16;
            quantized = true;
            effective_ratio = (2.0 * ratio).min(1.0);
        }

        // ---- Step 2: model pruning ----------------------------------------
        let pruning_rate = if self.config.enable_pruning {
            pruning_rate_for(effective_ratio)
        } else {
            0.0
        };
        if pruning_rate > 0.0 {
            let th = self.prune_threshold_with(weights, pruning_rate, &mut ws.pairs);
            for (g, &w) in self.scratch.iter_mut().zip(weights.iter()) {
                if w.abs() < th {
                    *g = 0.0;
                }
            }
        }

        // ---- Step 3: Top-K sparsification ---------------------------------
        let k = k_for_ratio(n, effective_ratio);
        let kth = top_k_with_threshold_hint_into(
            &self.scratch,
            k,
            self.last_threshold,
            self.config.topk_slack,
            &mut ws.pairs,
            &mut ws.cand,
            &mut ws.sub,
            &mut ws.sub_keep,
            &mut ws.indices,
        );
        self.last_threshold = Some(kth);

        let raw_wire_bytes = 12 + (ws.indices.len() as u64) * (4 + precision.bytes() as u64);
        FusedOutcome {
            nnz: ws.indices.len(),
            quantized,
            effective_ratio,
            pruning_rate,
            grad_l2,
            wire_bytes: raw_wire_bytes,
            raw_wire_bytes,
            lossless: false,
            dense_bytes: 4 * n as u64,
            precision,
        }
    }

    /// Lossless negotiation on the fused emit paths: when
    /// [`CompressionConfig::lossless`] is set, encode the byte-plane +
    /// zero-run candidate into `ws.lossless` and ship it iff it is
    /// strictly smaller than the raw COO payload. Updates `outcome`
    /// (`wire_bytes`, `lossless`) and the obs byte-ratio metrics; returns
    /// whether the candidate won (caller then emits `ws.lossless` instead
    /// of running [`encode_gathered_into`]).
    fn lossless_stage(&mut self, ws: &mut Workspace, outcome: &mut FusedOutcome) -> bool {
        if !self.config.lossless {
            return false;
        }
        let raw = outcome.raw_wire_bytes;
        let packed = lossless::encode_gathered_lossless_into(
            &self.scratch,
            &ws.indices,
            outcome.precision,
            &mut ws.val_bits,
            &mut ws.lossless,
        ) as u64;
        let m = crate::obs::hot();
        m.lossless_raw_bytes_total.add(raw);
        if packed < raw {
            outcome.wire_bytes = packed;
            outcome.lossless = true;
            m.lossless_wire_bytes_total.add(packed);
            m.lossless_ratio_pct.observe(packed * 100 / raw);
            true
        } else {
            m.lossless_wire_bytes_total.add(raw);
            m.lossless_skipped_total.inc();
            false
        }
    }

    /// Predict the wire size Algorithm 2 would produce for a ratio without
    /// running it (used by the controller to pick ratios against the BDP,
    /// and by `sync_predicted` for timing-only rounds).
    ///
    /// The quantization branch honors *both* of step 1's conditions: the
    /// ratio test (`ratio < tr_q`) and the density test (`‖g‖₂ > tr_d`),
    /// the latter via the compensated gradient norm cached by the most
    /// recent [`Self::compress`] call. A frozen tensor (zero gradients, so
    /// zero cached norm — error feedback keeps it pinned there) therefore
    /// predicts the quantization-*skip* size, byte-exact against the full
    /// path. Before the first compress there is no norm to consult and the
    /// steady-state density assumption applies.
    ///
    /// With [`CompressionConfig::lossless`] enabled the prediction is the
    /// *raw* COO size ([`FusedOutcome::raw_wire_bytes`]) — an upper bound
    /// on the emitted bytes, since the packed candidate only ships when it
    /// is strictly smaller. The controller sizing against the BDP stays
    /// safe (never under-predicts), just conservative.
    pub fn predict_wire_bytes(&self, ratio: f64) -> u64 {
        let ratio = ratio.clamp(0.0, 1.0);
        let (eff, prec) = if self.would_quantize(ratio) {
            ((2.0 * ratio).min(1.0), Precision::F16)
        } else {
            (ratio, Precision::F32)
        };
        let k = k_for_ratio(self.n(), eff) as u64;
        12 + k * (4 + prec.bytes() as u64)
    }

    /// Would Algorithm 2's step 1 quantize at `ratio`? Same contract as
    /// [`Self::predict_wire_bytes`]: the density test uses the cached
    /// compensated norm; with no compress yet, density is assumed to hold.
    pub fn would_quantize(&self, ratio: f64) -> bool {
        let density_ok = self
            .last_grad_l2
            .map(|l2| l2 > self.config.density_threshold)
            .unwrap_or(true);
        ratio.clamp(0.0, 1.0) < self.config.quant_ratio_threshold && density_ok
    }

    /// Snapshot everything that makes future compress calls a pure
    /// function of future inputs: the error-feedback residual plus the
    /// selection caches (threshold hint, pruning cache, cached norm). A
    /// compressor restored from this state continues **bit-identically**
    /// to the original — the contract [`crate::fault::Checkpoint`] gives
    /// a rejoining rank.
    pub fn export_state(&self) -> CompressorState {
        CompressorState {
            residual: self.ef.residual().to_vec(),
            last_threshold: self.last_threshold,
            prune_cache: self.prune_cache,
            prune_cache_age: self.prune_cache_age,
            last_grad_l2: self.last_grad_l2,
        }
    }

    /// Restore a [`Self::export_state`] snapshot (tensor length must
    /// match).
    pub fn import_state(&mut self, state: &CompressorState) {
        self.ef.restore(&state.residual);
        self.last_threshold = state.last_threshold;
        self.prune_cache = state.prune_cache;
        self.prune_cache_age = state.prune_cache_age;
        self.last_grad_l2 = state.last_grad_l2;
    }
}

/// The serializable state of one [`NetSenseCompressor`] (one tensor or
/// one bucket): the error-feedback residual and the caches that make the
/// next compress call reproducible bit-for-bit. Wire format lives in
/// [`crate::fault::Checkpoint`].
#[derive(Clone, Debug, PartialEq)]
pub struct CompressorState {
    pub residual: Vec<f32>,
    /// Last step's k-th magnitude (top-k threshold-reuse hint — it
    /// changes which coordinates the fast path selects, so it must
    /// survive a restore for bit-exact resumption).
    pub last_threshold: Option<f32>,
    /// Cached `(pruning_rate, |weight| threshold)`.
    pub prune_cache: Option<(f64, f32)>,
    pub prune_cache_age: u32,
    /// Compensated gradient L2 of the most recent compress (the
    /// quantization-skip predictor).
    pub last_grad_l2: Option<f64>,
}

/// L2 norm via the runtime-dispatched striped sum-of-squares kernel. Every
/// dispatch level — and the fused compensate+L2 sweep — accumulates in the
/// same 8-lane-striped f64 order, so staged and fused norms stay
/// f64-bit-identical.
fn l2(xs: &[f32]) -> f64 {
    simd::sum_sq(xs).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg64::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }

    #[test]
    fn high_ratio_no_quantization() {
        let n = 1000;
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let out = c.compress(&randn(n, 1), &randn(n, 2), 0.5);
        assert!(!out.quantized);
        assert_eq!(out.effective_ratio, 0.5);
        assert_eq!(out.payload.precision, Precision::F32);
        assert_eq!(out.payload.nnz(), 500);
    }

    #[test]
    fn low_ratio_triggers_quantization_and_doubles_ratio() {
        let n = 1000;
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let out = c.compress(&randn(n, 1), &randn(n, 2), 0.01);
        assert!(out.quantized);
        assert!((out.effective_ratio - 0.02).abs() < 1e-12);
        assert_eq!(out.payload.precision, Precision::F16);
        assert_eq!(out.payload.nnz(), 20);
    }

    #[test]
    fn tiny_gradient_norm_skips_quantization() {
        let n = 1000;
        let mut cfg = CompressionConfig::default();
        cfg.density_threshold = 1e3; // absurdly high → never quantize
        let mut c = NetSenseCompressor::new(n, cfg);
        let out = c.compress(&randn(n, 1), &randn(n, 2), 0.01);
        assert!(!out.quantized);
        assert_eq!(out.effective_ratio, 0.01);
    }

    #[test]
    fn pruning_rate_follows_rule() {
        let n = 1000;
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let out = c.compress(&randn(n, 1), &randn(n, 2), 0.5);
        assert!((out.pruning_rate - 0.25).abs() < 1e-12);
        let out = c.compress(&randn(n, 3), &randn(n, 4), 1.0);
        assert_eq!(out.pruning_rate, 0.0);
    }

    #[test]
    fn pruning_disabled() {
        let n = 100;
        let cfg = CompressionConfig {
            enable_pruning: false,
            ..Default::default()
        };
        let mut c = NetSenseCompressor::new(n, cfg);
        let out = c.compress(&randn(n, 1), &randn(n, 2), 0.5);
        assert_eq!(out.pruning_rate, 0.0);
    }

    #[test]
    fn wire_bytes_shrink_with_ratio() {
        let n = 10_000;
        let g = randn(n, 5);
        let w = randn(n, 6);
        let sizes: Vec<u64> = [1.0, 0.5, 0.1, 0.01]
            .iter()
            .map(|&r| {
                let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
                c.compress(&g, &w, r).wire_bytes
            })
            .collect();
        assert!(sizes.windows(2).all(|s| s[0] > s[1]), "{sizes:?}");
        // Dense baseline for comparison.
        assert_eq!(sizes[0], 12 + 8 * n as u64); // ratio 1.0 → all indices
    }

    #[test]
    fn predict_matches_actual() {
        let n = 5000;
        let g = randn(n, 7);
        let w = randn(n, 8);
        for &r in &[1.0, 0.3, 0.1, 0.04, 0.01, 0.005] {
            let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
            let predicted = c.predict_wire_bytes(r);
            let actual = c.compress(&g, &w, r).wire_bytes;
            assert_eq!(predicted, actual, "ratio {r}");
        }
    }

    #[test]
    fn predict_honors_quantization_skip_for_near_zero_gradients() {
        // A frozen tensor (zero gradients) fails the density condition, so
        // the full path skips quantization at low ratios; the prediction
        // must follow once it has a norm to consult — and stay exact for a
        // healthy tensor.
        let n = 5000;
        let w = randn(n, 21);
        let zeros = vec![0f32; n];
        let mut frozen = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut healthy = NetSenseCompressor::new(n, CompressionConfig::default());
        let g = randn(n, 22);
        // Prime the caches (step 0 is full-fidelity in mixed-mode runs).
        frozen.compress(&zeros, &w, 0.01);
        healthy.compress(&g, &w, 0.01);
        for &r in &[0.04, 0.01, 0.005] {
            let predicted = frozen.predict_wire_bytes(r);
            let out = frozen.compress(&zeros, &w, r);
            assert!(!out.quantized, "zero gradient must skip quantization");
            assert_eq!(predicted, out.wire_bytes, "frozen, ratio {r}");

            let predicted = healthy.predict_wire_bytes(r);
            let out = healthy.compress(&g, &w, r);
            assert!(out.quantized);
            assert_eq!(predicted, out.wire_bytes, "healthy, ratio {r}");
        }
    }

    #[test]
    fn error_feedback_accumulates_across_steps() {
        let n = 1000;
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let g = randn(n, 9);
        let w = randn(n, 10);
        c.compress(&g, &w, 0.01);
        let r1 = c.residual_norm();
        assert!(r1 > 0.0);
        // Feeding zero gradients: residual mass drains into payloads.
        let zeros = vec![0f32; n];
        for _ in 0..200 {
            c.compress(&zeros, &w, 0.1);
        }
        let r2 = c.residual_norm();
        assert!(r2 < r1 * 0.5, "residual did not drain: {r1} → {r2}");
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let n = 512;
        let g = randn(n, 11);
        let w = randn(n, 12);
        let mut c1 = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut c2 = NetSenseCompressor::new(n, CompressionConfig::default());
        for &r in &[0.5, 0.2, 0.05, 0.01] {
            let o1 = c1.compress(&g, &w, r);
            let o2 = c2.compress(&g, &w, r);
            assert_eq!(o1.payload, o2.payload);
        }
    }

    #[test]
    fn ratio_one_transmits_everything_minus_pruning() {
        let n = 100;
        let cfg = CompressionConfig {
            enable_pruning: false,
            error_feedback: false,
            ..Default::default()
        };
        let mut c = NetSenseCompressor::new(n, cfg);
        let g = randn(n, 13);
        let out = c.compress(&g, &randn(n, 14), 1.0);
        assert_eq!(out.payload.nnz(), n);
        assert_eq!(out.payload.to_dense(), g);
    }

    #[test]
    fn lossless_frames_decode_bit_identical_to_raw_twins() {
        // Two compressors in lockstep — one raw, one with the lossless
        // stage — must produce frames that decode-reduce to bit-identical
        // dense updates, with the lossless wire never larger than raw and
        // strictly smaller somewhere along the run.
        use crate::compress::sparse::decode_reduce_frame_into;
        use crate::compress::workspace::Workspace;
        let n = 3000;
        let w = randn(n, 31);
        let mut g = randn(n, 32);
        let mut rng = Pcg64::seeded(33);
        let mut raw = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut packed = NetSenseCompressor::new(
            n,
            CompressionConfig {
                lossless: true,
                ..Default::default()
            },
        );
        let mut ws = Workspace::with_capacity(n);
        let (mut raw_frame, mut packed_frame) = (Vec::new(), Vec::new());
        let mut wins = 0;
        for (step, &ratio) in [0.1, 0.05, 0.01, 0.003, 1.0, 0.0, 0.1]
            .iter()
            .cycle()
            .take(21)
            .enumerate()
        {
            for x in g.iter_mut() {
                *x += 0.05 * rng.normal() as f32;
            }
            raw_frame.clear();
            packed_frame.clear();
            let or = raw.compress_frame_into(&g, &w, ratio, &mut ws, &mut raw_frame);
            let op = packed.compress_frame_into(&g, &w, ratio, &mut ws, &mut packed_frame);
            assert!(!or.lossless, "step {step}: raw config took the stage");
            assert_eq!(or.wire_bytes, or.raw_wire_bytes, "step {step}");
            assert_eq!(op.raw_wire_bytes, or.raw_wire_bytes, "step {step}");
            assert!(
                op.wire_bytes <= op.raw_wire_bytes,
                "step {step}: negotiation shipped a larger payload"
            );
            assert_eq!(op.lossless, op.wire_bytes < op.raw_wire_bytes);
            wins += op.lossless as u32;
            let mut acc_raw = vec![0f32; n];
            let mut acc_packed = vec![0f32; n];
            decode_reduce_frame_into(&raw_frame, &mut acc_raw).expect("raw frame decodes");
            decode_reduce_frame_into(&packed_frame, &mut acc_packed)
                .expect("lossless frame decodes");
            for (i, (a, b)) in acc_raw.iter().zip(&acc_packed).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "step {step} element {i}");
            }
            // Identical decoded updates → identical compressor evolution.
            assert_eq!(raw.residual_norm(), packed.residual_norm(), "step {step}");
        }
        assert!(wins > 0, "lossless stage never won on quantized payloads");
    }

    #[test]
    fn predict_is_upper_bound_under_lossless() {
        use crate::compress::workspace::Workspace;
        let n = 2000;
        let g = randn(n, 41);
        let w = randn(n, 42);
        let mut c = NetSenseCompressor::new(
            n,
            CompressionConfig {
                lossless: true,
                ..Default::default()
            },
        );
        let mut ws = Workspace::new();
        let mut out = Vec::new();
        for &r in &[0.3, 0.1, 0.01, 0.003] {
            let predicted = c.predict_wire_bytes(r);
            out.clear();
            let o = c.compress_frame_into(&g, &w, r, &mut ws, &mut out);
            assert_eq!(predicted, o.raw_wire_bytes, "ratio {r}");
            assert!(o.wire_bytes <= predicted, "ratio {r}");
            assert_eq!(out.len() as u64, 8 + o.wire_bytes, "ratio {r}");
        }
    }

    #[test]
    fn steady_state_uses_threshold_hint_consistently() {
        // Run many steps with slowly drifting gradients; outcomes must keep
        // nnz near k even via the fast path.
        let n = 4096;
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut r = Pcg64::seeded(15);
        let w = randn(n, 16);
        let mut g = randn(n, 17);
        for step in 0..20 {
            for x in g.iter_mut() {
                *x += 0.05 * r.normal() as f32;
            }
            let out = c.compress(&g, &w, 0.1);
            let k = (n as f64 * 0.1) as usize;
            let lo = (k as f64 * 0.75) as usize;
            let hi = (k as f64 * 1.3) as usize;
            assert!(
                (lo..=hi).contains(&out.payload.nnz()),
                "step {step}: nnz {} vs k {k}",
                out.payload.nnz()
            );
        }
    }
}
