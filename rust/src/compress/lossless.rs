//! 3LC-style lossless stage: zero-run-length encoding over byte planes of
//! the quantized COO payload (DESIGN.md §3.11; PAPERS.md: Lim et al.,
//! "3LC", arXiv 1802.07389).
//!
//! Quantized gradient payloads are highly structured: index deltas of a
//! top-k selection are small (high delta bytes are almost all zero), and
//! the low mantissa byte of f16/bf16 values clusters near zero for
//! small-magnitude gradients. Splitting each little-endian word into byte
//! planes and run-length-encoding the zeros typically buys another ~2×
//! wire reduction **at zero accuracy cost** — decode is bit-exact.
//!
//! # Wire layout (codec byte = 1 in the COO header)
//!
//! ```text
//! offset 0   [u32 n_total]                  ┐
//! offset 4   [u32 nnz]                      │ standard 12-byte COO header
//! offset 8   [u8 precision][u8 codec=1]     │ (codec was a pad byte; raw
//! offset 10  [u8 0][u8 0]                   ┘  frames carry codec=0)
//! offset 12  plane 0   [u32 comp_len][comp_len bytes ZRLE]
//!            plane 1   …
//!            …
//! ```
//!
//! There are `4 + precision.bytes()` planes: four for the
//! **delta-encoded indices** (`d₀ = idx₀`, `dⱼ = idxⱼ − idxⱼ₋₁ − 1`;
//! strictly-ascending by construction on decode), then one per value
//! byte. Plane *p* holds byte *p* (little-endian) of every word, in
//! element order; each plane decodes to exactly `nnz` bytes.
//!
//! # ZRLE token stream
//!
//! A control byte `c < 0x80` is a **literal** run: the next `c + 1` bytes
//! are copied verbatim. A control byte `c ≥ 0x80` is a **zero** run of
//! `c − 0x7f` bytes (1–128). The encoder emits zero tokens only for
//! maximal zero runs of length ≥ 2 (isolated zeros ride inside literals),
//! bounding worst-case expansion at ~0.8%; the decoder accepts any
//! well-formed token stream. Per-bucket negotiation in
//! [`crate::compress::NetSenseCompressor::compress_frame_into`] ships the
//! raw codec whenever the staged payload would not shrink, so
//! incompressible buckets never pay the expansion.
//!
//! # Contracts
//!
//! - **Bit-exact**: a lossless frame decodes to exactly the bytes the raw
//!   twin would carry — fused decode-reduce and the staged
//!   [`SparseGradient`] decoder both accept it with identical results.
//! - **Accumulator untouched on error**: the fused decoder fully
//!   validates structure, indices, and plane totals *before* the first
//!   scatter.
//! - **Zero allocations** on the encode and fused-decode success paths
//!   (the encoder writes into caller-owned scratch; the decoder streams
//!   from borrowed planes).

use super::quantize::{bf16_bits_to_f32, f16_bits_to_f32, Precision};
use super::sparse::{DecodeReduceOutcome, SparseGradient, COO_HEADER_BYTES};

/// Codec tag for this stage in COO header byte 9 (raw frames carry 0).
pub(crate) const CODEC_LOSSLESS: u8 = 1;

/// Upper bound on plane count (4 index planes + up to 4 value planes).
const MAX_PLANES: usize = 8;

fn truncated() -> String {
    "lossless plane truncated".to_string()
}

// ---------------------------------------------------------------------------
// ZRLE encode
// ---------------------------------------------------------------------------

/// Append the ZRLE stream for the `n`-byte virtual sequence `byte_at` to
/// `out`. Canonical form: maximal zero runs ≥ 2 become zero tokens,
/// everything else is packed into literal tokens of ≤ 128 bytes.
fn zrle_encode<F: Fn(usize) -> u8>(n: usize, byte_at: F, out: &mut Vec<u8>) {
    let mut i = 0usize;
    while i < n {
        // Literal segment [i, j): stops where a zero run of ≥ 2 begins.
        let mut j = i;
        while j < n {
            if byte_at(j) == 0 && j + 1 < n && byte_at(j + 1) == 0 {
                break;
            }
            j += 1;
        }
        let mut s = i;
        while s < j {
            let take = (j - s).min(128);
            out.push((take - 1) as u8);
            for t in s..s + take {
                out.push(byte_at(t));
            }
            s += take;
        }
        i = j;
        // Zero segment: all zeros from here (≥ 2 by the break condition,
        // or we are at the end).
        let mut z = i;
        while z < n && byte_at(z) == 0 {
            z += 1;
        }
        let mut left = z - i;
        while left > 0 {
            let take = left.min(128);
            out.push((0x7f + take) as u8);
            left -= take;
        }
        i = z;
    }
}

/// Write one `[u32 comp_len][ZRLE]` plane section.
fn encode_plane<F: Fn(usize) -> u8>(n: usize, byte_at: F, out: &mut Vec<u8>) {
    let len_pos = out.len();
    out.extend_from_slice(&[0u8; 4]);
    let start = out.len();
    zrle_encode(n, byte_at, out);
    let comp = (out.len() - start) as u32;
    out[len_pos..len_pos + 4].copy_from_slice(&comp.to_le_bytes());
}

#[inline]
fn index_delta(indices: &[u32], j: usize) -> u32 {
    if j == 0 {
        indices[0]
    } else {
        indices[j] - indices[j - 1] - 1
    }
}

/// Encode `dense[indices]` at `precision` as a complete lossless COO
/// payload (header included) into `out`, which is cleared first.
/// `val_bits` is caller scratch for the quantized wire words (reused
/// across steps → zero steady-state allocations). Returns the payload
/// length; the caller compares it against the raw size
/// (`12 + nnz·(4 + precision.bytes())`) and ships whichever is smaller.
pub(crate) fn encode_gathered_lossless_into(
    dense: &[f32],
    indices: &[u32],
    precision: Precision,
    val_bits: &mut Vec<u32>,
    out: &mut Vec<u8>,
) -> usize {
    let nnz = indices.len();
    out.clear();
    out.extend_from_slice(&(dense.len() as u32).to_le_bytes());
    out.extend_from_slice(&(nnz as u32).to_le_bytes());
    out.push(match precision {
        Precision::F32 => 0,
        Precision::F16 => 1,
        Precision::Bf16 => 2,
    });
    out.push(CODEC_LOSSLESS);
    out.extend_from_slice(&[0u8; 2]);
    // Quantize once into scratch; the planes read these words. The same
    // conversions as the raw wire path, so decode is bit-identical to the
    // raw twin.
    val_bits.clear();
    val_bits.reserve(nnz);
    match precision {
        Precision::F32 => {
            for &i in indices {
                val_bits.push(dense[i as usize].to_bits());
            }
        }
        Precision::F16 => {
            for &i in indices {
                val_bits.push(super::quantize::f32_to_f16_bits(dense[i as usize]) as u32);
            }
        }
        Precision::Bf16 => {
            for &i in indices {
                val_bits.push(super::quantize::f32_to_bf16_bits(dense[i as usize]) as u32);
            }
        }
    }
    for p in 0..4usize {
        let shift = 8 * p as u32;
        encode_plane(nnz, |j| (index_delta(indices, j) >> shift) as u8, out);
    }
    for p in 0..precision.bytes() {
        let shift = 8 * p as u32;
        encode_plane(nnz, |j| (val_bits[j] >> shift) as u8, out);
    }
    out.len()
}

// ---------------------------------------------------------------------------
// Decode
// ---------------------------------------------------------------------------

/// A streaming reader over one plane's ZRLE tokens (borrowed, no
/// allocation on the success path).
struct PlaneStream<'a> {
    data: &'a [u8],
    pos: usize,
    zeros_left: usize,
    lit_left: usize,
}

impl<'a> PlaneStream<'a> {
    fn new(data: &'a [u8]) -> Self {
        PlaneStream {
            data,
            pos: 0,
            zeros_left: 0,
            lit_left: 0,
        }
    }

    fn next_byte(&mut self) -> Result<u8, String> {
        loop {
            if self.zeros_left > 0 {
                self.zeros_left -= 1;
                return Ok(0);
            }
            if self.lit_left > 0 {
                let b = *self.data.get(self.pos).ok_or_else(truncated)?;
                self.pos += 1;
                self.lit_left -= 1;
                return Ok(b);
            }
            let c = *self.data.get(self.pos).ok_or_else(truncated)?;
            self.pos += 1;
            if c < 0x80 {
                self.lit_left = c as usize + 1;
            } else {
                self.zeros_left = c as usize - 0x7f;
            }
        }
    }

    /// True once every token has been fully consumed.
    fn finished(&self) -> bool {
        self.pos == self.data.len() && self.zeros_left == 0 && self.lit_left == 0
    }
}

/// Structural view of a lossless payload: the plane slices, bounds-checked
/// against the buffer (total length must match exactly, mirroring the raw
/// codec's "bad length" contract).
pub(crate) struct LosslessView<'a> {
    planes: [&'a [u8]; MAX_PLANES],
    n_planes: usize,
}

pub(crate) fn parse_lossless_planes(
    buf: &[u8],
    precision: Precision,
) -> Result<LosslessView<'_>, String> {
    let n_planes = 4 + precision.bytes();
    let mut planes: [&[u8]; MAX_PLANES] = [&[]; MAX_PLANES];
    let mut off = COO_HEADER_BYTES;
    for slot in planes.iter_mut().take(n_planes) {
        if buf.len() < off + 4 {
            return Err(truncated());
        }
        let len = u32::from_le_bytes(buf[off..off + 4].try_into().unwrap()) as usize;
        off += 4;
        if buf.len() - off < len {
            return Err(truncated());
        }
        *slot = &buf[off..off + len];
        off += len;
    }
    if off != buf.len() {
        return Err(format!("bad length {} (expected {off})", buf.len()));
    }
    Ok(LosslessView { planes, n_planes })
}

/// Streaming walk over a parsed payload: reconstructs `(index, word)`
/// pairs, enforcing the strictly-ascending-by-construction index chain
/// and the `n_total` bound as it goes.
struct LosslessReader<'a> {
    streams: [PlaneStream<'a>; MAX_PLANES],
    n_val_planes: usize,
    prev: i64,
    n_total: usize,
}

impl<'a> LosslessReader<'a> {
    fn new(view: &LosslessView<'a>, n_total: usize) -> Self {
        LosslessReader {
            streams: std::array::from_fn(|p| PlaneStream::new(view.planes[p])),
            n_val_planes: view.n_planes - 4,
            prev: -1,
            n_total,
        }
    }

    fn next_entry(&mut self) -> Result<(u32, u32), String> {
        let mut d = 0u32;
        for p in 0..4usize {
            d |= (self.streams[p].next_byte()? as u32) << (8 * p as u32);
        }
        // Delta-plus-one chain: ascending by construction, so the only
        // index failure mode left is the n_total bound.
        let i = if self.prev < 0 {
            d as i64
        } else {
            self.prev + 1 + d as i64
        };
        if i >= self.n_total as i64 {
            return Err(format!("index {i} out of range {}", self.n_total));
        }
        self.prev = i;
        let mut w = 0u32;
        for p in 0..self.n_val_planes {
            w |= (self.streams[4 + p].next_byte()? as u32) << (8 * p as u32);
        }
        Ok((i as u32, w))
    }

    /// After `nnz` entries every plane must be exactly drained — a plane
    /// whose tokens decode to more than `nnz` bytes is malformed.
    fn finish(&self) -> Result<(), String> {
        let live = self.n_val_planes + 4;
        for s in self.streams.iter().take(live) {
            if !s.finished() {
                return Err("lossless plane length mismatch".to_string());
            }
        }
        Ok(())
    }
}

/// Shared validation walk: proves the whole payload well-formed (bounds,
/// plane totals) without touching any accumulator. Both decoders run this
/// first so they accept exactly the same frames by construction.
fn validate(view: &LosslessView<'_>, n_total: usize, nnz: usize) -> Result<(), String> {
    if nnz > n_total {
        // Strictly-ascending indices in [0, n_total) can't number more
        // than n_total; rejecting early also bounds the token walk.
        return Err(format!("nnz {nnz} exceeds n_total {n_total}"));
    }
    let mut r = LosslessReader::new(view, n_total);
    for _ in 0..nnz {
        r.next_entry()?;
    }
    r.finish()
}

#[inline]
fn word_to_f32(w: u32, precision: Precision) -> f32 {
    match precision {
        Precision::F32 => f32::from_bits(w),
        Precision::F16 => f16_bits_to_f32(w as u16),
        Precision::Bf16 => bf16_bits_to_f32(w as u16),
    }
}

/// Fused decode + accumulate for a lossless payload — the codec-1 branch
/// of [`crate::compress::decode_reduce_into`]. Two passes: a full
/// validation walk (accumulator untouched on any error), then the
/// reconstruct + scatter sweep. Zero heap allocations on success.
pub(crate) fn decode_reduce_lossless(
    buf: &[u8],
    n_total: usize,
    nnz: usize,
    precision: Precision,
    out: &mut [f32],
) -> Result<DecodeReduceOutcome, String> {
    let view = parse_lossless_planes(buf, precision)?;
    validate(&view, n_total, nnz)?;
    let mut r = LosslessReader::new(&view, n_total);
    for _ in 0..nnz {
        // Cannot fail: validate() walked the identical token stream.
        let (i, w) = r.next_entry()?;
        out[i as usize] += word_to_f32(w, precision);
    }
    Ok(DecodeReduceOutcome { nnz, precision })
}

/// Staged (allocating) decoder for a lossless payload — the codec-1
/// branch of [`SparseGradient::decode`]. Accepts exactly the frames
/// [`decode_reduce_lossless`] accepts (shared [`validate`] walk), so the
/// fused-vs-staged differential holds on this surface too.
pub(crate) fn decode_lossless_sparse(
    buf: &[u8],
    n_total: usize,
    nnz: usize,
    precision: Precision,
) -> Result<SparseGradient, String> {
    let view = parse_lossless_planes(buf, precision)?;
    validate(&view, n_total, nnz)?;
    let mut indices = Vec::with_capacity(nnz);
    let mut values = Vec::with_capacity(nnz);
    let mut r = LosslessReader::new(&view, n_total);
    for _ in 0..nnz {
        let (i, w) = r.next_entry()?;
        indices.push(i);
        values.push(word_to_f32(w, precision));
    }
    Ok(SparseGradient {
        n_total,
        indices,
        values,
        precision,
    })
}

/// Decode one plane of a payload into `dst` (test/tooling helper): plane
/// `p` must decode to exactly `dst.len()` bytes.
#[cfg(test)]
fn decode_plane(view: &LosslessView<'_>, p: usize, dst: &mut [u8]) -> Result<(), String> {
    let mut s = PlaneStream::new(view.planes[p]);
    for b in dst.iter_mut() {
        *b = s.next_byte()?;
    }
    if !s.finished() {
        return Err("lossless plane length mismatch".to_string());
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Raw-size accounting
// ---------------------------------------------------------------------------

/// The raw-codec size this payload would occupy — the negotiation
/// baseline (`12 + nnz·(4 + value_bytes)`).
pub(crate) fn raw_wire_bytes(nnz: usize, precision: Precision) -> usize {
    COO_HEADER_BYTES + nnz * (4 + precision.bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sparse::decode_reduce_into;
    use crate::compress::topk::top_k_indices;
    use crate::util::rng::Pcg64;

    /// Round-trip through the standalone ZRLE codec.
    fn zrle_roundtrip(bytes: &[u8]) -> Vec<u8> {
        let mut enc = Vec::new();
        zrle_encode(bytes.len(), |i| bytes[i], &mut enc);
        let mut s = PlaneStream::new(&enc);
        let mut out = vec![0u8; bytes.len()];
        for b in out.iter_mut() {
            *b = s.next_byte().unwrap();
        }
        assert!(s.finished(), "tokens must drain exactly");
        out
    }

    #[test]
    fn zrle_roundtrips_edge_patterns() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],
            vec![0],
            vec![7],
            vec![0, 0],
            vec![0; 5],
            vec![0; 128],
            vec![0; 129],
            vec![0; 300],
            vec![1; 200],
            vec![1, 0, 2, 0, 3],          // isolated zeros stay literal
            vec![0, 0, 1, 0, 0, 2, 0, 0], // zero runs around literals
            vec![5, 0],                   // single trailing zero
            (0..=255u8).collect(),
            [vec![0; 130], vec![9], vec![0; 2]].concat(),
        ];
        for c in cases {
            assert_eq!(zrle_roundtrip(&c), c, "pattern {:?}…", &c[..c.len().min(8)]);
        }
    }

    #[test]
    fn zrle_roundtrips_random_buffers() {
        let mut rng = Pcg64::seeded(0x31c0);
        for len in [1usize, 3, 17, 64, 255, 1024] {
            for density in [0u64, 2, 5, 9] {
                let bytes: Vec<u8> = (0..len)
                    .map(|_| {
                        if rng.next_u64() % 10 <= density {
                            0
                        } else {
                            rng.next_u64() as u8
                        }
                    })
                    .collect();
                assert_eq!(zrle_roundtrip(&bytes), bytes);
            }
        }
    }

    #[test]
    fn zrle_compresses_sparse_planes() {
        let mut bytes = vec![0u8; 1000];
        bytes[3] = 7;
        bytes[500] = 9;
        let mut enc = Vec::new();
        zrle_encode(bytes.len(), |i| bytes[i], &mut enc);
        assert!(enc.len() < 30, "ZRLE stream was {} bytes", enc.len());
    }

    fn sample_payload(precision: Precision) -> (Vec<f32>, Vec<u32>, Vec<u8>) {
        let mut rng = Pcg64::seeded(77);
        let n = 512usize;
        let dense: Vec<f32> = (0..n)
            .map(|_| (rng.next_u64() as i32 as f32) * 1e-7)
            .collect();
        let indices = top_k_indices(&dense, 40);
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        encode_gathered_lossless_into(&dense, &indices, precision, &mut scratch, &mut out);
        (dense, indices, out)
    }

    #[test]
    fn lossless_decodes_bit_identical_to_raw_twin() {
        for precision in [Precision::F32, Precision::F16, Precision::Bf16] {
            let (dense, indices, wire) = sample_payload(precision);
            let mut raw = Vec::new();
            crate::compress::sparse::encode_gathered_into(&dense, &indices, precision, &mut raw);
            let mut from_lossless = vec![0f32; dense.len()];
            let o1 = decode_reduce_into(&wire, &mut from_lossless).unwrap();
            let mut from_raw = vec![0f32; dense.len()];
            let o2 = decode_reduce_into(&raw, &mut from_raw).unwrap();
            assert_eq!(o1, o2);
            let a: Vec<u32> = from_lossless.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = from_raw.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b, "lossless decode must be bit-identical ({precision:?})");
        }
    }

    #[test]
    fn lossless_staged_decode_matches_fused() {
        for precision in [Precision::F32, Precision::F16, Precision::Bf16] {
            let (dense, _indices, wire) = sample_payload(precision);
            let staged = SparseGradient::decode(&wire).unwrap();
            let mut fused = vec![0f32; dense.len()];
            decode_reduce_into(&wire, &mut fused).unwrap();
            let dense_staged = staged.to_dense();
            let a: Vec<u32> = dense_staged.iter().map(|x| x.to_bits()).collect();
            let b: Vec<u32> = fused.iter().map(|x| x.to_bits()).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn lossless_shrinks_quantized_payloads() {
        for precision in [Precision::F16, Precision::Bf16] {
            let (_dense, indices, wire) = sample_payload(precision);
            let raw = raw_wire_bytes(indices.len(), precision);
            assert!(
                wire.len() < raw,
                "{precision:?}: lossless {} !< raw {raw}",
                wire.len()
            );
        }
    }

    #[test]
    fn lossless_empty_payload_roundtrips() {
        let dense = vec![0f32; 16];
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        encode_gathered_lossless_into(&dense, &[], Precision::F16, &mut scratch, &mut out);
        let mut acc = vec![0f32; 16];
        let o = decode_reduce_into(&out, &mut acc).unwrap();
        assert_eq!(o.nnz, 0);
        assert_eq!(acc, vec![0f32; 16]);
    }

    #[test]
    fn lossless_planes_decode_to_expected_bytes() {
        let (_dense, indices, wire) = sample_payload(Precision::F16);
        let view = parse_lossless_planes(&wire, Precision::F16).unwrap();
        let nnz = indices.len();
        // plane 0 of the indices must be the low delta bytes
        let mut plane0 = vec![0u8; nnz];
        decode_plane(&view, 0, &mut plane0).unwrap();
        let expect: Vec<u8> = (0..nnz).map(|j| index_delta(&indices, j) as u8).collect();
        assert_eq!(plane0, expect);
        // high index planes of a 512-element tensor are all zero
        for p in 2..4 {
            let mut plane = vec![0xffu8; nnz];
            decode_plane(&view, p, &mut plane).unwrap();
            assert!(plane.iter().all(|&b| b == 0), "plane {p} not zero");
        }
    }

    #[test]
    fn lossless_rejects_corruption_without_touching_accumulator() {
        let (dense, _indices, wire) = sample_payload(Precision::F16);
        let sentinel: Vec<f32> = (0..dense.len()).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut check = |payload: &[u8], pin: &str| {
            let mut acc = sentinel.clone();
            let err = decode_reduce_into(payload, &mut acc).unwrap_err();
            assert!(err.contains(pin), "error {err:?} missing pin {pin:?}");
            assert_eq!(acc, sentinel, "error path scattered into the accumulator");
        };
        // bad codec tag
        let mut bad = wire.clone();
        bad[9] = 7;
        check(&bad, "bad codec tag");
        // truncated: drop the tail of the last plane
        check(&wire[..wire.len() - 2], "lossless plane truncated");
        // trailing garbage after the last plane
        let mut long = wire.clone();
        long.push(0);
        check(&long, "bad length");
        // nnz lies upward: the plane walk runs dry
        let mut lie = wire.clone();
        let nnz = u32::from_le_bytes(lie[4..8].try_into().unwrap());
        lie[4..8].copy_from_slice(&(nnz + 1).to_le_bytes());
        check(&lie, "lossless plane");
        // nnz above n_total is rejected by the cheap guard
        let mut huge = wire.clone();
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        check(&huge, "exceeds n_total");
    }

    #[test]
    fn lossless_rejects_out_of_range_reconstructed_index() {
        // Hand-build a payload whose delta chain runs past n_total.
        let dense = vec![1.0f32; 4];
        let indices = vec![0u32, 1, 2, 3];
        let mut scratch = Vec::new();
        let mut wire = Vec::new();
        encode_gathered_lossless_into(&dense, &indices, Precision::F32, &mut scratch, &mut wire);
        // Shrink the declared n_total below the real top index.
        wire[0..4].copy_from_slice(&2u32.to_le_bytes());
        let mut acc = vec![0f32; 2];
        let err = decode_reduce_into(&wire, &mut acc).unwrap_err();
        assert!(err.contains("out of range"), "got {err:?}");
        assert_eq!(acc, vec![0f32; 2]);
    }

    #[test]
    fn lossless_encode_reuses_scratch() {
        let (dense, indices, _wire) = sample_payload(Precision::F16);
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        encode_gathered_lossless_into(&dense, &indices, Precision::F16, &mut scratch, &mut out);
        let (sc, oc) = (scratch.capacity(), out.capacity());
        let (sp, op) = (scratch.as_ptr(), out.as_ptr());
        for _ in 0..3 {
            encode_gathered_lossless_into(&dense, &indices, Precision::F16, &mut scratch, &mut out);
        }
        assert_eq!(scratch.capacity(), sc);
        assert_eq!(out.capacity(), oc);
        assert!(std::ptr::eq(scratch.as_ptr(), sp));
        assert!(std::ptr::eq(out.as_ptr(), op));
    }

    #[test]
    fn lossless_accepts_non_canonical_token_streams() {
        // A decoder-only stream: single-zero zero-runs and fragmented
        // literals are legal even though the encoder never emits them.
        // Payload: n_total=8, nnz=2, f32, indices [1, 3], values [1.0, 2.0].
        let mut wire = Vec::new();
        wire.extend_from_slice(&8u32.to_le_bytes());
        wire.extend_from_slice(&2u32.to_le_bytes());
        wire.push(0); // f32
        wire.push(CODEC_LOSSLESS);
        wire.extend_from_slice(&[0, 0]);
        // deltas: [1, 1]; plane 0 as two 1-byte literals (non-canonical)
        let plane0 = [0x00u8, 1, 0x00, 1];
        wire.extend_from_slice(&(plane0.len() as u32).to_le_bytes());
        wire.extend_from_slice(&plane0);
        // planes 1..4: two zeros as two single-zero runs (non-canonical)
        for _ in 0..3 {
            let plane = [0x80u8, 0x80];
            wire.extend_from_slice(&(plane.len() as u32).to_le_bytes());
            wire.extend_from_slice(&plane);
        }
        // value words 1.0f32, 2.0f32 little-endian byte planes
        let words = [1.0f32.to_bits(), 2.0f32.to_bits()];
        for p in 0..4u32 {
            let bytes = [(words[0] >> (8 * p)) as u8, (words[1] >> (8 * p)) as u8];
            let mut plane = Vec::new();
            zrle_encode(2, |i| bytes[i], &mut plane);
            wire.extend_from_slice(&(plane.len() as u32).to_le_bytes());
            wire.extend_from_slice(&plane);
        }
        let mut acc = vec![0f32; 8];
        let o = decode_reduce_into(&wire, &mut acc).unwrap();
        assert_eq!(o.nnz, 2);
        assert_eq!(acc[1], 1.0);
        assert_eq!(acc[3], 2.0);
        assert_eq!(acc.iter().filter(|&&x| x != 0.0).count(), 2);
    }
}
