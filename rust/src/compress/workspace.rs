//! Per-worker scratch arena for the fused compression→wire hot path.
//!
//! Every buffer Algorithm 2 needs between "gradient in" and "frame out" —
//! quickselect pairs, candidate/sub-tensor staging for the threshold-reuse
//! fast path, and the selected-index staging — lives here and is reused
//! across steps and across buckets. One [`Workspace`] serves any tensor
//! length (buffers are cleared, never shrunk), so a worker needs exactly
//! one per concurrent compression thread: that is what [`WorkspacePool`]
//! holds, sized to the machine's available parallelism for the parallel
//! per-bucket path
//! ([`BucketedCompressor::compress_frames`](super::bucket::BucketedCompressor::compress_frames)).
//!
//! Ownership rules (DESIGN.md §Hot path anatomy):
//! - A `Workspace` is *transient scratch*: nothing in it survives a call
//!   as meaningful state. Compressor state (error-feedback residual,
//!   threshold hint, prune cache) stays in
//!   [`NetSenseCompressor`](super::NetSenseCompressor).
//! - Borrow one workspace per thread; never share one across concurrent
//!   compressions.
//! - After a few warmup steps every buffer has reached its steady-state
//!   capacity and the fused path performs **zero heap allocations** per
//!   step (regression-tested below with a counting allocator).

/// Reusable scratch buffers for one in-flight fused compression.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Quickselect (|value|, index) pairs (~12·n bytes at capacity).
    pub(crate) pairs: Vec<(f32, u32)>,
    /// Selected indices — the COO index staging of the frame being built.
    pub(crate) indices: Vec<u32>,
    /// Threshold-reuse candidate set (indices passing the hint pre-filter).
    pub(crate) cand: Vec<u32>,
    /// Candidate sub-tensor values (gathered for the trim quickselect).
    pub(crate) sub: Vec<f32>,
    /// Trim-selection output (indices local to `sub`).
    pub(crate) sub_keep: Vec<u32>,
    /// Lossless-stage candidate payload (byte planes + ZRLE); shipped only
    /// when it beats the raw COO encoding, else discarded in place.
    pub(crate) lossless: Vec<u8>,
    /// Quantized wire words staged for byte-plane packing.
    pub(crate) val_bits: Vec<u32>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Pre-size every buffer for tensors of up to `n` elements, so even
    /// the first step — and any threshold-hint miss, whose candidate set
    /// can transiently reach `n` — allocates nothing.
    pub fn with_capacity(n: usize) -> Workspace {
        Workspace {
            pairs: Vec::with_capacity(n),
            indices: Vec::with_capacity(n),
            cand: Vec::with_capacity(n),
            sub: Vec::with_capacity(n),
            sub_keep: Vec::with_capacity(n),
            // Worst case the lossless candidate is header + planes with no
            // zero runs at all: bounded by the raw encoding plus per-plane
            // length words; 9n is a safe ceiling for every precision.
            lossless: Vec::with_capacity(12 + 8 * 4 + 9 * n),
            val_bits: Vec::with_capacity(n),
        }
    }
}

/// A fixed set of [`Workspace`]s — one per compression thread.
///
/// [`WorkspacePool::with_available_parallelism`] sizes the pool to the
/// machine (`std::thread::available_parallelism`), which is also the width
/// the parallel per-bucket path fans out to. A pool of 1 forces the
/// single-thread inline path (no spawns, zero per-step allocations).
#[derive(Debug)]
pub struct WorkspacePool {
    workspaces: Vec<Workspace>,
}

impl WorkspacePool {
    /// Pool of exactly `threads` workspaces (`threads >= 1`).
    pub fn new(threads: usize) -> WorkspacePool {
        assert!(threads >= 1, "a pool needs at least one workspace");
        WorkspacePool {
            workspaces: (0..threads).map(|_| Workspace::new()).collect(),
        }
    }

    /// Pool sized to the machine's available parallelism.
    pub fn with_available_parallelism() -> WorkspacePool {
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        WorkspacePool::new(threads)
    }

    /// Number of workspaces (= maximum compression fan-out).
    pub fn len(&self) -> usize {
        self.workspaces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workspaces.is_empty()
    }

    /// Borrow workspace `i` (single-thread hot path uses `0`).
    pub fn workspace_mut(&mut self, i: usize) -> &mut Workspace {
        &mut self.workspaces[i]
    }

    /// All workspaces, for chunked parallel fan-out.
    pub(crate) fn workspaces_mut(&mut self) -> &mut [Workspace] {
        &mut self.workspaces
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::bucket::{BucketLayout, BucketedCompressor};
    use crate::compress::{CompressionConfig, NetSenseCompressor};
    use crate::testing::alloc::thread_alloc_count;
    use crate::testing::prop::*;
    use crate::transport::frame::encode_frame;
    use crate::util::rng::Pcg64;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg64::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }

    /// The staged reference path of the ISSUE acceptance test:
    /// compensate → top_k → quantize → encode → encode_frame.
    fn staged_frame(c: &mut NetSenseCompressor, g: &[f32], w: &[f32], ratio: f64) -> Vec<u8> {
        let out = c.compress(g, w, ratio);
        encode_frame(&out.payload.encode())
    }

    #[test]
    fn property_fused_frame_bit_identical_to_staged_reference() {
        // Single-pass select+quantize+encode must match the staged
        // reference on the wire, bit for bit, across the quantization
        // boundary (F32 and F16 payloads), at ratio = 1.0 (the healthy-
        // network send-everything skip), and at ratio = 0.0 (empty
        // payload).
        forall(
            "fused frame == staged frame",
            60,
            vec_f32(1..250, -50.0..50.0),
            |v| {
                let n = v.len();
                let w = randn(n, 777);
                // Fresh per case: `forall` closures are `Fn`, and the
                // workspace is transient scratch anyway.
                let mut ws = Workspace::new();
                let mut out = Vec::new();
                for ratio in [1.0, 0.5, 0.1, 0.01, 0.003, 0.0] {
                    let mut staged = NetSenseCompressor::new(n, CompressionConfig::default());
                    let mut fused = NetSenseCompressor::new(n, CompressionConfig::default());
                    let want = staged_frame(&mut staged, v, &w, ratio);
                    out.clear();
                    let o = fused.compress_frame_into(v, &w, ratio, &mut ws, &mut out);
                    if out != want || o.wire_bytes + 8 != want.len() as u64 {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn fused_stays_bit_identical_over_many_steps() {
        // Multi-step: the error-feedback residual, threshold hint, and
        // prune cache must evolve identically on both paths, so the wire
        // stays bit-identical arbitrarily deep into a run — including
        // ratio changes that cross the quantization boundary mid-stream.
        let n = 3000;
        let w = randn(n, 5);
        let mut staged = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut fused = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut ws = Workspace::new();
        let mut out = Vec::new();
        let mut g = randn(n, 6);
        let mut r = Pcg64::seeded(7);
        let ratios = [0.1, 0.1, 0.05, 0.01, 0.01, 1.0, 0.1, 0.003, 0.1, 0.0, 0.1];
        for (step, &ratio) in ratios.iter().cycle().take(40).enumerate() {
            for x in g.iter_mut() {
                *x += 0.05 * r.normal() as f32;
            }
            let want = staged_frame(&mut staged, &g, &w, ratio);
            out.clear();
            let o = fused.compress_frame_into(&g, &w, ratio, &mut ws, &mut out);
            assert_eq!(out, want, "step {step} ratio {ratio}: wire diverged");
            assert_eq!(o.wire_bytes as usize + 8, want.len(), "step {step}");
            assert_eq!(
                staged.residual_norm(),
                fused.residual_norm(),
                "step {step}: residual state diverged"
            );
            assert_eq!(
                staged.predict_wire_bytes(ratio),
                fused.predict_wire_bytes(ratio),
                "step {step}: prediction state diverged"
            );
        }
    }

    #[test]
    fn steady_state_fused_step_is_allocation_free() {
        // The acceptance gate: once the workspace, the compressor scratch,
        // and the wire buffer are warm, a compress+encode step performs
        // ZERO heap allocations. The lib test binary runs under
        // `testing::alloc::CountingAlloc`, so any allocation on this
        // thread is caught.
        let n = 20_000;
        let w = randn(n, 11);
        let mut g = randn(n, 12);
        let mut r = Pcg64::seeded(13);
        let mut c = NetSenseCompressor::new(n, CompressionConfig::default());
        let mut ws = Workspace::with_capacity(n);
        let mut out: Vec<u8> = Vec::new();
        let mut step = |c: &mut NetSenseCompressor,
                        ws: &mut Workspace,
                        out: &mut Vec<u8>,
                        g: &mut [f32],
                        r: &mut Pcg64| {
            for x in g.iter_mut() {
                *x += 0.05 * r.normal() as f32;
            }
            out.clear();
            c.compress_frame_into(g, &w, 0.1, ws, out);
        };
        for _ in 0..40 {
            step(&mut c, &mut ws, &mut out, &mut g, &mut r);
        }
        let before = thread_alloc_count();
        for _ in 0..10 {
            step(&mut c, &mut ws, &mut out, &mut g, &mut r);
        }
        let allocs = thread_alloc_count() - before;
        assert_eq!(allocs, 0, "steady-state fused step allocated {allocs} times");
    }

    #[test]
    fn steady_state_lossless_fused_step_is_allocation_free() {
        // Same gate with the lossless stage on: the byte-plane + ZRLE
        // candidate is built in the workspace's own scratch, so a warm
        // step still performs ZERO heap allocations — win or skip.
        let n = 20_000;
        let w = randn(n, 31);
        let mut g = randn(n, 32);
        let mut r = Pcg64::seeded(33);
        let cfg = CompressionConfig {
            lossless: true,
            ..Default::default()
        };
        let mut c = NetSenseCompressor::new(n, cfg);
        let mut ws = Workspace::with_capacity(n);
        let mut out: Vec<u8> = Vec::new();
        let mut step = |c: &mut NetSenseCompressor,
                        ws: &mut Workspace,
                        out: &mut Vec<u8>,
                        g: &mut [f32],
                        r: &mut Pcg64,
                        ratio: f64| {
            for x in g.iter_mut() {
                *x += 0.05 * r.normal() as f32;
            }
            out.clear();
            c.compress_frame_into(g, &w, ratio, ws, out)
        };
        // Warm both the quantized (f16, stage wins) and the f32 regimes,
        // plus the lazily-initialized obs metrics.
        let mut saw_win = false;
        for i in 0..40 {
            let ratio = if i % 2 == 0 { 0.1 } else { 0.01 };
            saw_win |= step(&mut c, &mut ws, &mut out, &mut g, &mut r, ratio).lossless;
        }
        assert!(saw_win, "lossless stage never won during warmup");
        let before = thread_alloc_count();
        for i in 0..10 {
            let ratio = if i % 2 == 0 { 0.1 } else { 0.01 };
            step(&mut c, &mut ws, &mut out, &mut g, &mut r, ratio);
        }
        let allocs = thread_alloc_count() - before;
        assert_eq!(allocs, 0, "steady-state lossless step allocated {allocs} times");
    }

    #[test]
    fn steady_state_bucketed_fused_step_is_allocation_free() {
        // Same gate through the bucketed path: a pool of 1 runs the
        // inline no-spawn fan-out, and every per-bucket frame buffer is
        // reused — zero allocations per steady-state step.
        let n = 16_000;
        let layout = BucketLayout::new(n, 3000);
        let w = randn(n, 21);
        let mut g = randn(n, 22);
        let mut r = Pcg64::seeded(23);
        let mut bc = BucketedCompressor::new(layout, CompressionConfig::default());
        let mut pool = WorkspacePool::new(1);
        // Pre-size to the largest bucket so even a threshold-hint miss
        // (candidate set transiently near bucket size) cannot regrow a
        // buffer mid-measurement.
        *pool.workspace_mut(0) = Workspace::with_capacity(3000);
        let mut step = |bc: &mut BucketedCompressor, pool: &mut WorkspacePool, g: &mut [f32], r: &mut Pcg64| {
            for x in g.iter_mut() {
                *x += 0.05 * r.normal() as f32;
            }
            bc.compress_frames(g, &w, 0.1, pool);
        };
        for _ in 0..40 {
            step(&mut bc, &mut pool, &mut g, &mut r);
        }
        let before = thread_alloc_count();
        for _ in 0..10 {
            step(&mut bc, &mut pool, &mut g, &mut r);
        }
        let allocs = thread_alloc_count() - before;
        assert_eq!(allocs, 0, "steady-state bucketed step allocated {allocs} times");
    }
}

