//! Scalar quantization: IEEE 754 binary16 (f16) and bfloat16 conversion,
//! implemented from scratch (no `half` crate offline).
//!
//! The paper's Algorithm 2 step 1 halves the gradient payload by moving
//! from 32-bit to 16-bit floats when the compression ratio is critical and
//! the gradient still carries substantial information (L2 norm test).

/// Wire precision of sparse gradient values.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    #[default]
    F32,
    F16,
    Bf16,
}

impl Precision {
    /// Bytes per value on the wire.
    pub fn bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F16 | Precision::Bf16 => 2,
        }
    }
}

/// Convert f32 → IEEE binary16 bits with round-to-nearest-even, handling
/// subnormals, overflow→inf, and NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        let nan_payload = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan_payload;
    }
    // Unbiased exponent.
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow → inf
    }
    if e >= -14 {
        // Normal f16: 10-bit mantissa, round to nearest even on bit 13.
        let mant16 = mant >> 13;
        let rest = mant & 0x1fff;
        let half = 0x1000;
        let mut out = sign | (((e + 15) as u16) << 10) | mant16 as u16;
        if rest > half || (rest == half && (mant16 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent — correct
        }
        return out;
    }
    if e >= -24 {
        // Subnormal f16.
        let shift = (-14 - e) as u32; // 1..=10
        let mant_full = mant | 0x0080_0000; // implicit bit
        let total_shift = 13 + shift;
        let mant16 = mant_full >> total_shift;
        let rest = mant_full & ((1 << total_shift) - 1);
        let half = 1u32 << (total_shift - 1);
        let mut out = sign | mant16 as u16;
        if rest > half || (rest == half && (mant16 & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    sign // underflow → signed zero
}

/// Convert IEEE binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        // inf / nan
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign // zero
        } else {
            // subnormal: normalize. value = (mant/2^10)·2^-14; after s left
            // shifts m ∈ [2^10, 2^11) and the unbiased exponent is
            // E = -14 - s. With e starting at -1 and decrementing per
            // shift, s = -1 - e, so E = e - 13 and the f32 biased
            // exponent is e + 114.
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03ff;
            let exp32 = (e + 114) as u32;
            sign | (exp32 << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits (round-to-nearest-even). bf16 is the top 16 bits of
/// f32, so range is preserved and conversion is cheap — this is the TPU-
/// native 16-bit format.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040; // quiet the NaN
    }
    // Round to nearest even on bit 15.
    let hi = bits >> 16;
    let low = bits & 0xffff;
    let half = 0x8000;
    let rounded = if low > half || (low == half && (hi & 1) == 1) {
        hi.wrapping_add(1)
    } else {
        hi
    };
    rounded as u16
}

/// bfloat16 bits → f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Quantize a slice to `precision`, returning the dequantized values (what
/// the receiver reconstructs). For `F32` this is the identity.
///
/// Allocating convenience kept for tests and examples only — hot-path call
/// sites must use [`quantize_roundtrip_ref`] /
/// [`quantize_roundtrip_in_place`] (hidden from docs so new code can't
/// pick it up by accident; the fused send path goes further and quantizes
/// during encode, see [`super::sparse::encode_gathered_into`]).
#[doc(hidden)]
pub fn quantize_roundtrip(xs: &[f32], precision: Precision) -> Vec<f32> {
    let mut out = xs.to_vec();
    quantize_roundtrip_in_place(&mut out, precision);
    out
}

/// Quantize-roundtrip in place: rewrites `xs` to the receiver-visible
/// wire-precision values. `F32` touches nothing (§Perf: the healthy-
/// network path — the paper's common case — moves zero bytes). 16-bit
/// precisions run the runtime-dispatched SIMD kernels, bit-identical to
/// the scalar [`f32_to_f16_bits`]/[`f16_bits_to_f32`] composition.
pub fn quantize_roundtrip_in_place(xs: &mut [f32], precision: Precision) {
    match precision {
        Precision::F32 => {}
        Precision::F16 => super::simd::roundtrip_f16_in_place(xs),
        Precision::Bf16 => super::simd::roundtrip_bf16_in_place(xs),
    }
}

/// Borrowing variant of [`quantize_roundtrip`]: `F32` returns the input
/// slice unchanged (zero copies, zero allocations); 16-bit precisions
/// round through `scratch` and return it.
pub fn quantize_roundtrip_ref<'a>(
    xs: &'a [f32],
    precision: Precision,
    scratch: &'a mut Vec<f32>,
) -> &'a [f32] {
    match precision {
        Precision::F32 => xs,
        _ => {
            scratch.clear();
            scratch.extend_from_slice(xs);
            quantize_roundtrip_in_place(scratch, precision);
            scratch
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::*;

    #[test]
    fn f16_exact_values() {
        // Exactly representable values round-trip bit-perfectly.
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "{x}");
        }
    }

    #[test]
    fn f16_known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3c00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xc000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // max finite f16
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        // smallest positive subnormal f16 = 2^-24
        assert_eq!(f32_to_f16_bits(5.960_464_5e-8), 0x0001);
    }

    #[test]
    fn f16_overflow_to_inf_and_underflow_to_zero() {
        assert_eq!(f32_to_f16_bits(1e10), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e10), 0xfc00);
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
    }

    #[test]
    fn f16_nan_stays_nan() {
        let h = f32_to_f16_bits(f32::NAN);
        assert!(f16_bits_to_f32(h).is_nan());
    }

    #[test]
    fn f16_subnormals_roundtrip() {
        // All subnormal f16 bit patterns decode and re-encode exactly.
        for bits in 1u16..0x0400 {
            let x = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(x), bits, "bits {bits:#06x} ({x})");
        }
    }

    #[test]
    fn f16_all_finite_patterns_roundtrip() {
        // Every finite f16 decodes to an f32 that re-encodes identically.
        for bits in 0u16..=0xffff {
            let exp = (bits >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/nan handled elsewhere
            }
            let x = f16_bits_to_f32(bits);
            assert_eq!(f32_to_f16_bits(x), bits, "bits {bits:#06x}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        forall(
            "f16 rel error < 2^-10 for normal range",
            500,
            vec_f32(1..50, -1000.0..1000.0),
            |v| {
                v.iter().all(|&x| {
                    if x.abs() < 6.2e-5 {
                        return true; // subnormal territory: absolute error regime
                    }
                    let y = f16_bits_to_f32(f32_to_f16_bits(x));
                    (y - x).abs() <= x.abs() * (1.0 / 1024.0)
                })
            },
        );
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10 → ties to even (1.0).
        let x = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3c00);
        // 1 + 3*2^-11 is between 1+2^-10 and 1+2^-9 → ties to even (1+2^-9).
        let x = 1.0 + 3.0 * 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(x), 0x3c02);
    }

    #[test]
    fn bf16_known_values() {
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(1.0)), 1.0);
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(-2.5)), -2.5);
        // bf16 keeps f32 range: 1e38 stays finite.
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(1e38)).is_finite());
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(
            bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)),
            f32::INFINITY
        );
    }

    #[test]
    fn bf16_relative_error_bounded() {
        forall(
            "bf16 rel error <= 2^-7",
            500,
            vec_f32(1..50, -1e30..1e30),
            |v| {
                v.iter().all(|&x| {
                    let y = bf16_bits_to_f32(f32_to_bf16_bits(x));
                    x == 0.0 || (y - x).abs() <= x.abs() * (1.0 / 128.0)
                })
            },
        );
    }

    #[test]
    fn roundtrip_helper_identity_for_f32() {
        let v = vec![1.5f32, -2.25, 0.0, 1e-20];
        assert_eq!(quantize_roundtrip(&v, Precision::F32), v);
    }

    #[test]
    fn roundtrip_ref_borrows_for_f32_and_rounds_for_f16() {
        let v = vec![0.1234567f32, -2.25, 0.0];
        let mut scratch = Vec::new();
        // F32: the returned slice IS the input — no bytes moved.
        let out = quantize_roundtrip_ref(&v, Precision::F32, &mut scratch);
        assert!(std::ptr::eq(out.as_ptr(), v.as_ptr()));
        assert!(scratch.is_empty(), "identity path must not touch scratch");
        // F16/Bf16: matches the allocating variant exactly.
        for prec in [Precision::F16, Precision::Bf16] {
            let out = quantize_roundtrip_ref(&v, prec, &mut scratch).to_vec();
            assert_eq!(out, quantize_roundtrip(&v, prec), "{prec:?}");
        }
    }

    #[test]
    fn roundtrip_in_place_matches_allocating() {
        let v = vec![0.1f32, 65519.0, -1e-8, f32::NAN, 3.0];
        for prec in [Precision::F32, Precision::F16, Precision::Bf16] {
            let want = quantize_roundtrip(&v, prec);
            let mut got = v.clone();
            quantize_roundtrip_in_place(&mut got, prec);
            assert_eq!(got.len(), want.len());
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "{prec:?}");
            }
        }
    }

    #[test]
    fn precision_default_is_f32() {
        assert_eq!(Precision::default(), Precision::F32);
    }

    #[test]
    fn precision_wire_bytes() {
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::F16.bytes(), 2);
        assert_eq!(Precision::Bf16.bytes(), 2);
    }
}
