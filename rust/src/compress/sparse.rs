//! Sparse gradient wire codec: COO (index, value) pairs with f32 or f16
//! values, byte-exact wire-size accounting, and the aggregation operations
//! the collectives need (sum of sparse gradients, densify).
//!
//! Wire layout (little-endian):
//! `[u32 n_total][u32 nnz][u8 precision][u8 codec][pad 2][payload]`
//!
//! Codec 0 (raw) carries `[nnz × u32 idx][nnz × value]`; codec 1 routes
//! the payload through the 3LC-style lossless stage
//! ([`super::lossless`]: delta + zero-run + byte-plane packing). The
//! codec byte was padding before the lossless stage existed, so raw
//! frames are wire-compatible in both directions.

use super::quantize::{f16_bits_to_f32, f32_to_f16_bits, Precision};
use super::{lossless, simd};

/// Bytes in the COO wire header (`n_total` + `nnz` + precision tag + pad).
pub const COO_HEADER_BYTES: usize = 12;

/// A sparse gradient: sorted unique indices + values, tagged with the dense
/// length it came from.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGradient {
    pub n_total: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    pub precision: Precision,
}

impl SparseGradient {
    /// Gather `indices` out of a dense tensor.
    pub fn gather(dense: &[f32], indices: Vec<u32>, precision: Precision) -> Self {
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices unsorted");
        let values = indices.iter().map(|&i| dense[i as usize]).collect();
        SparseGradient {
            n_total: dense.len(),
            indices,
            values,
            precision,
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Exact wire size in bytes (header + indices + values).
    pub fn wire_bytes(&self) -> u64 {
        COO_HEADER_BYTES as u64 + (self.nnz() as u64) * (4 + self.precision.bytes() as u64)
    }

    /// Densify into a fresh dense vector (receiver side).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n_total];
        self.add_into(&mut out);
        out
    }

    /// Accumulate into an existing dense buffer (aggregation hot path).
    pub fn add_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.n_total, "dense length mismatch");
        for (&i, &v) in self.indices.iter().zip(self.values.iter()) {
            out[i as usize] += v;
        }
    }

    /// Apply this gradient's value precision (what the receiver would see
    /// after decode). f32 is identity; f16 quantizes values.
    pub fn quantize_values(&mut self) {
        if self.precision == Precision::F16 {
            for v in self.values.iter_mut() {
                *v = f16_bits_to_f32(f32_to_f16_bits(*v));
            }
        }
    }

    /// Serialize to the wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes() as usize);
        self.encode_into(&mut out);
        out
    }

    /// [`SparseGradient::encode`] appending into a caller-owned buffer
    /// (§Perf: zero allocations once the buffer has capacity).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let before = out.len();
        encode_coo_header_into(self.n_total, self.nnz(), self.precision, out);
        for &i in &self.indices {
            out.extend_from_slice(&i.to_le_bytes());
        }
        encode_values_into(&self.values, self.precision, out);
        debug_assert_eq!((out.len() - before) as u64, self.wire_bytes());
    }

    /// Deserialize from the wire format (either codec).
    pub fn decode(buf: &[u8]) -> Result<SparseGradient, String> {
        let (n_total, nnz, precision, codec) = parse_coo_prefix(buf)?;
        if codec == lossless::CODEC_LOSSLESS {
            return lossless::decode_lossless_sparse(buf, n_total, nnz, precision);
        }
        let (idx_end, val_end) = raw_extents(buf.len(), nnz, precision)?;
        let mut indices = Vec::with_capacity(nnz);
        for c in buf[COO_HEADER_BYTES..idx_end].chunks_exact(4) {
            let i = u32::from_le_bytes(c.try_into().unwrap());
            if i as usize >= n_total {
                return Err(format!("index {i} out of range {n_total}"));
            }
            indices.push(i);
        }
        if indices.windows(2).any(|w| w[0] >= w[1]) {
            return Err("indices not strictly ascending".into());
        }
        let values = match precision {
            Precision::F32 => buf[idx_end..val_end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            Precision::F16 => buf[idx_end..val_end]
                .chunks_exact(2)
                .map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
            Precision::Bf16 => buf[idx_end..val_end]
                .chunks_exact(2)
                .map(|c| {
                    super::quantize::bf16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()))
                })
                .collect(),
        };
        Ok(SparseGradient {
            n_total,
            indices,
            values,
            precision,
        })
    }

    /// Merge-sum two sparse gradients (union of indices, summed values).
    /// Both must describe the same dense length. Allocates the result —
    /// loops that merge repeatedly should reuse a buffer via
    /// [`SparseGradient::merge_sum_into`].
    pub fn merge_sum(&self, other: &SparseGradient) -> SparseGradient {
        let mut out = SparseGradient {
            n_total: self.n_total,
            indices: Vec::new(),
            values: Vec::new(),
            precision: self.precision,
        };
        self.merge_sum_into(other, &mut out);
        out
    }

    /// [`SparseGradient::merge_sum`] into a caller-owned output: an
    /// aggregation loop that merges one payload per iteration (e.g. a
    /// sparse reduce over incoming peers) reuses `out` instead of paying
    /// per-merge reallocation, and the pre-sizing `reserve` makes even a
    /// cold buffer fill without incremental growth. The current
    /// coordinator reduce path densifies via [`SparseGradient::add_into`]
    /// instead; this is the sparse-output twin for payloads far below the
    /// dense crossover.
    pub fn merge_sum_into(&self, other: &SparseGradient, out: &mut SparseGradient) {
        assert_eq!(self.n_total, other.n_total);
        let cap = self.nnz() + other.nnz();
        out.n_total = self.n_total;
        out.indices.clear();
        out.values.clear();
        out.indices.reserve(cap);
        out.values.reserve(cap);
        let (mut a, mut b) = (0usize, 0usize);
        while a < self.nnz() || b < other.nnz() {
            let ia = self.indices.get(a).copied().unwrap_or(u32::MAX);
            let ib = other.indices.get(b).copied().unwrap_or(u32::MAX);
            match ia.cmp(&ib) {
                std::cmp::Ordering::Less => {
                    out.indices.push(ia);
                    out.values.push(self.values[a]);
                    a += 1;
                }
                std::cmp::Ordering::Greater => {
                    out.indices.push(ib);
                    out.values.push(other.values[b]);
                    b += 1;
                }
                std::cmp::Ordering::Equal => {
                    out.indices.push(ia);
                    out.values.push(self.values[a] + other.values[b]);
                    a += 1;
                    b += 1;
                }
            }
        }
        out.precision =
            if self.precision == Precision::F32 || other.precision == Precision::F32 {
                Precision::F32
            } else {
                self.precision
            };
    }
}

/// Parse the fixed 12-byte COO wire prefix — shared by the staged decoder
/// ([`SparseGradient::decode`]) and the fused decode-reduce
/// ([`decode_reduce_into`]), so both receive paths accept exactly the
/// same frames by construction (the decode-side twin of
/// [`encode_coo_header_into`]). Returns `(n_total, nnz, precision,
/// codec)`; the raw-codec extents and length check live in
/// [`raw_extents`] because the lossless codec sizes its payload from the
/// per-plane sections instead.
fn parse_coo_prefix(buf: &[u8]) -> Result<(usize, usize, Precision, u8), String> {
    if buf.len() < COO_HEADER_BYTES {
        return Err("short header".into());
    }
    let n_total = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
    let nnz = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    let precision = match buf[8] {
        0 => Precision::F32,
        1 => Precision::F16,
        2 => Precision::Bf16,
        p => return Err(format!("bad precision tag {p}")),
    };
    let codec = buf[9];
    if codec != 0 && codec != lossless::CODEC_LOSSLESS {
        return Err(format!("bad codec tag {codec}"));
    }
    Ok((n_total, nnz, precision, codec))
}

/// Raw-codec payload extents: check the declared element count against
/// `len` and return `(idx_end, val_end)`.
fn raw_extents(len: usize, nnz: usize, precision: Precision) -> Result<(usize, usize), String> {
    // Checked arithmetic: a u32 nnz can't overflow usize on 64-bit hosts,
    // but the header contract shouldn't depend on pointer width — a lying
    // count is a named error, never a wrapped offset.
    let idx_end = nnz
        .checked_mul(4)
        .and_then(|b| b.checked_add(COO_HEADER_BYTES))
        .ok_or_else(|| format!("nnz {nnz} overflows frame size"))?;
    let val_end = nnz
        .checked_mul(precision.bytes())
        .and_then(|b| b.checked_add(idx_end))
        .ok_or_else(|| format!("nnz {nnz} overflows frame size"))?;
    if len != val_end {
        return Err(format!("bad length {len} (expected {val_end})"));
    }
    Ok((idx_end, val_end))
}

/// Write the 12-byte COO wire header (`n_total`, `nnz`, precision tag,
/// codec 0 = raw, padding) — shared by the staged codec and the fused
/// encoder. The lossless encoder writes its own header with codec 1
/// ([`lossless::encode_gathered_lossless_into`]).
fn encode_coo_header_into(n_total: usize, nnz: usize, precision: Precision, out: &mut Vec<u8>) {
    out.extend_from_slice(&(n_total as u32).to_le_bytes());
    out.extend_from_slice(&(nnz as u32).to_le_bytes());
    out.push(match precision {
        Precision::F32 => 0,
        Precision::F16 => 1,
        Precision::Bf16 => 2,
    });
    out.extend_from_slice(&[0u8; 3]);
}

/// Write `values` at wire precision — shared by the staged codec and the
/// fused encoder (so both produce identical bits by construction).
fn encode_values_into(values: &[f32], precision: Precision, out: &mut Vec<u8>) {
    match precision {
        Precision::F32 => {
            for &v in values {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Precision::F16 => {
            for &v in values {
                out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
            }
        }
        Precision::Bf16 => {
            for &v in values {
                out.extend_from_slice(&super::quantize::f32_to_bf16_bits(v).to_le_bytes());
            }
        }
    }
}

/// Fused gather + quantize + encode: write the COO payload for
/// `dense[indices]` straight into `out` — no `SparseGradient`
/// materialization on the send side. Bit-identical on the wire to the
/// staged path (`gather → quantize_values → encode`) because f16/bf16
/// conversion is idempotent: encoding a raw value and encoding its
/// rounded-through-16-bit view produce the same bits. Appends exactly the
/// returned byte count (`12 + nnz·(4 + value_bytes)`).
pub fn encode_gathered_into(
    dense: &[f32],
    indices: &[u32],
    precision: Precision,
    out: &mut Vec<u8>,
) -> u64 {
    let nnz = indices.len();
    let bytes = COO_HEADER_BYTES as u64 + (nnz as u64) * (4 + precision.bytes() as u64);
    out.reserve(bytes as usize);
    let before = out.len();
    encode_coo_header_into(dense.len(), nnz, precision, out);
    for &i in indices {
        out.extend_from_slice(&i.to_le_bytes());
    }
    match precision {
        Precision::F32 => {
            for &i in indices {
                out.extend_from_slice(&dense[i as usize].to_le_bytes());
            }
        }
        Precision::F16 => {
            for &i in indices {
                out.extend_from_slice(&f32_to_f16_bits(dense[i as usize]).to_le_bytes());
            }
        }
        Precision::Bf16 => {
            for &i in indices {
                out.extend_from_slice(
                    &super::quantize::f32_to_bf16_bits(dense[i as usize]).to_le_bytes(),
                );
            }
        }
    }
    debug_assert_eq!((out.len() - before) as u64, bytes);
    bytes
}

/// What one fused decode-reduce consumed — the receive-side twin of
/// [`crate::compress::FusedOutcome`]: the payload never exists as a
/// [`SparseGradient`], so this carries the wire metadata only (the values
/// landed in the caller's accumulator).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeReduceOutcome {
    /// Coordinates scattered into the accumulator.
    pub nnz: usize,
    /// Wire precision the values were dequantized from.
    pub precision: Precision,
}

/// Fused decode + accumulate: parse a COO wire payload and scatter its
/// dequantized values straight into `out` — the receive-side mirror of
/// [`encode_gathered_into`]. No [`SparseGradient`] is materialized and
/// the call performs **zero heap allocations**: the f32 identity path
/// moves no extra bytes (read the wire word, add it), and f16/bf16
/// dequantize in the same sweep that accumulates.
///
/// Bit-identical to the staged reference
/// ([`SparseGradient::decode`] + [`SparseGradient::add_into`]): both
/// perform the same bits→f32 conversion and the same adds in the same
/// index order (property-tested below).
///
/// Corruption safety: the header, the declared length, and the whole
/// index region (strict ascent + bounds) are validated **before** the
/// first scatter, so malformed input returns `Err` with `out` untouched —
/// it can never scatter out of bounds or leave a partial sum behind. A
/// payload whose `n_total` disagrees with `out.len()` is malformed too
/// (the staged path's `add_into` would panic; a real receiver must get a
/// named error instead).
pub fn decode_reduce_into(buf: &[u8], out: &mut [f32]) -> Result<DecodeReduceOutcome, String> {
    let (n_total, nnz, precision, codec) = parse_coo_prefix(buf)?;
    if n_total != out.len() {
        return Err(format!(
            "payload for {n_total} elements, accumulator holds {}",
            out.len()
        ));
    }
    if codec == lossless::CODEC_LOSSLESS {
        return lossless::decode_reduce_lossless(buf, n_total, nnz, precision, out);
    }
    let (idx_end, val_end) = raw_extents(buf.len(), nnz, precision)?;
    // Validation sweep over the index region (vectorized compare chain,
    // DESIGN.md §3.11) — nothing touches `out` until every index is
    // proven in-bounds and strictly ascending.
    let idx_bytes = &buf[COO_HEADER_BYTES..idx_end];
    let last = simd::max_strictly_ascending_u32le(idx_bytes)
        .map_err(|()| String::from("indices not strictly ascending"))?;
    if last >= n_total as i64 {
        return Err(format!("index {last} out of range {n_total}"));
    }
    // Scatter sweep: dequantize in vectorized stack-buffer chunks, then
    // scatter-accumulate. Same conversions, same order as the scalar
    // reference → bit-identical.
    let values = &buf[idx_end..val_end];
    match precision {
        Precision::F32 => {
            let indices = idx_bytes.chunks_exact(4);
            for (c, v) in indices.zip(values.chunks_exact(4)) {
                let i = u32::from_le_bytes(c.try_into().unwrap()) as usize;
                out[i] += f32::from_le_bytes(v.try_into().unwrap());
            }
        }
        Precision::F16 => scatter_16bit(idx_bytes, values, out, simd::dequantize_f16_le_bytes),
        Precision::Bf16 => scatter_16bit(idx_bytes, values, out, simd::dequantize_bf16_le_bytes),
    }
    Ok(DecodeReduceOutcome { nnz, precision })
}

/// Chunk size (elements) for the dequantize-then-scatter sweep: small
/// enough to live on the stack and stay in L1, big enough to amortize the
/// vector kernel's tail handling.
const SCATTER_CHUNK: usize = 256;

/// Dequantize 16-bit wire values through fixed stack chunks and scatter
/// them — the adds happen in the same element order as the scalar loop,
/// so the result is bit-identical. Zero heap allocations.
fn scatter_16bit(
    idx_bytes: &[u8],
    values: &[u8],
    out: &mut [f32],
    dequant: fn(&[u8], &mut [f32]),
) {
    let nnz = idx_bytes.len() / 4;
    let mut chunk = [0f32; SCATTER_CHUNK];
    let mut off = 0usize;
    while off < nnz {
        let m = (nnz - off).min(SCATTER_CHUNK);
        dequant(&values[2 * off..2 * (off + m)], &mut chunk[..m]);
        for (c, &v) in idx_bytes[4 * off..4 * (off + m)]
            .chunks_exact(4)
            .zip(&chunk[..m])
        {
            let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
            out[i] += v;
        }
        off += m;
    }
}

/// [`decode_reduce_into`] for a complete transport frame (the 8-byte
/// length-prefixed header of
/// [`crate::transport::frame`] followed by the COO payload) — the unit
/// [`crate::compress::BucketedCompressor::compress_frames`] emits and the
/// pipelined receive path consumes. Validates the frame header, then
/// decodes-reduces the payload; same corruption contract (malformed input
/// returns `Err`, `out` untouched).
pub fn decode_reduce_frame_into(
    frame: &[u8],
    out: &mut [f32],
) -> Result<DecodeReduceOutcome, String> {
    let payload =
        crate::transport::frame::frame_payload(frame).map_err(|e| e.to_string())?;
    decode_reduce_into(payload, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::top_k_indices;
    use crate::testing::prop::*;

    fn sample() -> SparseGradient {
        SparseGradient {
            n_total: 10,
            indices: vec![1, 4, 7],
            values: vec![0.5, -2.0, 3.25],
            precision: Precision::F32,
        }
    }

    #[test]
    fn gather_and_densify_roundtrip() {
        let dense = vec![0.0f32, 0.5, 0.0, 0.0, -2.0, 0.0, 0.0, 3.25, 0.0, 0.0];
        let idx = top_k_indices(&dense, 3);
        let s = SparseGradient::gather(&dense, idx, Precision::F32);
        assert_eq!(s.to_dense(), dense);
    }

    #[test]
    fn wire_bytes_exact() {
        let s = sample();
        assert_eq!(s.wire_bytes(), 12 + 3 * 8);
        assert_eq!(s.encode().len() as u64, s.wire_bytes());
        let mut h = s.clone();
        h.precision = Precision::F16;
        assert_eq!(h.wire_bytes(), 12 + 3 * 6);
        assert_eq!(h.encode().len() as u64, h.wire_bytes());
    }

    #[test]
    fn encode_decode_roundtrip_f32() {
        let s = sample();
        let d = SparseGradient::decode(&s.encode()).unwrap();
        assert_eq!(d, s);
    }

    #[test]
    fn encode_decode_roundtrip_f16_quantizes() {
        let mut s = sample();
        s.precision = Precision::F16;
        let d = SparseGradient::decode(&s.encode()).unwrap();
        assert_eq!(d.indices, s.indices);
        // values are exactly representable in f16 here
        assert_eq!(d.values, s.values);
        // a non-representable value gets rounded
        let mut s2 = sample();
        s2.precision = Precision::F16;
        s2.values[0] = 0.1234567;
        let d2 = SparseGradient::decode(&s2.encode()).unwrap();
        assert!((d2.values[0] - 0.1234567).abs() < 1e-3);
        assert_ne!(d2.values[0], 0.1234567f32);
    }

    #[test]
    fn decode_rejects_corruption() {
        let s = sample();
        let mut buf = s.encode();
        assert!(SparseGradient::decode(&buf[..5]).is_err()); // truncated
        buf[8] = 9; // bad precision tag
        assert!(SparseGradient::decode(&buf).is_err());
        let mut buf2 = s.encode();
        buf2.push(0); // trailing garbage
        assert!(SparseGradient::decode(&buf2).is_err());
        // out-of-range index
        let mut bad = sample();
        bad.indices[2] = 99;
        assert!(SparseGradient::decode(&bad.encode()).is_err());
        // unsorted indices
        let mut bad = sample();
        bad.indices = vec![4, 1, 7];
        assert!(SparseGradient::decode(&bad.encode()).is_err());
    }

    #[test]
    fn merge_sum_matches_dense_sum() {
        let a = SparseGradient {
            n_total: 8,
            indices: vec![0, 3, 5],
            values: vec![1.0, 2.0, 3.0],
            precision: Precision::F32,
        };
        let b = SparseGradient {
            n_total: 8,
            indices: vec![3, 4, 7],
            values: vec![10.0, 20.0, 30.0],
            precision: Precision::F32,
        };
        let m = a.merge_sum(&b);
        let mut dense = a.to_dense();
        for (x, y) in dense.iter_mut().zip(b.to_dense()) {
            *x += y;
        }
        assert_eq!(m.to_dense(), dense);
        assert_eq!(m.indices, vec![0, 3, 4, 5, 7]);
    }

    #[test]
    fn property_roundtrip_random_sparse() {
        forall(
            "encode/decode roundtrip",
            100,
            vec_f32(1..200, -50.0..50.0),
            |v| {
                let k = (v.len() / 4).max(1);
                let idx = top_k_indices(v, k);
                let s = SparseGradient::gather(v, idx, Precision::F32);
                match SparseGradient::decode(&s.encode()) {
                    Ok(d) => d == s,
                    Err(_) => false,
                }
            },
        );
    }

    #[test]
    fn property_merge_sum_commutative() {
        forall(
            "merge_sum commutes",
            50,
            pair(vec_f32(8..64, -5.0..5.0), vec_f32(8..64, -5.0..5.0)),
            |(x, y)| {
                let n = x.len().min(y.len());
                let x = &x[..n];
                let y = &y[..n];
                let a = SparseGradient::gather(x, top_k_indices(x, n / 2 + 1), Precision::F32);
                let b = SparseGradient::gather(y, top_k_indices(y, n / 3 + 1), Precision::F32);
                a.merge_sum(&b).to_dense() == b.merge_sum(&a).to_dense()
            },
        );
    }

    #[test]
    fn property_encode_gathered_matches_staged_path_all_precisions() {
        // The fused gather+quantize+encode must be bit-identical on the
        // wire to the staged reference (gather → quantize_values →
        // encode), for every precision.
        forall(
            "encode_gathered_into == staged encode",
            100,
            vec_f32(1..200, -1e30..1e30),
            |v| {
                let k = (v.len() / 3).max(1);
                let idx = top_k_indices(v, k);
                let mut buf = Vec::new();
                for prec in [Precision::F32, Precision::F16, Precision::Bf16] {
                    let mut staged = SparseGradient::gather(v, idx.clone(), prec);
                    staged.quantize_values();
                    buf.clear();
                    let bytes = encode_gathered_into(v, &idx, prec, &mut buf);
                    if buf != staged.encode() || bytes != staged.wire_bytes() {
                        return false;
                    }
                }
                true
            },
        );
    }

    /// The ISSUE acceptance property: fused decode-reduce must be
    /// bit-identical to the staged reference (decode → add_into) across
    /// precisions, sparsity ratios, and peer counts — the same harness
    /// style as the fused-vs-staged compress property above.
    #[test]
    fn property_decode_reduce_matches_staged_decode_add_into() {
        forall(
            "decode_reduce_into == decode + add_into",
            100,
            pair(vec_f32(1..200, -1e30..1e30), usize_in(1..5)),
            |(v, n_peers)| {
                let n = v.len();
                for prec in [Precision::F32, Precision::F16, Precision::Bf16] {
                    // Each "peer" contributes a different top-k slice of
                    // the same tensor (k varies per peer).
                    let wires: Vec<Vec<u8>> = (0..*n_peers)
                        .map(|p| {
                            let k = (n / (p + 2)).max(1);
                            let idx = top_k_indices(v, k);
                            let mut s = SparseGradient::gather(v, idx, prec);
                            s.quantize_values();
                            s.encode()
                        })
                        .collect();
                    let mut staged = vec![0f32; n];
                    for w in &wires {
                        SparseGradient::decode(w).unwrap().add_into(&mut staged);
                    }
                    let mut fused = vec![0f32; n];
                    for w in &wires {
                        let o = decode_reduce_into(w, &mut fused).unwrap();
                        if o.precision != prec {
                            return false;
                        }
                    }
                    // Bit-identical, not approximately equal.
                    if staged.iter().zip(&fused).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn decode_reduce_frame_matches_payload_path() {
        use crate::transport::frame::encode_frame;
        let s = sample();
        let mut via_payload = vec![0f32; s.n_total];
        let mut via_frame = vec![0f32; s.n_total];
        let a = decode_reduce_into(&s.encode(), &mut via_payload).unwrap();
        let b = decode_reduce_frame_into(&encode_frame(&s.encode()), &mut via_frame).unwrap();
        assert_eq!(a, b);
        assert_eq!(via_payload, via_frame);
        assert_eq!(via_payload, s.to_dense());
        assert_eq!(a, DecodeReduceOutcome { nnz: 3, precision: Precision::F32 });
    }

    /// The ISSUE corruption contract: malformed input must return `Err` —
    /// never panic, never scatter out of bounds — and must leave the
    /// accumulator untouched (no partial sums from a half-validated
    /// frame).
    #[test]
    fn decode_reduce_rejects_corruption_without_touching_accumulator() {
        use crate::transport::frame::encode_frame;
        let s = sample();
        let wire = s.encode();
        let sentinel: Vec<f32> = (0..s.n_total).map(|i| i as f32).collect();
        let mut check = |payload: &[u8]| {
            let mut acc = sentinel.clone();
            assert!(decode_reduce_into(payload, &mut acc).is_err());
            assert_eq!(acc, sentinel, "error path scattered into the accumulator");
        };
        check(&wire[..5]); // truncated header
        check(&wire[..wire.len() - 3]); // short payload
        let mut bad = wire.clone();
        bad[8] = 9; // bad precision tag
        check(&bad);
        let mut long = wire.clone();
        long.push(0); // trailing garbage
        check(&long);
        // Out-of-range index (would scatter past the accumulator).
        let mut oob = sample();
        oob.indices[2] = 99;
        check(&oob.encode());
        // Unsorted indices.
        let mut unsorted = sample();
        unsorted.indices = vec![4, 1, 7];
        check(&unsorted.encode());
        // Duplicate index (not strictly ascending).
        let mut dup = sample();
        dup.indices = vec![1, 1, 7];
        check(&dup.encode());
        // Accumulator-length mismatch is malformed input, not a panic.
        let mut short_acc = vec![0f32; s.n_total - 1];
        assert!(decode_reduce_into(&wire, &mut short_acc).is_err());

        // Frame-level corruption: truncated frame, bad frame header,
        // payload shorter than the header declares.
        let mut acc = sentinel.clone();
        let framed = encode_frame(&wire);
        assert!(decode_reduce_frame_into(&framed[..4], &mut acc).is_err());
        let mut bad_magic = framed.clone();
        bad_magic[0] ^= 0xff;
        assert!(decode_reduce_frame_into(&bad_magic, &mut acc).is_err());
        let mut short_frame = framed.clone();
        short_frame.pop();
        assert!(decode_reduce_frame_into(&short_frame, &mut acc).is_err());
        assert_eq!(acc, sentinel);
        // The intact frame still decodes after all that.
        assert!(decode_reduce_frame_into(&framed, &mut acc).is_ok());
    }

    #[test]
    fn decode_reduce_empty_payload_is_a_noop() {
        let s = SparseGradient {
            n_total: 5,
            indices: vec![],
            values: vec![],
            precision: Precision::F16,
        };
        let mut acc = vec![1f32; 5];
        let o = decode_reduce_into(&s.encode(), &mut acc).unwrap();
        assert_eq!(o.nnz, 0);
        assert_eq!(acc, vec![1f32; 5]);
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches_encode() {
        let s = sample();
        let mut buf = Vec::new();
        s.encode_into(&mut buf);
        assert_eq!(buf, s.encode());
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        buf.clear();
        s.encode_into(&mut buf);
        assert_eq!(buf, s.encode());
        assert_eq!(buf.capacity(), cap, "re-encode must not grow the buffer");
        assert!(std::ptr::eq(buf.as_ptr(), ptr), "re-encode must not realloc");
    }

    #[test]
    fn merge_sum_into_reuses_output_buffers() {
        let a = sample();
        let mut b = sample();
        b.indices = vec![0, 4, 9];
        let mut out = a.merge_sum(&b); // warm: capacity >= union size
        let want = a.merge_sum(&b);
        let (ip, vp) = (out.indices.as_ptr(), out.values.as_ptr());
        a.merge_sum_into(&b, &mut out);
        assert_eq!(out, want);
        assert!(std::ptr::eq(out.indices.as_ptr(), ip), "indices realloc'd");
        assert!(std::ptr::eq(out.values.as_ptr(), vp), "values realloc'd");
    }

    #[test]
    fn add_into_accumulates() {
        let s = sample();
        let mut acc = vec![1.0f32; 10];
        s.add_into(&mut acc);
        assert_eq!(acc[1], 1.5);
        assert_eq!(acc[4], -1.0);
        assert_eq!(acc[7], 4.25);
        assert_eq!(acc[0], 1.0);
    }

    #[test]
    fn empty_sparse_gradient() {
        let s = SparseGradient {
            n_total: 5,
            indices: vec![],
            values: vec![],
            precision: Precision::F32,
        };
        assert_eq!(s.wire_bytes(), 12);
        let d = SparseGradient::decode(&s.encode()).unwrap();
        assert_eq!(d.to_dense(), vec![0.0; 5]);
    }
}
