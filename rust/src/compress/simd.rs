//! Vectorized codec kernels behind a runtime-detected feature gate
//! (DESIGN.md §3.11).
//!
//! The fused send/receive paths (PRs 3/5) are zero-alloc but were scalar;
//! on a fast link the codec — not the socket — is the hot-path ceiling.
//! This module vectorizes the four sweeps that dominate a step:
//!
//! 1. the fused compensate + L2 sweep ([`compensate_sum_sq_extend`]),
//! 2. quantize/dequantize ([`quantize_f16_bits`] & friends),
//! 3. the threshold scan ([`threshold_select_into`]),
//! 4. the decode-reduce scatter helpers ([`dequantize_f16_le_bytes`],
//!    [`max_strictly_ascending_u32le`]).
//!
//! # Dispatch
//!
//! [`active_level`] probes `is_x86_feature_detected!` once, honours the
//! `NETSENSE_SIMD` env override (`off|scalar|sse41|avx2|auto`, clamped to
//! what the host supports), and caches the answer in an atomic so the hot
//! path pays a single relaxed load. Every kernel also has a `_with(level)`
//! variant so tests and benches can pin a level deterministically; the
//! scalar tier is the always-correct reference on every architecture.
//!
//! # Bit-identity contract
//!
//! Each vector kernel is **bit-identical** to its scalar reference — not
//! merely close. Two design rules make that hold:
//!
//! - f16/bf16 conversion is implemented branchlessly from the same
//!   integer round-to-nearest-even algebra as the scalar code (including
//!   the scalar's flush of |x| < 2⁻²⁴ to signed zero and its fixed
//!   `0x0200` NaN payload) — the hardware F16C path is deliberately *not*
//!   used because `vcvtps2ph` preserves NaN payload bits the scalar
//!   drops. The one float operation in the subnormal path,
//!   round-to-nearest of |x|·2²⁴, is exact-by-construction (the product
//!   has ≤ 24 significant bits) and matches the scalar integer rounding.
//! - every L2 accumulation — scalar, SSE4.1, AVX2, staged and fused —
//!   uses the same fixed 8-lane-striped f64 layout: lane *j* accumulates
//!   elements *i* with `i & 7 == j` in increasing *i*, and the lanes are
//!   reduced sequentially at the end. The grouping is level-independent,
//!   so staged-vs-fused stays bit-identical even across hosts with
//!   different SIMD tiers.
//!
//! # Allocation contract
//!
//! Kernels never allocate on the success path. [`threshold_select_into`]
//! reserves `len + 8` once (vector stores may overspill up to one lane
//! past the live count); the growth lands in warmup, keeping the
//! counting-allocator gate at 0 allocs/step.

use super::quantize::{bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits};
use std::sync::atomic::{AtomicU8, Ordering};

/// Number of interleaved f64 accumulators in every L2 kernel. Fixed so
/// scalar/SSE/AVX2 produce identical bits (see module docs).
pub const L2_LANES: usize = 8;

/// A vectorization tier. Ordered: higher tiers imply the lower ones.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SimdLevel {
    /// Portable scalar reference (always available, always correct).
    Scalar,
    /// 128-bit SSE4.1 kernels (x86-64 only).
    Sse41,
    /// 256-bit AVX2 kernels (x86-64 only).
    Avx2,
}

const LEVEL_UNSET: u8 = 0;
const LEVEL_SCALAR: u8 = 1;
const LEVEL_SSE41: u8 = 2;
const LEVEL_AVX2: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn encode_level(l: SimdLevel) -> u8 {
    match l {
        SimdLevel::Scalar => LEVEL_SCALAR,
        SimdLevel::Sse41 => LEVEL_SSE41,
        SimdLevel::Avx2 => LEVEL_AVX2,
    }
}

/// What the host CPU supports, ignoring the env override.
#[cfg(target_arch = "x86_64")]
pub fn hw_level() -> SimdLevel {
    if is_x86_feature_detected!("avx2") {
        SimdLevel::Avx2
    } else if is_x86_feature_detected!("sse4.1") {
        SimdLevel::Sse41
    } else {
        SimdLevel::Scalar
    }
}

/// What the host CPU supports, ignoring the env override.
#[cfg(not(target_arch = "x86_64"))]
pub fn hw_level() -> SimdLevel {
    SimdLevel::Scalar
}

/// Every level the host can run, lowest first. Property tests iterate
/// this to compare each available tier against the scalar reference.
pub fn supported_levels() -> &'static [SimdLevel] {
    match hw_level() {
        SimdLevel::Scalar => &[SimdLevel::Scalar],
        SimdLevel::Sse41 => &[SimdLevel::Scalar, SimdLevel::Sse41],
        SimdLevel::Avx2 => &[SimdLevel::Scalar, SimdLevel::Sse41, SimdLevel::Avx2],
    }
}

fn detect_level() -> SimdLevel {
    let cap = hw_level();
    match std::env::var("NETSENSE_SIMD").ok().as_deref() {
        Some("off") | Some("scalar") => SimdLevel::Scalar,
        Some("sse41") => cap.min(SimdLevel::Sse41),
        // "avx2", "auto", unset, or garbage: best the host offers.
        _ => cap,
    }
}

/// The tier the dispatched kernels run at: detected once (env override +
/// CPUID), then cached. The env read can allocate; the first call happens
/// during warmup, so steady state stays allocation-free.
pub fn active_level() -> SimdLevel {
    match LEVEL.load(Ordering::Relaxed) {
        LEVEL_SCALAR => SimdLevel::Scalar,
        LEVEL_SSE41 => SimdLevel::Sse41,
        LEVEL_AVX2 => SimdLevel::Avx2,
        _ => {
            let l = detect_level();
            LEVEL.store(encode_level(l), Ordering::Relaxed);
            l
        }
    }
}

fn check_supported(level: SimdLevel) {
    assert!(
        level <= hw_level(),
        "SIMD level {level:?} not supported by this host (max {:?})",
        hw_level()
    );
}

// ---------------------------------------------------------------------------
// L2 kernels (striped f64 accumulation)
// ---------------------------------------------------------------------------

/// Σx² in the fixed 8-lane-striped f64 order (bit-identical at any level).
pub fn sum_sq(xs: &[f32]) -> f64 {
    sum_sq_with(active_level(), xs)
}

/// [`sum_sq`] pinned to `level` (test/bench seam; `level` must be
/// supported by the host, see [`supported_levels`]).
pub fn sum_sq_with(level: SimdLevel, xs: &[f32]) -> f64 {
    check_supported(level);
    match level {
        SimdLevel::Scalar => scalar::sum_sq(xs),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::sum_sq_sse41(xs) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::sum_sq_avx2(xs) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::sum_sq(xs),
    }
}

/// Fused compensate + L2: `out ← g + r` elementwise (overwriting `out`,
/// which is cleared first) and returns Σ(g+r)² in the striped order.
/// Bit-identical to `extend(g+r)` followed by [`sum_sq`].
pub fn compensate_sum_sq_extend(g: &[f32], r: &[f32], out: &mut Vec<f32>) -> f64 {
    compensate_sum_sq_extend_with(active_level(), g, r, out)
}

/// [`compensate_sum_sq_extend`] pinned to `level`.
pub fn compensate_sum_sq_extend_with(
    level: SimdLevel,
    g: &[f32],
    r: &[f32],
    out: &mut Vec<f32>,
) -> f64 {
    check_supported(level);
    assert_eq!(g.len(), r.len(), "gradient/residual length mismatch");
    out.clear();
    out.reserve(g.len());
    // Raw-pointer writes into the spare capacity: each element is written
    // exactly once (no memset), matching the old extend()-based sweep.
    let dst = out.spare_capacity_mut().as_mut_ptr() as *mut f32;
    let sq = unsafe {
        match level {
            SimdLevel::Scalar => scalar::compensate_sum_sq(g, r, dst),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Sse41 => x86::compensate_sum_sq_sse41(g, r, dst),
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx2 => x86::compensate_sum_sq_avx2(g, r, dst),
            #[cfg(not(target_arch = "x86_64"))]
            _ => scalar::compensate_sum_sq(g, r, dst),
        }
    };
    // SAFETY: the kernel wrote g.len() elements into the reserved spare
    // capacity.
    unsafe { out.set_len(g.len()) };
    sq
}

// ---------------------------------------------------------------------------
// Quantize / dequantize kernels
// ---------------------------------------------------------------------------

/// f32 → f16 wire bits, elementwise (`dst.len() == src.len()`).
pub fn quantize_f16_bits(src: &[f32], dst: &mut [u16]) {
    quantize_f16_bits_with(active_level(), src, dst)
}

/// [`quantize_f16_bits`] pinned to `level`.
pub fn quantize_f16_bits_with(level: SimdLevel, src: &[f32], dst: &mut [u16]) {
    check_supported(level);
    assert_eq!(src.len(), dst.len(), "quantize length mismatch");
    match level {
        SimdLevel::Scalar => scalar::quantize_f16(src, dst),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::quantize_f16_sse41(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::quantize_f16_avx2(src, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::quantize_f16(src, dst),
    }
}

/// f16 wire bits → f32, elementwise (`dst.len() == src.len()`).
pub fn dequantize_f16_bits(src: &[u16], dst: &mut [f32]) {
    dequantize_f16_bits_with(active_level(), src, dst)
}

/// [`dequantize_f16_bits`] pinned to `level`.
pub fn dequantize_f16_bits_with(level: SimdLevel, src: &[u16], dst: &mut [f32]) {
    check_supported(level);
    assert_eq!(src.len(), dst.len(), "dequantize length mismatch");
    match level {
        SimdLevel::Scalar => scalar::dequantize_f16(src, dst),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::dequantize_f16_sse41(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dequantize_f16_avx2(src, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dequantize_f16(src, dst),
    }
}

/// f32 → bf16 wire bits, elementwise (`dst.len() == src.len()`).
pub fn quantize_bf16_bits(src: &[f32], dst: &mut [u16]) {
    quantize_bf16_bits_with(active_level(), src, dst)
}

/// [`quantize_bf16_bits`] pinned to `level`.
pub fn quantize_bf16_bits_with(level: SimdLevel, src: &[f32], dst: &mut [u16]) {
    check_supported(level);
    assert_eq!(src.len(), dst.len(), "quantize length mismatch");
    match level {
        SimdLevel::Scalar => scalar::quantize_bf16(src, dst),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::quantize_bf16_sse41(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::quantize_bf16_avx2(src, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::quantize_bf16(src, dst),
    }
}

/// bf16 wire bits → f32, elementwise (`dst.len() == src.len()`).
pub fn dequantize_bf16_bits(src: &[u16], dst: &mut [f32]) {
    dequantize_bf16_bits_with(active_level(), src, dst)
}

/// [`dequantize_bf16_bits`] pinned to `level`.
pub fn dequantize_bf16_bits_with(level: SimdLevel, src: &[u16], dst: &mut [f32]) {
    check_supported(level);
    assert_eq!(src.len(), dst.len(), "dequantize length mismatch");
    match level {
        SimdLevel::Scalar => scalar::dequantize_bf16(src, dst),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::dequantize_bf16_sse41(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dequantize_bf16_avx2(src, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dequantize_bf16(src, dst),
    }
}

/// In-place f32 → f16 → f32 roundtrip (the error-feedback residual sweep).
pub fn roundtrip_f16_in_place(xs: &mut [f32]) {
    roundtrip_f16_in_place_with(active_level(), xs)
}

/// [`roundtrip_f16_in_place`] pinned to `level`.
pub fn roundtrip_f16_in_place_with(level: SimdLevel, xs: &mut [f32]) {
    check_supported(level);
    match level {
        SimdLevel::Scalar => scalar::roundtrip_f16(xs),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::roundtrip_f16_sse41(xs) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::roundtrip_f16_avx2(xs) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::roundtrip_f16(xs),
    }
}

/// In-place f32 → bf16 → f32 roundtrip.
pub fn roundtrip_bf16_in_place(xs: &mut [f32]) {
    roundtrip_bf16_in_place_with(active_level(), xs)
}

/// [`roundtrip_bf16_in_place`] pinned to `level`.
pub fn roundtrip_bf16_in_place_with(level: SimdLevel, xs: &mut [f32]) {
    check_supported(level);
    match level {
        SimdLevel::Scalar => scalar::roundtrip_bf16(xs),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::roundtrip_bf16_sse41(xs) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::roundtrip_bf16_avx2(xs) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::roundtrip_bf16(xs),
    }
}

/// Dequantize little-endian f16 wire bytes (`src.len() == 2·dst.len()`)
/// into f32s — the decode-reduce scatter feeds fixed stack chunks through
/// this.
pub fn dequantize_f16_le_bytes(src: &[u8], dst: &mut [f32]) {
    dequantize_f16_le_bytes_with(active_level(), src, dst)
}

/// [`dequantize_f16_le_bytes`] pinned to `level`.
pub fn dequantize_f16_le_bytes_with(level: SimdLevel, src: &[u8], dst: &mut [f32]) {
    check_supported(level);
    assert_eq!(src.len(), dst.len() * 2, "f16 byte length mismatch");
    match level {
        SimdLevel::Scalar => scalar::dequantize_f16_le(src, dst),
        // x86 is little-endian: u16 lane loads see the same values the
        // scalar from_le_bytes path decodes.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::dequantize_f16_le_sse41(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dequantize_f16_le_avx2(src, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dequantize_f16_le(src, dst),
    }
}

/// Dequantize little-endian bf16 wire bytes (`src.len() == 2·dst.len()`).
pub fn dequantize_bf16_le_bytes(src: &[u8], dst: &mut [f32]) {
    dequantize_bf16_le_bytes_with(active_level(), src, dst)
}

/// [`dequantize_bf16_le_bytes`] pinned to `level`.
pub fn dequantize_bf16_le_bytes_with(level: SimdLevel, src: &[u8], dst: &mut [f32]) {
    check_supported(level);
    assert_eq!(src.len(), dst.len() * 2, "bf16 byte length mismatch");
    match level {
        SimdLevel::Scalar => scalar::dequantize_bf16_le(src, dst),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::dequantize_bf16_le_sse41(src, dst) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::dequantize_bf16_le_avx2(src, dst) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::dequantize_bf16_le(src, dst),
    }
}

// ---------------------------------------------------------------------------
// Threshold scan
// ---------------------------------------------------------------------------

/// Collect indices of every element with `|v| >= threshold` into `out`
/// (cleared first), preserving order. Vector tiers left-pack compare
/// masks; output is byte-identical to the scalar push loop.
pub fn threshold_select_into(values: &[f32], threshold: f32, out: &mut Vec<u32>) {
    threshold_select_into_with(active_level(), values, threshold, out)
}

/// [`threshold_select_into`] pinned to `level`.
pub fn threshold_select_into_with(
    level: SimdLevel,
    values: &[f32],
    threshold: f32,
    out: &mut Vec<u32>,
) {
    check_supported(level);
    out.clear();
    // Vector stores write a full lane; up to 8 slots past the live count
    // are scratch. One-time growth, covered by warmup.
    out.reserve(values.len() + 8);
    match level {
        SimdLevel::Scalar => scalar::threshold_select(values, threshold, out),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::threshold_select_sse41(values, threshold, out) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::threshold_select_avx2(values, threshold, out) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::threshold_select(values, threshold, out),
    }
}

// ---------------------------------------------------------------------------
// Ascending-index validation
// ---------------------------------------------------------------------------

/// Validate that the little-endian u32 words in `bytes` are strictly
/// ascending; returns the last value as i64 (or -1 when empty). `Err(())`
/// mirrors the scalar first-violation outcome (the caller owns the error
/// message). `bytes.len()` must be a multiple of 4.
pub fn max_strictly_ascending_u32le(bytes: &[u8]) -> Result<i64, ()> {
    max_strictly_ascending_u32le_with(active_level(), bytes)
}

/// [`max_strictly_ascending_u32le`] pinned to `level`.
pub fn max_strictly_ascending_u32le_with(level: SimdLevel, bytes: &[u8]) -> Result<i64, ()> {
    check_supported(level);
    debug_assert_eq!(bytes.len() % 4, 0);
    match level {
        SimdLevel::Scalar => scalar::max_ascending_u32le(bytes),
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Sse41 => unsafe { x86::max_ascending_u32le_sse41(bytes) },
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { x86::max_ascending_u32le_avx2(bytes) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::max_ascending_u32le(bytes),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference kernels
// ---------------------------------------------------------------------------

pub(crate) mod scalar {
    use super::*;

    pub fn sum_sq(xs: &[f32]) -> f64 {
        let mut acc = [0f64; L2_LANES];
        for (i, &x) in xs.iter().enumerate() {
            let d = x as f64;
            acc[i & (L2_LANES - 1)] += d * d;
        }
        acc.iter().sum()
    }

    /// # Safety
    /// `dst` must be valid for `g.len()` writes.
    pub unsafe fn compensate_sum_sq(g: &[f32], r: &[f32], dst: *mut f32) -> f64 {
        let mut acc = [0f64; L2_LANES];
        for (i, (&gv, &rv)) in g.iter().zip(r).enumerate() {
            let c = gv + rv;
            dst.add(i).write(c);
            let d = c as f64;
            acc[i & (L2_LANES - 1)] += d * d;
        }
        acc.iter().sum()
    }

    pub fn quantize_f16(src: &[f32], dst: &mut [u16]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f32_to_f16_bits(s);
        }
    }

    pub fn dequantize_f16(src: &[u16], dst: &mut [f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f16_bits_to_f32(s);
        }
    }

    pub fn quantize_bf16(src: &[f32], dst: &mut [u16]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = f32_to_bf16_bits(s);
        }
    }

    pub fn dequantize_bf16(src: &[u16], dst: &mut [f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d = bf16_bits_to_f32(s);
        }
    }

    pub fn roundtrip_f16(xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = f16_bits_to_f32(f32_to_f16_bits(*x));
        }
    }

    pub fn roundtrip_bf16(xs: &mut [f32]) {
        for x in xs.iter_mut() {
            *x = bf16_bits_to_f32(f32_to_bf16_bits(*x));
        }
    }

    pub fn dequantize_f16_le(src: &[u8], dst: &mut [f32]) {
        for (d, c) in dst.iter_mut().zip(src.chunks_exact(2)) {
            *d = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }

    pub fn dequantize_bf16_le(src: &[u8], dst: &mut [f32]) {
        for (d, c) in dst.iter_mut().zip(src.chunks_exact(2)) {
            *d = bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }

    pub fn threshold_select(values: &[f32], threshold: f32, out: &mut Vec<u32>) {
        for (i, &v) in values.iter().enumerate() {
            if v.abs() >= threshold {
                out.push(i as u32);
            }
        }
    }

    pub fn max_ascending_u32le(bytes: &[u8]) -> Result<i64, ()> {
        let mut prev: i64 = -1;
        for c in bytes.chunks_exact(4) {
            let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as i64;
            if i <= prev {
                return Err(());
            }
            prev = i;
        }
        Ok(prev)
    }
}

// ---------------------------------------------------------------------------
// x86-64 vector kernels
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::quantize_tables::{AVX2_COMPACT, SSE_COMPACT};
    use crate::compress::quantize::{f32_to_bf16_bits, f32_to_f16_bits};
    use std::arch::x86_64::*;

    // --- L2 ----------------------------------------------------------------

    #[target_feature(enable = "avx2")]
    pub unsafe fn sum_sq_avx2(xs: &[f32]) -> f64 {
        let n = xs.len();
        let mut acc0 = _mm256_setzero_pd(); // stripe lanes 0..4
        let mut acc1 = _mm256_setzero_pd(); // stripe lanes 4..8
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(xs.as_ptr().add(i));
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(v));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v));
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(lo, lo));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(hi, hi));
            i += 8;
        }
        let mut lanes = [0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        while i < n {
            let d = *xs.get_unchecked(i) as f64;
            lanes[i & 7] += d * d;
            i += 1;
        }
        lanes.iter().sum()
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn sum_sq_sse41(xs: &[f32]) -> f64 {
        let n = xs.len();
        let mut a01 = _mm_setzero_pd();
        let mut a23 = _mm_setzero_pd();
        let mut a45 = _mm_setzero_pd();
        let mut a67 = _mm_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            let v0 = _mm_loadu_ps(xs.as_ptr().add(i));
            let v1 = _mm_loadu_ps(xs.as_ptr().add(i + 4));
            let l0 = _mm_cvtps_pd(v0);
            let h0 = _mm_cvtps_pd(_mm_movehl_ps(v0, v0));
            let l1 = _mm_cvtps_pd(v1);
            let h1 = _mm_cvtps_pd(_mm_movehl_ps(v1, v1));
            a01 = _mm_add_pd(a01, _mm_mul_pd(l0, l0));
            a23 = _mm_add_pd(a23, _mm_mul_pd(h0, h0));
            a45 = _mm_add_pd(a45, _mm_mul_pd(l1, l1));
            a67 = _mm_add_pd(a67, _mm_mul_pd(h1, h1));
            i += 8;
        }
        let mut lanes = [0f64; 8];
        _mm_storeu_pd(lanes.as_mut_ptr(), a01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), a23);
        _mm_storeu_pd(lanes.as_mut_ptr().add(4), a45);
        _mm_storeu_pd(lanes.as_mut_ptr().add(6), a67);
        while i < n {
            let d = *xs.get_unchecked(i) as f64;
            lanes[i & 7] += d * d;
            i += 1;
        }
        lanes.iter().sum()
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn compensate_sum_sq_avx2(g: &[f32], r: &[f32], dst: *mut f32) -> f64 {
        let n = g.len();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            let c = _mm256_add_ps(
                _mm256_loadu_ps(g.as_ptr().add(i)),
                _mm256_loadu_ps(r.as_ptr().add(i)),
            );
            _mm256_storeu_ps(dst.add(i), c);
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(c));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(c));
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(lo, lo));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(hi, hi));
            i += 8;
        }
        let mut lanes = [0f64; 8];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc0);
        _mm256_storeu_pd(lanes.as_mut_ptr().add(4), acc1);
        while i < n {
            let c = *g.get_unchecked(i) + *r.get_unchecked(i);
            dst.add(i).write(c);
            let d = c as f64;
            lanes[i & 7] += d * d;
            i += 1;
        }
        lanes.iter().sum()
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn compensate_sum_sq_sse41(g: &[f32], r: &[f32], dst: *mut f32) -> f64 {
        let n = g.len();
        let mut a01 = _mm_setzero_pd();
        let mut a23 = _mm_setzero_pd();
        let mut a45 = _mm_setzero_pd();
        let mut a67 = _mm_setzero_pd();
        let mut i = 0usize;
        while i + 8 <= n {
            let c0 = _mm_add_ps(
                _mm_loadu_ps(g.as_ptr().add(i)),
                _mm_loadu_ps(r.as_ptr().add(i)),
            );
            let c1 = _mm_add_ps(
                _mm_loadu_ps(g.as_ptr().add(i + 4)),
                _mm_loadu_ps(r.as_ptr().add(i + 4)),
            );
            _mm_storeu_ps(dst.add(i), c0);
            _mm_storeu_ps(dst.add(i + 4), c1);
            let l0 = _mm_cvtps_pd(c0);
            let h0 = _mm_cvtps_pd(_mm_movehl_ps(c0, c0));
            let l1 = _mm_cvtps_pd(c1);
            let h1 = _mm_cvtps_pd(_mm_movehl_ps(c1, c1));
            a01 = _mm_add_pd(a01, _mm_mul_pd(l0, l0));
            a23 = _mm_add_pd(a23, _mm_mul_pd(h0, h0));
            a45 = _mm_add_pd(a45, _mm_mul_pd(l1, l1));
            a67 = _mm_add_pd(a67, _mm_mul_pd(h1, h1));
            i += 8;
        }
        let mut lanes = [0f64; 8];
        _mm_storeu_pd(lanes.as_mut_ptr(), a01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), a23);
        _mm_storeu_pd(lanes.as_mut_ptr().add(4), a45);
        _mm_storeu_pd(lanes.as_mut_ptr().add(6), a67);
        while i < n {
            let c = *g.get_unchecked(i) + *r.get_unchecked(i);
            dst.add(i).write(c);
            let d = c as f64;
            lanes[i & 7] += d * d;
            i += 1;
        }
        lanes.iter().sum()
    }

    // --- f16 quantize (branchless, bit-identical to f32_to_f16_bits) ------
    //
    // Produces the u32 lanes holding the u16 result for 8 (AVX2) or 4
    // (SSE4.1) floats. See DESIGN.md §3.11 for the mask algebra; the
    // subnormal tier uses cvtps(|x|·2²⁴) whose round-to-nearest-even is
    // exact-by-construction and equal to the scalar integer rounding.

    #[target_feature(enable = "avx2")]
    unsafe fn f16_lanes_avx2(bits: __m256i) -> __m256i {
        let abs_mask = _mm256_set1_epi32(0x7fff_ffff);
        let abs = _mm256_and_si256(bits, abs_mask);
        let sign16 = _mm256_srli_epi32::<16>(_mm256_andnot_si256(abs_mask, bits));
        // normal tier: exponent rebias + RNE on bit 13
        let base = _mm256_srli_epi32::<13>(abs);
        let norm = _mm256_sub_epi32(base, _mm256_set1_epi32(112 << 10));
        let rest = _mm256_and_si256(abs, _mm256_set1_epi32(0x1fff));
        let half = _mm256_set1_epi32(0x1000);
        let one = _mm256_set1_epi32(1);
        let rest_gt = _mm256_cmpgt_epi32(rest, half);
        let rest_eq = _mm256_cmpeq_epi32(rest, half);
        let odd = _mm256_cmpeq_epi32(_mm256_and_si256(base, one), one);
        let round = _mm256_and_si256(
            _mm256_or_si256(rest_gt, _mm256_and_si256(rest_eq, odd)),
            one,
        );
        let norm = _mm256_add_epi32(norm, round);
        // subnormal tier: RNE(|x|·2²⁴) — exact, matches scalar rounding
        let absf = _mm256_castsi256_ps(abs);
        let subv = _mm256_cvtps_epi32(_mm256_mul_ps(absf, _mm256_set1_ps(16_777_216.0)));
        // NaN/Inf tier
        let is_naninf = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7f7f_ffff));
        let is_nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7f80_0000));
        let naninf = _mm256_or_si256(
            _mm256_set1_epi32(0x7c00),
            _mm256_and_si256(is_nan, _mm256_set1_epi32(0x0200)),
        );
        // tier thresholds (abs < 2³¹ so signed compares are safe)
        let ge_sub = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x3380_0000 - 1));
        let ge_norm = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x3880_0000 - 1));
        let ge_over = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x4780_0000 - 1));
        let mut out = _mm256_setzero_si256();
        out = _mm256_blendv_epi8(out, subv, ge_sub);
        out = _mm256_blendv_epi8(out, norm, ge_norm);
        out = _mm256_blendv_epi8(out, _mm256_set1_epi32(0x7c00), ge_over);
        out = _mm256_blendv_epi8(out, naninf, is_naninf);
        _mm256_or_si256(out, sign16)
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn f16_lanes_sse41(bits: __m128i) -> __m128i {
        let abs_mask = _mm_set1_epi32(0x7fff_ffff);
        let abs = _mm_and_si128(bits, abs_mask);
        let sign16 = _mm_srli_epi32::<16>(_mm_andnot_si128(abs_mask, bits));
        let base = _mm_srli_epi32::<13>(abs);
        let norm = _mm_sub_epi32(base, _mm_set1_epi32(112 << 10));
        let rest = _mm_and_si128(abs, _mm_set1_epi32(0x1fff));
        let half = _mm_set1_epi32(0x1000);
        let one = _mm_set1_epi32(1);
        let rest_gt = _mm_cmpgt_epi32(rest, half);
        let rest_eq = _mm_cmpeq_epi32(rest, half);
        let odd = _mm_cmpeq_epi32(_mm_and_si128(base, one), one);
        let round = _mm_and_si128(_mm_or_si128(rest_gt, _mm_and_si128(rest_eq, odd)), one);
        let norm = _mm_add_epi32(norm, round);
        let absf = _mm_castsi128_ps(abs);
        let subv = _mm_cvtps_epi32(_mm_mul_ps(absf, _mm_set1_ps(16_777_216.0)));
        let is_naninf = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x7f7f_ffff));
        let is_nan = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x7f80_0000));
        let naninf = _mm_or_si128(
            _mm_set1_epi32(0x7c00),
            _mm_and_si128(is_nan, _mm_set1_epi32(0x0200)),
        );
        let ge_sub = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x3380_0000 - 1));
        let ge_norm = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x3880_0000 - 1));
        let ge_over = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x4780_0000 - 1));
        let mut out = _mm_setzero_si128();
        out = _mm_blendv_epi8(out, subv, ge_sub);
        out = _mm_blendv_epi8(out, norm, ge_norm);
        out = _mm_blendv_epi8(out, _mm_set1_epi32(0x7c00), ge_over);
        out = _mm_blendv_epi8(out, naninf, is_naninf);
        _mm_or_si128(out, sign16)
    }

    // --- bf16 quantize (RNE on bit 15, quiet-NaN) --------------------------

    #[target_feature(enable = "avx2")]
    unsafe fn bf16_lanes_avx2(bits: __m256i) -> __m256i {
        let abs = _mm256_and_si256(bits, _mm256_set1_epi32(0x7fff_ffff));
        let is_nan = _mm256_cmpgt_epi32(abs, _mm256_set1_epi32(0x7f80_0000));
        let hi = _mm256_srli_epi32::<16>(bits);
        let low = _mm256_and_si256(bits, _mm256_set1_epi32(0xffff));
        let half = _mm256_set1_epi32(0x8000);
        let one = _mm256_set1_epi32(1);
        let low_gt = _mm256_cmpgt_epi32(low, half);
        let low_eq = _mm256_cmpeq_epi32(low, half);
        let odd = _mm256_cmpeq_epi32(_mm256_and_si256(hi, one), one);
        let round = _mm256_and_si256(
            _mm256_or_si256(low_gt, _mm256_and_si256(low_eq, odd)),
            one,
        );
        let rounded = _mm256_and_si256(_mm256_add_epi32(hi, round), _mm256_set1_epi32(0xffff));
        let nan = _mm256_or_si256(hi, _mm256_set1_epi32(0x0040));
        _mm256_blendv_epi8(rounded, nan, is_nan)
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn bf16_lanes_sse41(bits: __m128i) -> __m128i {
        let abs = _mm_and_si128(bits, _mm_set1_epi32(0x7fff_ffff));
        let is_nan = _mm_cmpgt_epi32(abs, _mm_set1_epi32(0x7f80_0000));
        let hi = _mm_srli_epi32::<16>(bits);
        let low = _mm_and_si128(bits, _mm_set1_epi32(0xffff));
        let half = _mm_set1_epi32(0x8000);
        let one = _mm_set1_epi32(1);
        let low_gt = _mm_cmpgt_epi32(low, half);
        let low_eq = _mm_cmpeq_epi32(low, half);
        let odd = _mm_cmpeq_epi32(_mm_and_si128(hi, one), one);
        let round = _mm_and_si128(_mm_or_si128(low_gt, _mm_and_si128(low_eq, odd)), one);
        let rounded = _mm_and_si128(_mm_add_epi32(hi, round), _mm_set1_epi32(0xffff));
        let nan = _mm_or_si128(hi, _mm_set1_epi32(0x0040));
        _mm_blendv_epi8(rounded, nan, is_nan)
    }

    // --- f16/bf16 dequantize lanes -----------------------------------------

    #[target_feature(enable = "avx2")]
    unsafe fn f16_to_f32_lanes_avx2(h: __m256i) -> __m256 {
        // h: u32 lanes each holding a u16 half-float pattern
        let sign = _mm256_slli_epi32::<16>(_mm256_and_si256(h, _mm256_set1_epi32(0x8000)));
        let expmant = _mm256_and_si256(h, _mm256_set1_epi32(0x7fff));
        let exp = _mm256_srli_epi32::<10>(expmant);
        let mant = _mm256_and_si256(h, _mm256_set1_epi32(0x3ff));
        // normal: ((exp+112)<<23) | (mant<<13) == (expmant<<13) + (112<<23)
        let norm = _mm256_add_epi32(
            _mm256_slli_epi32::<13>(expmant),
            _mm256_set1_epi32(112 << 23),
        );
        // exp==31: Inf/NaN
        let infnan = _mm256_or_si256(
            _mm256_set1_epi32(0x7f80_0000),
            _mm256_slli_epi32::<13>(mant),
        );
        // exp==0: exact mant·2⁻²⁴
        let subf = _mm256_mul_ps(
            _mm256_cvtepi32_ps(mant),
            _mm256_set1_ps(5.960_464_5e-8), // 2^-24
        );
        let is_inf = _mm256_cmpeq_epi32(exp, _mm256_set1_epi32(31));
        let is_sub = _mm256_cmpeq_epi32(exp, _mm256_setzero_si256());
        let mut out = norm;
        out = _mm256_blendv_epi8(out, infnan, is_inf);
        out = _mm256_blendv_epi8(out, _mm256_castps_si256(subf), is_sub);
        _mm256_castsi256_ps(_mm256_or_si256(out, sign))
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn f16_to_f32_lanes_sse41(h: __m128i) -> __m128 {
        let sign = _mm_slli_epi32::<16>(_mm_and_si128(h, _mm_set1_epi32(0x8000)));
        let expmant = _mm_and_si128(h, _mm_set1_epi32(0x7fff));
        let exp = _mm_srli_epi32::<10>(expmant);
        let mant = _mm_and_si128(h, _mm_set1_epi32(0x3ff));
        let norm = _mm_add_epi32(_mm_slli_epi32::<13>(expmant), _mm_set1_epi32(112 << 23));
        let infnan = _mm_or_si128(_mm_set1_epi32(0x7f80_0000), _mm_slli_epi32::<13>(mant));
        let subf = _mm_mul_ps(_mm_cvtepi32_ps(mant), _mm_set1_ps(5.960_464_5e-8));
        let is_inf = _mm_cmpeq_epi32(exp, _mm_set1_epi32(31));
        let is_sub = _mm_cmpeq_epi32(exp, _mm_setzero_si128());
        let mut out = norm;
        out = _mm_blendv_epi8(out, infnan, is_inf);
        out = _mm_blendv_epi8(out, _mm_castps_si128(subf), is_sub);
        _mm_castsi128_ps(_mm_or_si128(out, sign))
    }

    // --- pack/widen helpers -------------------------------------------------

    /// Pack 8 u32 lanes (each ≤ 0xffff) into 8 u16s and store.
    #[target_feature(enable = "avx2")]
    unsafe fn store_u16x8_avx2(lanes: __m256i, dst: *mut u16) {
        let packed = _mm256_packus_epi32(lanes, lanes);
        // qwords 0 and 2 hold the in-order halves
        let perm = _mm256_permute4x64_epi64::<0b1000>(packed);
        _mm_storeu_si128(dst as *mut __m128i, _mm256_castsi256_si128(perm));
    }

    /// Pack 4 u32 lanes (each ≤ 0xffff) into 4 u16s and store.
    #[target_feature(enable = "sse4.1")]
    unsafe fn store_u16x4_sse41(lanes: __m128i, dst: *mut u16) {
        let packed = _mm_packus_epi32(lanes, lanes);
        _mm_storel_epi64(dst as *mut __m128i, packed);
    }

    // --- quantize/dequantize drivers ---------------------------------------

    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_f16_avx2(src: &[f32], dst: &mut [u16]) {
        let n = src.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let bits = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            store_u16x8_avx2(f16_lanes_avx2(bits), dst.as_mut_ptr().add(i));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = f32_to_f16_bits(*src.get_unchecked(i));
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn quantize_f16_sse41(src: &[f32], dst: &mut [u16]) {
        let n = src.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let bits = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            store_u16x4_sse41(f16_lanes_sse41(bits), dst.as_mut_ptr().add(i));
            i += 4;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = f32_to_f16_bits(*src.get_unchecked(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_bf16_avx2(src: &[f32], dst: &mut [u16]) {
        let n = src.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let bits = _mm256_loadu_si256(src.as_ptr().add(i) as *const __m256i);
            store_u16x8_avx2(bf16_lanes_avx2(bits), dst.as_mut_ptr().add(i));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = f32_to_bf16_bits(*src.get_unchecked(i));
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn quantize_bf16_sse41(src: &[f32], dst: &mut [u16]) {
        let n = src.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let bits = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            store_u16x4_sse41(bf16_lanes_sse41(bits), dst.as_mut_ptr().add(i));
            i += 4;
        }
        while i < n {
            *dst.get_unchecked_mut(i) = f32_to_bf16_bits(*src.get_unchecked(i));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dequantize_f16_ptr_avx2(src: *const u16, dst: *mut f32, n: usize) {
        let mut i = 0usize;
        while i + 8 <= n {
            let h = _mm256_cvtepu16_epi32(_mm_loadu_si128(src.add(i) as *const __m128i));
            _mm256_storeu_ps(dst.add(i), f16_to_f32_lanes_avx2(h));
            i += 8;
        }
        while i < n {
            dst.add(i)
                .write(crate::compress::quantize::f16_bits_to_f32(*src.add(i)));
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn dequantize_f16_ptr_sse41(src: *const u16, dst: *mut f32, n: usize) {
        let mut i = 0usize;
        while i + 4 <= n {
            let h = _mm_cvtepu16_epi32(_mm_loadl_epi64(src.add(i) as *const __m128i));
            _mm_storeu_ps(dst.add(i), f16_to_f32_lanes_sse41(h));
            i += 4;
        }
        while i < n {
            dst.add(i)
                .write(crate::compress::quantize::f16_bits_to_f32(*src.add(i)));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn dequantize_bf16_ptr_avx2(src: *const u16, dst: *mut f32, n: usize) {
        let mut i = 0usize;
        while i + 8 <= n {
            let h = _mm256_cvtepu16_epi32(_mm_loadu_si128(src.add(i) as *const __m128i));
            let bits = _mm256_slli_epi32::<16>(h);
            _mm256_storeu_ps(dst.add(i), _mm256_castsi256_ps(bits));
            i += 8;
        }
        while i < n {
            dst.add(i)
                .write(crate::compress::quantize::bf16_bits_to_f32(*src.add(i)));
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    unsafe fn dequantize_bf16_ptr_sse41(src: *const u16, dst: *mut f32, n: usize) {
        let mut i = 0usize;
        while i + 4 <= n {
            let h = _mm_cvtepu16_epi32(_mm_loadl_epi64(src.add(i) as *const __m128i));
            let bits = _mm_slli_epi32::<16>(h);
            _mm_storeu_ps(dst.add(i), _mm_castsi128_ps(bits));
            i += 4;
        }
        while i < n {
            dst.add(i)
                .write(crate::compress::quantize::bf16_bits_to_f32(*src.add(i)));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_f16_avx2(src: &[u16], dst: &mut [f32]) {
        dequantize_f16_ptr_avx2(src.as_ptr(), dst.as_mut_ptr(), src.len());
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dequantize_f16_sse41(src: &[u16], dst: &mut [f32]) {
        dequantize_f16_ptr_sse41(src.as_ptr(), dst.as_mut_ptr(), src.len());
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_bf16_avx2(src: &[u16], dst: &mut [f32]) {
        dequantize_bf16_ptr_avx2(src.as_ptr(), dst.as_mut_ptr(), src.len());
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dequantize_bf16_sse41(src: &[u16], dst: &mut [f32]) {
        dequantize_bf16_ptr_sse41(src.as_ptr(), dst.as_mut_ptr(), src.len());
    }

    // Wire bytes are little-endian u16s and x86 is little-endian, so the
    // byte-slice variants are straight reinterpreting loads. The pointers
    // may be unaligned; all loads are loadu/loadl.

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_f16_le_avx2(src: &[u8], dst: &mut [f32]) {
        dequantize_f16_ptr_avx2(src.as_ptr() as *const u16, dst.as_mut_ptr(), dst.len());
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dequantize_f16_le_sse41(src: &[u8], dst: &mut [f32]) {
        dequantize_f16_ptr_sse41(src.as_ptr() as *const u16, dst.as_mut_ptr(), dst.len());
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dequantize_bf16_le_avx2(src: &[u8], dst: &mut [f32]) {
        dequantize_bf16_ptr_avx2(src.as_ptr() as *const u16, dst.as_mut_ptr(), dst.len());
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn dequantize_bf16_le_sse41(src: &[u8], dst: &mut [f32]) {
        dequantize_bf16_ptr_sse41(src.as_ptr() as *const u16, dst.as_mut_ptr(), dst.len());
    }

    // --- roundtrips (quantize lanes → dequantize lanes, no pack) ------------

    #[target_feature(enable = "avx2")]
    pub unsafe fn roundtrip_f16_avx2(xs: &mut [f32]) {
        let n = xs.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let bits = _mm256_loadu_si256(xs.as_ptr().add(i) as *const __m256i);
            let h = f16_lanes_avx2(bits);
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), f16_to_f32_lanes_avx2(h));
            i += 8;
        }
        while i < n {
            let x = xs.get_unchecked_mut(i);
            *x = crate::compress::quantize::f16_bits_to_f32(f32_to_f16_bits(*x));
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn roundtrip_f16_sse41(xs: &mut [f32]) {
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let bits = _mm_loadu_si128(xs.as_ptr().add(i) as *const __m128i);
            let h = f16_lanes_sse41(bits);
            _mm_storeu_ps(xs.as_mut_ptr().add(i), f16_to_f32_lanes_sse41(h));
            i += 4;
        }
        while i < n {
            let x = xs.get_unchecked_mut(i);
            *x = crate::compress::quantize::f16_bits_to_f32(f32_to_f16_bits(*x));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn roundtrip_bf16_avx2(xs: &mut [f32]) {
        let n = xs.len();
        let mut i = 0usize;
        while i + 8 <= n {
            let bits = _mm256_loadu_si256(xs.as_ptr().add(i) as *const __m256i);
            let h = bf16_lanes_avx2(bits);
            let out = _mm256_slli_epi32::<16>(h);
            _mm256_storeu_ps(xs.as_mut_ptr().add(i), _mm256_castsi256_ps(out));
            i += 8;
        }
        while i < n {
            let x = xs.get_unchecked_mut(i);
            *x = crate::compress::quantize::bf16_bits_to_f32(f32_to_bf16_bits(*x));
            i += 1;
        }
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn roundtrip_bf16_sse41(xs: &mut [f32]) {
        let n = xs.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let bits = _mm_loadu_si128(xs.as_ptr().add(i) as *const __m128i);
            let h = bf16_lanes_sse41(bits);
            let out = _mm_slli_epi32::<16>(h);
            _mm_storeu_ps(xs.as_mut_ptr().add(i), _mm_castsi128_ps(out));
            i += 4;
        }
        while i < n {
            let x = xs.get_unchecked_mut(i);
            *x = crate::compress::quantize::bf16_bits_to_f32(f32_to_bf16_bits(*x));
            i += 1;
        }
    }

    // --- threshold scan (compare → movemask → left-pack) --------------------

    #[target_feature(enable = "avx2")]
    pub unsafe fn threshold_select_avx2(values: &[f32], threshold: f32, out: &mut Vec<u32>) {
        let n = values.len();
        debug_assert!(out.capacity() >= n + 8);
        let ptr = out.as_mut_ptr();
        let mut count = 0usize;
        let abs_mask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let th = _mm256_set1_ps(threshold);
        let iota = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        let eight = _mm256_set1_epi32(8);
        let mut base = iota;
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(values.as_ptr().add(i));
            let a = _mm256_and_ps(v, abs_mask);
            // GE_OQ is false on NaN, matching scalar `v.abs() >= threshold`
            let m = _mm256_movemask_ps(_mm256_cmp_ps::<_CMP_GE_OQ>(a, th)) as usize;
            if m != 0 {
                let perm =
                    _mm256_loadu_si256(AVX2_COMPACT.0[m].as_ptr() as *const __m256i);
                let packed = _mm256_permutevar8x32_epi32(base, perm);
                _mm256_storeu_si256(ptr.add(count) as *mut __m256i, packed);
                count += m.count_ones() as usize;
            }
            base = _mm256_add_epi32(base, eight);
            i += 8;
        }
        while i < n {
            if values.get_unchecked(i).abs() >= threshold {
                ptr.add(count).write(i as u32);
                count += 1;
            }
            i += 1;
        }
        out.set_len(count);
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn threshold_select_sse41(values: &[f32], threshold: f32, out: &mut Vec<u32>) {
        let n = values.len();
        debug_assert!(out.capacity() >= n + 8);
        let ptr = out.as_mut_ptr();
        let mut count = 0usize;
        let abs_mask = _mm_castsi128_ps(_mm_set1_epi32(0x7fff_ffff));
        let th = _mm_set1_ps(threshold);
        let four = _mm_set1_epi32(4);
        let mut base = _mm_setr_epi32(0, 1, 2, 3);
        let mut i = 0usize;
        while i + 4 <= n {
            let v = _mm_loadu_ps(values.as_ptr().add(i));
            let a = _mm_and_ps(v, abs_mask);
            let m = _mm_movemask_ps(_mm_cmpge_ps(a, th)) as usize;
            if m != 0 {
                let shuf = _mm_loadu_si128(SSE_COMPACT.0[m].as_ptr() as *const __m128i);
                let packed = _mm_shuffle_epi8(base, shuf);
                _mm_storeu_si128(ptr.add(count) as *mut __m128i, packed);
                count += m.count_ones() as usize;
            }
            base = _mm_add_epi32(base, four);
            i += 4;
        }
        while i < n {
            if values.get_unchecked(i).abs() >= threshold {
                ptr.add(count).write(i as u32);
                count += 1;
            }
            i += 1;
        }
        out.set_len(count);
    }

    // --- strictly-ascending u32 validation ----------------------------------

    #[target_feature(enable = "avx2")]
    pub unsafe fn max_ascending_u32le_avx2(bytes: &[u8]) -> Result<i64, ()> {
        let n = bytes.len() / 4;
        if n == 0 {
            return Ok(-1);
        }
        let p = bytes.as_ptr();
        let bias = _mm256_set1_epi32(i32::MIN);
        let mut ok = _mm256_set1_epi32(-1);
        let mut e = 1usize;
        while e + 8 <= n {
            let cur = _mm256_loadu_si256(p.add(4 * e) as *const __m256i);
            let prev = _mm256_loadu_si256(p.add(4 * (e - 1)) as *const __m256i);
            // unsigned > via sign-bias
            let gt = _mm256_cmpgt_epi32(
                _mm256_xor_si256(cur, bias),
                _mm256_xor_si256(prev, bias),
            );
            ok = _mm256_and_si256(ok, gt);
            e += 8;
        }
        if _mm256_movemask_epi8(ok) != -1i32 {
            return Err(());
        }
        scalar_ascending_tail(bytes, e)
    }

    #[target_feature(enable = "sse4.1")]
    pub unsafe fn max_ascending_u32le_sse41(bytes: &[u8]) -> Result<i64, ()> {
        let n = bytes.len() / 4;
        if n == 0 {
            return Ok(-1);
        }
        let p = bytes.as_ptr();
        let bias = _mm_set1_epi32(i32::MIN);
        let mut ok = _mm_set1_epi32(-1);
        let mut e = 1usize;
        while e + 4 <= n {
            let cur = _mm_loadu_si128(p.add(4 * e) as *const __m128i);
            let prev = _mm_loadu_si128(p.add(4 * (e - 1)) as *const __m128i);
            let gt = _mm_cmpgt_epi32(_mm_xor_si128(cur, bias), _mm_xor_si128(prev, bias));
            ok = _mm_and_si128(ok, gt);
            e += 4;
        }
        if _mm_movemask_epi8(ok) != 0xffff {
            return Err(());
        }
        scalar_ascending_tail(bytes, e)
    }

    /// Finish an ascending sweep from word index `e` (≥ 1): the vector
    /// loop validated words [1, e); check the rest and return the last.
    fn scalar_ascending_tail(bytes: &[u8], e: usize) -> Result<i64, ()> {
        let n = bytes.len() / 4;
        let word = |j: usize| -> u32 {
            u32::from_le_bytes([
                bytes[4 * j],
                bytes[4 * j + 1],
                bytes[4 * j + 2],
                bytes[4 * j + 3],
            ])
        };
        let mut prev = word(e - 1);
        for j in e..n {
            let cur = word(j);
            if cur <= prev {
                return Err(());
            }
            prev = cur;
        }
        Ok(word(n - 1) as i64)
    }

}

/// Left-packing lookup tables for the threshold scan, built at compile
/// time (mask → lane permutation placing selected lanes first).
#[cfg(target_arch = "x86_64")]
mod quantize_tables {
    /// AVX2: for each 8-bit mask, the `vpermd` indices that move selected
    /// lanes to the front (unselected lanes duplicate lane 0; only the
    /// first `popcount` outputs are live).
    pub struct Avx2Lut(pub [[u32; 8]; 256]);
    /// SSE4.1: for each 4-bit mask, the `pshufb` byte shuffle packing
    /// selected 4-byte lanes to the front.
    pub struct SseLut(pub [[u8; 16]; 16]);

    const fn build_avx2() -> Avx2Lut {
        let mut lut = [[0u32; 8]; 256];
        let mut m = 0usize;
        while m < 256 {
            let mut out_i = 0usize;
            let mut lane = 0usize;
            while lane < 8 {
                if m & (1 << lane) != 0 {
                    lut[m][out_i] = lane as u32;
                    out_i += 1;
                }
                lane += 1;
            }
            m += 1;
        }
        Avx2Lut(lut)
    }

    const fn build_sse() -> SseLut {
        let mut lut = [[0x80u8; 16]; 16];
        let mut m = 0usize;
        while m < 16 {
            let mut out_i = 0usize;
            let mut lane = 0usize;
            while lane < 4 {
                if m & (1 << lane) != 0 {
                    let mut b = 0usize;
                    while b < 4 {
                        lut[m][out_i * 4 + b] = (lane * 4 + b) as u8;
                        b += 1;
                    }
                    out_i += 1;
                }
                lane += 1;
            }
            m += 1;
        }
        SseLut(lut)
    }

    pub static AVX2_COMPACT: Avx2Lut = build_avx2();
    pub static SSE_COMPACT: SseLut = build_sse();
}

// ---------------------------------------------------------------------------
// Tests: every kernel bit-identical to the scalar reference across ragged
// tails, all precisions, and denormal/NaN/±Inf inputs, at every level the
// host supports (the suite also runs with NETSENSE_SIMD=off in verify.sh).
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Adversarial float inputs: denormals, NaN payload variants, ±Inf,
    /// exact halfway-rounding cases, and the f16 under/overflow edges.
    fn edge_values() -> Vec<f32> {
        let mut v = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.5,
            f32::NAN,
            -f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,          // smallest normal f32
            -f32::MIN_POSITIVE,
            f32::from_bits(1),          // smallest denormal f32
            f32::from_bits(0x0000_ffff),
            f32::from_bits(0x7f80_0001), // signalling NaN, low payload
            f32::from_bits(0xffc0_1234), // quiet NaN with payload
            f32::from_bits(0x3380_0000), // 2^-24 (f16 subnormal floor)
            f32::from_bits(0x337f_ffff), // just below the floor
            f32::from_bits(0x3400_0000), // 2^-23 halfway region
            f32::from_bits(0x3880_0000), // smallest f16 normal
            f32::from_bits(0x477f_e000), // f16 max (65504)
            f32::from_bits(0x477f_f000), // rounds to f16 Inf
            f32::from_bits(0x4780_0000), // 65536 → f16 Inf
            65504.0,
            -65504.0,
            65520.0,
            1e-30,
            -1e-30,
            3.141_592_7,
        ];
        // halfway cases for f16 (bit 13 boundary) and bf16 (bit 15)
        v.push(f32::from_bits(0x3f80_1000));
        v.push(f32::from_bits(0x3f80_3000));
        v.push(f32::from_bits(0x3f80_8000));
        v.push(f32::from_bits(0x3f81_8000));
        v
    }

    /// A ragged-length pseudorandom buffer salted with edge values.
    fn mixed_input(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let edges = edge_values();
        (0..len)
            .map(|i| {
                if i % 7 == 3 {
                    edges[(rng.next_u64() as usize) % edges.len()]
                } else {
                    // full-range bit patterns: exercises denormals/NaNs too
                    f32::from_bits(rng.next_u64() as u32)
                }
            })
            .collect()
    }

    fn lens() -> Vec<usize> {
        // ragged tails: every residue mod the widest lane count, plus
        // sizes around the unroll boundaries
        let mut ls: Vec<usize> = (0..=9).collect();
        ls.extend([15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 257, 1000]);
        ls
    }

    #[test]
    fn active_level_is_cached_and_supported() {
        let l = active_level();
        assert!(supported_levels().contains(&l));
        assert_eq!(l, active_level());
    }

    #[test]
    fn simd_sum_sq_bit_identical_across_levels() {
        for &len in &lens() {
            let xs: Vec<f32> = mixed_input(len, 0xA11CE + len as u64)
                .iter()
                // keep L2 finite: strip NaN/Inf (sum order still exercised)
                .map(|x| if x.is_finite() { *x } else { 1.5 })
                .collect();
            let reference = scalar::sum_sq(&xs);
            for &level in supported_levels() {
                let got = sum_sq_with(level, &xs);
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "sum_sq mismatch at len {len} level {level:?}"
                );
            }
        }
    }

    #[test]
    fn simd_compensate_sum_sq_matches_extend_plus_sum() {
        for &len in &lens() {
            let g: Vec<f32> = mixed_input(len, 77 + len as u64)
                .iter()
                .map(|x| if x.is_finite() { *x } else { -0.25 })
                .collect();
            let r: Vec<f32> = mixed_input(len, 991 + len as u64)
                .iter()
                .map(|x| if x.is_finite() { *x } else { 2.0 })
                .collect();
            let expect_vec: Vec<f32> = g.iter().zip(&r).map(|(a, b)| a + b).collect();
            let expect_sq = scalar::sum_sq(&expect_vec);
            for &level in supported_levels() {
                let mut out = Vec::new();
                let sq = compensate_sum_sq_extend_with(level, &g, &r, &mut out);
                assert_eq!(out.len(), len);
                for (i, (a, b)) in out.iter().zip(&expect_vec).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "compensate lane {i} mismatch at len {len} level {level:?}"
                    );
                }
                assert_eq!(sq.to_bits(), expect_sq.to_bits(), "L2 at {level:?}");
            }
        }
    }

    #[test]
    fn simd_f16_quantize_bit_identical_across_levels() {
        for &len in &lens() {
            let xs = mixed_input(len, 5 + len as u64);
            let mut reference = vec![0u16; len];
            scalar::quantize_f16(&xs, &mut reference);
            for &level in supported_levels() {
                let mut got = vec![0u16; len];
                quantize_f16_bits_with(level, &xs, &mut got);
                assert_eq!(got, reference, "f16 quantize len {len} level {level:?}");
            }
        }
    }

    #[test]
    fn simd_f16_quantize_exhaustive_exponent_sweep() {
        // every exponent × a mantissa sample, both signs: catches tier
        // boundary mistakes the random sweep could miss
        let mut xs = Vec::new();
        for e in 0..=255u32 {
            for m in [0u32, 1, 0x1000, 0x1fff, 0x2000, 0x2001, 0x7fffff] {
                xs.push(f32::from_bits((e << 23) | m));
                xs.push(f32::from_bits(0x8000_0000 | (e << 23) | m));
            }
        }
        let mut reference = vec![0u16; xs.len()];
        scalar::quantize_f16(&xs, &mut reference);
        for &level in supported_levels() {
            let mut got = vec![0u16; xs.len()];
            quantize_f16_bits_with(level, &xs, &mut got);
            for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g, r,
                    "f16 sweep mismatch at {level:?} input {:#010x}",
                    xs[i].to_bits()
                );
            }
        }
    }

    #[test]
    fn simd_f16_dequantize_exhaustive_all_patterns() {
        // all 65536 half patterns — dequantize must be bit-exact on each
        let src: Vec<u16> = (0..=u16::MAX).collect();
        let mut reference = vec![0f32; src.len()];
        scalar::dequantize_f16(&src, &mut reference);
        for &level in supported_levels() {
            let mut got = vec![0f32; src.len()];
            dequantize_f16_bits_with(level, &src, &mut got);
            for (i, (g, r)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    r.to_bits(),
                    "f16 dequantize mismatch at {level:?} pattern {:#06x}",
                    src[i]
                );
            }
        }
    }

    #[test]
    fn simd_bf16_quantize_dequantize_bit_identical() {
        for &len in &lens() {
            let xs = mixed_input(len, 31 + len as u64);
            let mut qref = vec![0u16; len];
            scalar::quantize_bf16(&xs, &mut qref);
            let mut dref = vec![0f32; len];
            scalar::dequantize_bf16(&qref, &mut dref);
            for &level in supported_levels() {
                let mut q = vec![0u16; len];
                quantize_bf16_bits_with(level, &xs, &mut q);
                assert_eq!(q, qref, "bf16 quantize len {len} level {level:?}");
                let mut d = vec![0f32; len];
                dequantize_bf16_bits_with(level, &q, &mut d);
                let bits: Vec<u32> = d.iter().map(|x| x.to_bits()).collect();
                let rbits: Vec<u32> = dref.iter().map(|x| x.to_bits()).collect();
                assert_eq!(bits, rbits, "bf16 dequantize len {len} level {level:?}");
            }
        }
    }

    #[test]
    fn simd_roundtrips_match_scalar_reference() {
        for &len in &lens() {
            let xs = mixed_input(len, 1234 + len as u64);
            let mut f16_ref = xs.clone();
            scalar::roundtrip_f16(&mut f16_ref);
            let mut bf16_ref = xs.clone();
            scalar::roundtrip_bf16(&mut bf16_ref);
            for &level in supported_levels() {
                let mut a = xs.clone();
                roundtrip_f16_in_place_with(level, &mut a);
                let mut b = xs.clone();
                roundtrip_bf16_in_place_with(level, &mut b);
                let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                let rb: Vec<u32> = f16_ref.iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, rb, "f16 roundtrip len {len} level {level:?}");
                let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                let rbb: Vec<u32> = bf16_ref.iter().map(|x| x.to_bits()).collect();
                assert_eq!(bb, rbb, "bf16 roundtrip len {len} level {level:?}");
            }
        }
    }

    #[test]
    fn simd_le_byte_dequantize_matches_u16_path() {
        for &len in &lens() {
            let xs = mixed_input(len, 555 + len as u64);
            let mut words = vec![0u16; len];
            scalar::quantize_f16(&xs, &mut words);
            let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
            let mut reference = vec![0f32; len];
            scalar::dequantize_f16_le(&bytes, &mut reference);
            for &level in supported_levels() {
                let mut got = vec![0f32; len];
                dequantize_f16_le_bytes_with(level, &bytes, &mut got);
                let gb: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
                let rb: Vec<u32> = reference.iter().map(|x| x.to_bits()).collect();
                assert_eq!(gb, rb, "f16 LE len {len} level {level:?}");
                let mut got2 = vec![0f32; len];
                dequantize_bf16_le_bytes_with(level, &bytes, &mut got2);
                let mut ref2 = vec![0f32; len];
                scalar::dequantize_bf16_le(&bytes, &mut ref2);
                let g2: Vec<u32> = got2.iter().map(|x| x.to_bits()).collect();
                let r2: Vec<u32> = ref2.iter().map(|x| x.to_bits()).collect();
                assert_eq!(g2, r2, "bf16 LE len {len} level {level:?}");
            }
        }
    }

    #[test]
    fn simd_threshold_select_identical_output() {
        for &len in &lens() {
            let xs = mixed_input(len, 4242 + len as u64);
            for threshold in [0.0f32, 0.25, 1.0, 1e30, f32::INFINITY] {
                let mut reference = Vec::new();
                scalar::threshold_select(&xs, threshold, &mut reference);
                for &level in supported_levels() {
                    let mut got = Vec::new();
                    threshold_select_into_with(level, &xs, threshold, &mut got);
                    assert_eq!(
                        got, reference,
                        "threshold scan len {len} th {threshold} level {level:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn simd_threshold_select_reuses_capacity() {
        let xs = mixed_input(300, 9);
        let mut out = Vec::new();
        threshold_select_into(&xs, 0.5, &mut out);
        let cap = out.capacity();
        for _ in 0..5 {
            threshold_select_into(&xs, 0.5, &mut out);
            assert_eq!(out.capacity(), cap, "capacity must be stable after warmup");
        }
    }

    #[test]
    fn simd_ascending_check_matches_scalar() {
        let mut rng = Pcg64::seeded(7);
        for &n in &[0usize, 1, 2, 3, 7, 8, 9, 15, 16, 17, 40, 100] {
            // ascending case
            let mut asc: Vec<u32> = Vec::new();
            let mut cur = 0u32;
            for _ in 0..n {
                cur = cur.wrapping_add(1 + (rng.next_u64() as u32 % 50));
                asc.push(cur);
            }
            let bytes: Vec<u8> = asc.iter().flat_map(|w| w.to_le_bytes()).collect();
            let reference = scalar::max_ascending_u32le(&bytes);
            for &level in supported_levels() {
                assert_eq!(
                    max_strictly_ascending_u32le_with(level, &bytes),
                    reference,
                    "ascending n {n} level {level:?}"
                );
            }
            // corrupt one word (if any): duplicate its predecessor
            if n >= 2 {
                let k = 1 + (rng.next_u64() as usize % (n - 1));
                let mut bad = asc.clone();
                bad[k] = bad[k - 1];
                let bytes: Vec<u8> = bad.iter().flat_map(|w| w.to_le_bytes()).collect();
                assert!(scalar::max_ascending_u32le(&bytes).is_err());
                for &level in supported_levels() {
                    assert!(
                        max_strictly_ascending_u32le_with(level, &bytes).is_err(),
                        "corruption must be caught at n {n} level {level:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn simd_ascending_check_handles_high_bit_indices() {
        // indices above i32::MAX exercise the unsigned sign-bias compare
        let asc: Vec<u32> = vec![5, 0x7fff_ffff, 0x8000_0000, 0x8000_0001, 0xffff_fffe];
        let bytes: Vec<u8> = asc.iter().flat_map(|w| w.to_le_bytes()).collect();
        for &level in supported_levels() {
            assert_eq!(
                max_strictly_ascending_u32le_with(level, &bytes),
                Ok(0xffff_fffe),
                "high-bit ascent at {level:?}"
            );
        }
        let desc: Vec<u32> = vec![0x8000_0000, 0x7fff_ffff];
        let bytes: Vec<u8> = desc.iter().flat_map(|w| w.to_le_bytes()).collect();
        for &level in supported_levels() {
            assert!(max_strictly_ascending_u32le_with(level, &bytes).is_err());
        }
    }
}
