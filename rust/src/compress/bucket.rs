//! Gradient bucketing for the pipelined exchange: split a flat gradient
//! into fixed-size fused buckets, compress each bucket independently (with
//! per-bucket error-feedback state), and fuse the reduced buckets back into
//! a flat tensor.
//!
//! Why buckets: compressing the whole gradient as one monolithic payload
//! serializes Algorithm 2 ahead of the network — no byte moves until the
//! full quantize/prune/top-k pass finishes. With buckets, the coordinator
//! compresses bucket *k+1* while bucket *k* is in flight
//! ([`crate::coordinator::pipeline_exchange`]), hiding compression cost
//! behind transmission the way DDP gradient bucketing hides backward
//! compute behind all-reduce.
//!
//! Invariants (property-tested below):
//! - `fuse(split(g)) == g` for every layout;
//! - error feedback never leaks across bucket boundaries — each bucket's
//!   residual evolves exactly as an independent [`NetSenseCompressor`] of
//!   that bucket's length would.
//!
//! ```
//! use netsenseml::compress::bucket::BucketLayout;
//!
//! let layout = BucketLayout::new(10, 4); // buckets of 4 elements
//! assert_eq!(layout.n_buckets(), 3);
//! assert_eq!(layout.range(2), 8..10); // last bucket is the remainder
//!
//! let g: Vec<f32> = (0..10).map(|i| i as f32).collect();
//! let parts: Vec<Vec<f32>> = layout.split(&g).iter().map(|s| s.to_vec()).collect();
//! assert_eq!(parts[2], vec![8.0, 9.0]);
//! assert_eq!(layout.fuse(&parts), g);
//! ```

use super::pipeline::{
    CompressionConfig, CompressionOutcome, CompressorState, FusedOutcome, NetSenseCompressor,
};
use super::workspace::WorkspacePool;
use std::ops::Range;

/// How a flat tensor of `n_total` elements is cut into buckets: every
/// bucket holds `bucket_elems` elements except a possibly-shorter last one.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketLayout {
    n_total: usize,
    bucket_elems: usize,
}

impl BucketLayout {
    pub fn new(n_total: usize, bucket_elems: usize) -> BucketLayout {
        assert!(bucket_elems > 0, "bucket_elems must be positive");
        BucketLayout {
            n_total,
            bucket_elems,
        }
    }

    /// Layout from a dense byte budget per bucket (f32 elements).
    pub fn from_bytes(n_total: usize, bucket_bytes: u64) -> BucketLayout {
        BucketLayout::new(n_total, ((bucket_bytes / 4) as usize).max(1))
    }

    pub fn n_total(&self) -> usize {
        self.n_total
    }

    pub fn bucket_elems(&self) -> usize {
        self.bucket_elems
    }

    pub fn n_buckets(&self) -> usize {
        self.n_total.div_ceil(self.bucket_elems)
    }

    /// Element range of bucket `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        assert!(i < self.n_buckets(), "bucket {i} out of range");
        let start = i * self.bucket_elems;
        start..(start + self.bucket_elems).min(self.n_total)
    }

    /// Element count of bucket `i`.
    pub fn elems(&self, i: usize) -> usize {
        self.range(i).len()
    }

    /// Dense f32 bytes of bucket `i`.
    pub fn dense_bytes(&self, i: usize) -> u64 {
        4 * self.elems(i) as u64
    }

    /// Split a dense tensor into per-bucket slices (no copies).
    pub fn split<'a>(&self, dense: &'a [f32]) -> Vec<&'a [f32]> {
        assert_eq!(dense.len(), self.n_total, "dense length mismatch");
        (0..self.n_buckets()).map(|i| &dense[self.range(i)]).collect()
    }

    /// Fuse per-bucket dense tensors back into one flat tensor — the exact
    /// inverse of [`BucketLayout::split`].
    pub fn fuse(&self, parts: &[Vec<f32>]) -> Vec<f32> {
        assert_eq!(parts.len(), self.n_buckets(), "bucket count mismatch");
        let mut out = Vec::with_capacity(self.n_total);
        for (i, p) in parts.iter().enumerate() {
            assert_eq!(p.len(), self.elems(i), "bucket {i} length mismatch");
            out.extend_from_slice(p);
        }
        out
    }
}

/// Group consecutive items (by their byte sizes) into ranges whose summed
/// size stays at or under `target_bytes` — except that every group holds at
/// least one item, so oversized single items still form a group. Used to
/// coalesce compression buckets into transport units sized to the sensed
/// BDP ([`crate::sensing::RatioController::recommended_bucket_bytes`]).
pub fn group_indices_by_bytes(sizes: &[u64], target_bytes: u64) -> Vec<Range<usize>> {
    let mut groups = Vec::new();
    let mut start = 0usize;
    let mut acc = 0u64;
    for (i, &s) in sizes.iter().enumerate() {
        if i > start && acc + s > target_bytes {
            groups.push(start..i);
            start = i;
            acc = 0;
        }
        acc += s;
    }
    if start < sizes.len() {
        groups.push(start..sizes.len());
    }
    groups
}

/// Per-bucket Algorithm-2 compression of one flat gradient tensor: one
/// [`NetSenseCompressor`] (and therefore one error-feedback residual) per
/// bucket.
pub struct BucketedCompressor {
    layout: BucketLayout,
    compressors: Vec<NetSenseCompressor>,
    /// Reused per-bucket wire buffers: after
    /// [`Self::compress_frames`], `frames[b]` holds bucket `b`'s complete
    /// length-prefixed frame. Capacity survives across steps (§Perf:
    /// steady state re-fills in place, no allocation).
    frames: Vec<Vec<u8>>,
    /// Reused per-bucket fused outcomes (same indexing as `frames`).
    outcomes: Vec<FusedOutcome>,
}

impl BucketedCompressor {
    pub fn new(layout: BucketLayout, config: CompressionConfig) -> BucketedCompressor {
        let nb = layout.n_buckets();
        let compressors = (0..nb)
            .map(|i| NetSenseCompressor::new(layout.elems(i), config.clone()))
            .collect();
        BucketedCompressor {
            layout,
            compressors,
            frames: (0..nb).map(|_| Vec::new()).collect(),
            outcomes: vec![FusedOutcome::default(); nb],
        }
    }

    pub fn layout(&self) -> &BucketLayout {
        &self.layout
    }

    pub fn n(&self) -> usize {
        self.layout.n_total()
    }

    /// Run Algorithm 2 on every bucket of `grads` at the controller's
    /// `ratio`. Outcome `i` is bucket `i`'s payload, with indices local to
    /// the bucket (offset by `layout.range(i).start` in the flat tensor).
    pub fn compress(
        &mut self,
        grads: &[f32],
        weights: &[f32],
        ratio: f64,
    ) -> Vec<CompressionOutcome> {
        assert_eq!(grads.len(), self.n(), "gradient length mismatch");
        assert_eq!(weights.len(), self.n(), "weight length mismatch");
        self.compressors
            .iter_mut()
            .enumerate()
            .map(|(i, c)| {
                let r = self.layout.range(i);
                c.compress(&grads[r.clone()], &weights[r], ratio)
            })
            .collect()
    }

    /// Fused compression of every bucket straight to length-prefixed wire
    /// frames, in parallel across the pool's workspaces.
    ///
    /// Buckets are split into contiguous chunks — one per workspace, via a
    /// dependency-free `std::thread::scope` fan-out — so with a pool of
    /// `t` workspaces up to `t` buckets compress concurrently. Each bucket
    /// still runs on its own [`NetSenseCompressor`] (its own residual,
    /// threshold hint, prune cache), so the result is bit-identical to
    /// [`Self::compress`]-then-encode at *any* pool width, including 1
    /// (which runs inline: no spawns, and zero steady-state allocations).
    ///
    /// Returns `(outcomes, frames)`, both indexed by bucket; `frames[b]`
    /// holds `8 + outcomes[b].wire_bytes` bytes.
    pub fn compress_frames(
        &mut self,
        grads: &[f32],
        weights: &[f32],
        ratio: f64,
        pool: &mut WorkspacePool,
    ) -> (&[FusedOutcome], &[Vec<u8>]) {
        assert_eq!(grads.len(), self.n(), "gradient length mismatch");
        assert_eq!(weights.len(), self.n(), "weight length mismatch");
        let nb = self.layout.n_buckets();
        let threads = pool.len().min(nb).max(1);
        let layout = &self.layout;
        if threads <= 1 {
            let ws = pool.workspace_mut(0);
            for (b, ((comp, frame), out)) in self
                .compressors
                .iter_mut()
                .zip(self.frames.iter_mut())
                .zip(self.outcomes.iter_mut())
                .enumerate()
            {
                let r = layout.range(b);
                frame.clear();
                *out = comp.compress_frame_into(&grads[r.clone()], &weights[r], ratio, ws, frame);
            }
        } else {
            let chunk = nb.div_ceil(threads);
            let compressors = &mut self.compressors;
            let frames = &mut self.frames;
            let outcomes = &mut self.outcomes;
            std::thread::scope(|s| {
                for (ci, (((comps, frs), outs), ws)) in compressors
                    .chunks_mut(chunk)
                    .zip(frames.chunks_mut(chunk))
                    .zip(outcomes.chunks_mut(chunk))
                    .zip(pool.workspaces_mut().iter_mut())
                    .enumerate()
                {
                    let base = ci * chunk;
                    s.spawn(move || {
                        for (j, ((comp, frame), out)) in
                            comps.iter_mut().zip(frs.iter_mut()).zip(outs.iter_mut()).enumerate()
                        {
                            let r = layout.range(base + j);
                            frame.clear();
                            *out = comp.compress_frame_into(
                                &grads[r.clone()],
                                &weights[r],
                                ratio,
                                ws,
                                frame,
                            );
                        }
                    });
                }
            });
        }
        (&self.outcomes, &self.frames)
    }

    /// Per-bucket wire-size prediction (byte-exact vs [`Self::compress`],
    /// same contract as [`NetSenseCompressor::predict_wire_bytes`]).
    pub fn predict_wire_bytes(&self, ratio: f64) -> Vec<u64> {
        self.compressors
            .iter()
            .map(|c| c.predict_wire_bytes(ratio))
            .collect()
    }

    /// Would any bucket quantize at `ratio`? (Mirrors the `quantized`
    /// outcome of [`Self::compress`]: an OR across buckets.)
    pub fn would_quantize(&self, ratio: f64) -> bool {
        self.compressors.iter().any(|c| c.would_quantize(ratio))
    }

    /// Per-bucket state snapshot for checkpointing (same bit-exact
    /// resumption contract as [`NetSenseCompressor::export_state`]).
    pub fn export_state(&self) -> Vec<CompressorState> {
        self.compressors
            .iter()
            .map(NetSenseCompressor::export_state)
            .collect()
    }

    /// Restore a [`Self::export_state`] snapshot (bucket count and
    /// lengths must match the layout).
    pub fn import_state(&mut self, states: &[CompressorState]) {
        assert_eq!(
            states.len(),
            self.compressors.len(),
            "checkpoint bucket count mismatch"
        );
        for (c, s) in self.compressors.iter_mut().zip(states) {
            c.import_state(s);
        }
    }

    /// L2 norm of the concatenated residual across buckets.
    pub fn residual_norm(&self) -> f64 {
        self.compressors
            .iter()
            .map(|c| {
                let r = c.residual_norm();
                r * r
            })
            .sum::<f64>()
            .sqrt()
    }

    /// Per-bucket residual norms (compression-health metric).
    pub fn residual_norms(&self) -> Vec<f64> {
        self.compressors.iter().map(|c| c.residual_norm()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::*;
    use crate::util::rng::Pcg64;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg64::seeded(seed);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }

    #[test]
    fn layout_basics() {
        let l = BucketLayout::new(100, 32);
        assert_eq!(l.n_buckets(), 4);
        assert_eq!(l.range(0), 0..32);
        assert_eq!(l.range(3), 96..100);
        assert_eq!(l.elems(3), 4);
        assert_eq!(l.dense_bytes(0), 128);
        // exact division: no runt bucket
        let l = BucketLayout::new(64, 32);
        assert_eq!(l.n_buckets(), 2);
        assert_eq!(l.elems(1), 32);
        // bucket larger than tensor: one bucket
        let l = BucketLayout::from_bytes(10, 1 << 20);
        assert_eq!(l.n_buckets(), 1);
        assert_eq!(l.range(0), 0..10);
    }

    #[test]
    fn from_bytes_floors_at_one_element() {
        let l = BucketLayout::from_bytes(8, 1);
        assert_eq!(l.bucket_elems(), 1);
        assert_eq!(l.n_buckets(), 8);
    }

    #[test]
    fn property_fuse_split_roundtrip() {
        forall(
            "fuse(split(g)) == g",
            100,
            pair(vec_f32(1..300, -100.0..100.0), usize_in(1..64)),
            |(g, bucket_elems)| {
                let layout = BucketLayout::new(g.len(), *bucket_elems);
                let parts: Vec<Vec<f32>> =
                    layout.split(g).iter().map(|s| s.to_vec()).collect();
                layout.fuse(&parts) == *g
            },
        );
    }

    #[test]
    fn property_split_covers_every_element_once() {
        forall(
            "split is a partition",
            100,
            pair(vec_f32(1..200, -1.0..1.0), usize_in(1..50)),
            |(g, bucket_elems)| {
                let layout = BucketLayout::new(g.len(), *bucket_elems);
                let total: usize = (0..layout.n_buckets()).map(|i| layout.elems(i)).sum();
                let contiguous = (0..layout.n_buckets().saturating_sub(1))
                    .all(|i| layout.range(i).end == layout.range(i + 1).start);
                total == g.len() && contiguous
            },
        );
    }

    #[test]
    fn grouping_respects_target() {
        let sizes = vec![10u64, 10, 10, 10, 10];
        assert_eq!(group_indices_by_bytes(&sizes, 25), vec![0..2, 2..4, 4..5]);
        // target smaller than any item → singletons
        assert_eq!(
            group_indices_by_bytes(&sizes, 5),
            vec![0..1, 1..2, 2..3, 3..4, 4..5]
        );
        // target covers everything → one group
        assert_eq!(group_indices_by_bytes(&sizes, 1_000), vec![0..5]);
        assert_eq!(group_indices_by_bytes(&[], 10), Vec::<std::ops::Range<usize>>::new());
    }

    #[test]
    fn property_grouping_is_a_partition() {
        forall(
            "groups tile 0..n in order",
            100,
            pair(usize_in(0..40), usize_in(1..2000)),
            |&(n, target)| {
                let sizes: Vec<u64> = (0..n).map(|i| (i as u64 % 17) * 37 + 1).collect();
                let groups = group_indices_by_bytes(&sizes, target as u64);
                let mut next = 0usize;
                for g in &groups {
                    if g.start != next || g.is_empty() {
                        return false;
                    }
                    next = g.end;
                }
                next == n
            },
        );
    }

    #[test]
    fn bucketed_wire_prediction_matches_actual() {
        let n = 10_000;
        let layout = BucketLayout::new(n, 1536);
        let g = randn(n, 1);
        let w = randn(n, 2);
        for &ratio in &[1.0, 0.3, 0.1, 0.04, 0.01] {
            let mut bc = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
            let predicted = bc.predict_wire_bytes(ratio);
            let actual: Vec<u64> = bc
                .compress(&g, &w, ratio)
                .iter()
                .map(|o| o.wire_bytes)
                .collect();
            assert_eq!(predicted, actual, "ratio {ratio}");
        }
    }

    #[test]
    fn per_bucket_error_feedback_matches_independent_compressors() {
        // The bucketed compressor must be bit-identical to running an
        // independent NetSenseCompressor on each slice — residuals included.
        let n = 4096;
        let layout = BucketLayout::new(n, 1000);
        let w = randn(n, 3);
        let mut bc = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        let mut refs: Vec<NetSenseCompressor> = (0..layout.n_buckets())
            .map(|i| NetSenseCompressor::new(layout.elems(i), CompressionConfig::default()))
            .collect();
        for step in 0..5 {
            let g = randn(n, 100 + step);
            let outs = bc.compress(&g, &w, 0.05);
            for (i, r) in refs.iter_mut().enumerate() {
                let range = layout.range(i);
                let want = r.compress(&g[range.clone()], &w[range], 0.05);
                assert_eq!(outs[i].payload, want.payload, "step {step} bucket {i}");
                assert_eq!(
                    bc.residual_norms()[i],
                    r.residual_norm(),
                    "step {step} bucket {i} residual"
                );
            }
        }
    }

    #[test]
    fn fused_frames_bit_identical_to_staged_compress_across_steps() {
        // The fused parallel path must emit, bucket for bucket, the exact
        // frame bytes the staged path (compress → quantize_values →
        // encode → encode_frame) produces — across steps, so the
        // error-feedback state evolves identically too.
        use crate::transport::frame::encode_frame;
        let n = 4096;
        let layout = BucketLayout::new(n, 1000);
        let w = randn(n, 30);
        let mut staged = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        let mut fused = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        let mut pool = WorkspacePool::new(3);
        // Ratio sweep crosses the quantization boundary (0.01 < tr_q) and
        // includes the ratio=1.0 send-everything case.
        for (step, &ratio) in [0.1, 0.05, 0.01, 1.0, 0.1, 0.01].iter().enumerate() {
            let g = randn(n, 300 + step as u64);
            let outs_staged = staged.compress(&g, &w, ratio);
            let (outs_fused, frames) = fused.compress_frames(&g, &w, ratio, &mut pool);
            for (b, (so, fo)) in outs_staged.iter().zip(outs_fused).enumerate() {
                assert_eq!(
                    frames[b],
                    encode_frame(&so.payload.encode()),
                    "step {step} bucket {b}: wire bytes diverged"
                );
                assert_eq!(so.wire_bytes, fo.wire_bytes, "step {step} bucket {b}");
                assert_eq!(so.quantized, fo.quantized, "step {step} bucket {b}");
                assert_eq!(so.payload.nnz(), fo.nnz, "step {step} bucket {b}");
            }
            assert_eq!(
                staged.residual_norms(),
                fused.residual_norms(),
                "step {step}: error-feedback state diverged"
            );
        }
    }

    #[test]
    fn fused_frames_identical_at_any_pool_width() {
        // Parallel chunking is a scheduling choice only: pools of 1 (the
        // inline no-spawn path), 2, and 8 must produce identical frames.
        let n = 5000;
        let layout = BucketLayout::new(n, 640);
        let w = randn(n, 31);
        let mut reference: Option<Vec<Vec<u8>>> = None;
        for width in [1usize, 2, 8] {
            let mut bc = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
            let mut pool = WorkspacePool::new(width);
            let mut got: Vec<Vec<u8>> = Vec::new();
            for step in 0..4 {
                let g = randn(n, 400 + step);
                let (_, frames) = bc.compress_frames(&g, &w, 0.05, &mut pool);
                got = frames.to_vec();
            }
            match &reference {
                None => reference = Some(got),
                Some(want) => assert_eq!(&got, want, "pool width {width} diverged"),
            }
        }
    }

    /// Receive-side twin of the fused-frames property: across ragged
    /// bucket layouts (runt last bucket), multiple peers, and steps that
    /// cross the quantization boundary, the fused decode-reduce of every
    /// bucket frame must reproduce the staged receive (decode → add_into)
    /// bit for bit.
    #[test]
    fn fused_decode_reduce_matches_staged_receive_on_ragged_buckets() {
        use crate::compress::sparse::decode_reduce_frame_into;
        use crate::compress::SparseGradient;
        let n = 4096;
        let n_peers = 3;
        // 1000 does not divide 4096: the last bucket is a 96-element runt.
        let layout = BucketLayout::new(n, 1000);
        let w = randn(n, 40);
        let mut peers: Vec<BucketedCompressor> = (0..n_peers)
            .map(|_| BucketedCompressor::new(layout.clone(), CompressionConfig::default()))
            .collect();
        let mut pool = WorkspacePool::new(2);
        for (step, &ratio) in [0.1, 0.05, 0.01, 1.0, 0.003].iter().enumerate() {
            let mut staged: Vec<Vec<f32>> =
                (0..layout.n_buckets()).map(|b| vec![0f32; layout.elems(b)]).collect();
            let mut fused = staged.clone();
            for (p, bc) in peers.iter_mut().enumerate() {
                let g = randn(n, 500 + (step * n_peers + p) as u64);
                let (_, frames) = bc.compress_frames(&g, &w, ratio, &mut pool);
                for (b, frame) in frames.iter().enumerate() {
                    SparseGradient::decode(&frame[8..])
                        .unwrap()
                        .add_into(&mut staged[b]);
                    decode_reduce_frame_into(frame, &mut fused[b])
                        .unwrap_or_else(|e| panic!("step {step} bucket {b}: {e}"));
                }
            }
            for (b, (s, f)) in staged.iter().zip(&fused).enumerate() {
                assert!(
                    s.iter().zip(f.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "step {step} bucket {b}: fused receive diverged from staged"
                );
            }
        }
    }

    #[test]
    fn error_feedback_does_not_leak_across_buckets() {
        // Bucket 0 sees zero gradients forever; its residual must stay
        // exactly zero no matter how much mass the other buckets carry.
        let n = 3000;
        let layout = BucketLayout::new(n, 1000);
        let mut bc = BucketedCompressor::new(layout, CompressionConfig::default());
        let w = randn(n, 4);
        for step in 0..10 {
            let mut g = randn(n, 200 + step);
            for x in g[0..1000].iter_mut() {
                *x = 0.0;
            }
            bc.compress(&g, &w, 0.02);
        }
        let norms = bc.residual_norms();
        assert_eq!(norms[0], 0.0, "bucket 0 residual leaked: {norms:?}");
        assert!(norms[1] > 0.0 && norms[2] > 0.0);
    }

    #[test]
    fn residual_mass_drains_per_bucket() {
        // Same conservation behaviour as the monolithic compressor: feed a
        // gradient once, then zeros; every bucket's residual must drain.
        let n = 2048;
        let layout = BucketLayout::new(n, 512);
        let mut bc = BucketedCompressor::new(layout, CompressionConfig::default());
        let g = randn(n, 5);
        let w = randn(n, 6);
        bc.compress(&g, &w, 0.01);
        let before = bc.residual_norms();
        assert!(before.iter().all(|&r| r > 0.0));
        let zeros = vec![0f32; n];
        for _ in 0..200 {
            bc.compress(&zeros, &w, 0.1);
        }
        let after = bc.residual_norms();
        for (i, (b, a)) in before.iter().zip(&after).enumerate() {
            assert!(a < &(b * 0.5), "bucket {i} residual did not drain: {b} → {a}");
        }
    }

    #[test]
    fn fused_payload_sum_tracks_dense_mean_over_time() {
        // Error-feedback conservation across the split/fuse boundary: over
        // many rounds the transmitted mass equals the injected mass.
        let n = 1500;
        let layout = BucketLayout::new(n, 400);
        let mut bc = BucketedCompressor::new(layout.clone(), CompressionConfig::default());
        let g = randn(n, 7);
        let w = randn(n, 8);
        let rounds = 30;
        let mut sum = vec![0f64; n];
        for _ in 0..rounds {
            let outs = bc.compress(&g, &w, 0.25);
            let parts: Vec<Vec<f32>> = outs.iter().map(|o| o.payload.to_dense()).collect();
            let fused = layout.fuse(&parts);
            for (s, &v) in sum.iter_mut().zip(&fused) {
                *s += v as f64;
            }
        }
        let mut err = 0f64;
        let mut mag = 0f64;
        for i in 0..n {
            let want = g[i] as f64 * rounds as f64;
            err += (sum[i] - want).abs();
            mag += want.abs();
        }
        assert!(err / mag < 0.15, "relative drift {}", err / mag);
    }
}
