//! Model pruning (Algorithm 2, step 2): select the parameters with the
//! smallest |weight| at rate `0.5 × (1 − ratio)` and zero *their gradients*
//! for this step. Pruned parameters are not removed — they are merely
//! excluded from gradient transport and can reactivate later (the paper's
//! "gradually reactivated in subsequent training iterations").

use super::topk::{k_for_ratio, kth_magnitude};

/// The paper's pruning-rate rule: `ratio_p = 0.5 × (1 − ratio)`.
pub fn pruning_rate_for(ratio: f64) -> f64 {
    (0.5 * (1.0 - ratio)).clamp(0.0, 0.5)
}

/// A pruning mask over a flat parameter vector. `true` = pruned.
#[derive(Clone, Debug)]
pub struct PruneMask {
    pub pruned: Vec<bool>,
    pub n_pruned: usize,
}

impl PruneMask {
    /// Build a mask that prunes the `rate` fraction of parameters with the
    /// smallest absolute weight.
    pub fn smallest_weights(weights: &[f32], rate: f64) -> PruneMask {
        let n = weights.len();
        let n_prune = k_for_ratio(n, rate).min(n);
        let mut pruned = vec![false; n];
        if n_prune == 0 {
            return PruneMask { pruned, n_pruned: 0 };
        }
        if n_prune == n {
            return PruneMask {
                pruned: vec![true; n],
                n_pruned: n,
            };
        }
        // Threshold = the (n - n_prune)-th largest magnitude; anything
        // strictly below it is pruned. Ties at the threshold survive, so
        // the realized count can undershoot slightly — fill from the
        // smallest ties to hit the exact count.
        let keep_k = n - n_prune;
        let threshold = kth_magnitude(weights, keep_k);
        let mut n_pruned = 0usize;
        for (i, &w) in weights.iter().enumerate() {
            if w.abs() < threshold {
                pruned[i] = true;
                n_pruned += 1;
            }
        }
        if n_pruned < n_prune {
            // prune ties (== threshold) until the count is exact
            for (i, &w) in weights.iter().enumerate() {
                if n_pruned == n_prune {
                    break;
                }
                if !pruned[i] && w.abs() == threshold {
                    pruned[i] = true;
                    n_pruned += 1;
                }
            }
        }
        PruneMask { pruned, n_pruned }
    }

    /// Zero the gradients of pruned parameters in place; returns how many
    /// were actually non-zero before.
    pub fn apply(&self, grads: &mut [f32]) -> usize {
        assert_eq!(grads.len(), self.pruned.len());
        let mut zeroed = 0;
        for (g, &p) in grads.iter_mut().zip(self.pruned.iter()) {
            if p {
                if *g != 0.0 {
                    zeroed += 1;
                }
                *g = 0.0;
            }
        }
        zeroed
    }

    pub fn rate(&self) -> f64 {
        if self.pruned.is_empty() {
            0.0
        } else {
            self.n_pruned as f64 / self.pruned.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn rate_rule_matches_paper() {
        assert_eq!(pruning_rate_for(1.0), 0.0); // no compression → no pruning
        assert_eq!(pruning_rate_for(0.0), 0.5);
        assert_eq!(pruning_rate_for(0.5), 0.25);
        // Out-of-range ratios are clamped.
        assert_eq!(pruning_rate_for(2.0), 0.0);
        assert_eq!(pruning_rate_for(-1.0), 0.5);
    }

    #[test]
    fn prunes_smallest_magnitudes() {
        let w = [0.1f32, -5.0, 0.2, 4.0, -0.05, 3.0];
        let m = PruneMask::smallest_weights(&w, 0.5);
        assert_eq!(m.n_pruned, 3);
        assert!(m.pruned[0] && m.pruned[2] && m.pruned[4]);
        assert!(!m.pruned[1] && !m.pruned[3] && !m.pruned[5]);
    }

    #[test]
    fn apply_zeroes_only_pruned() {
        let w = [0.1f32, -5.0, 0.2, 4.0];
        let m = PruneMask::smallest_weights(&w, 0.5);
        let mut g = [1.0f32, 2.0, 3.0, 4.0];
        let zeroed = m.apply(&mut g);
        assert_eq!(zeroed, 2);
        assert_eq!(g, [0.0, 2.0, 0.0, 4.0]);
    }

    #[test]
    fn zero_rate_prunes_nothing_full_rate_everything() {
        let w = [1.0f32, 2.0, 3.0];
        assert_eq!(PruneMask::smallest_weights(&w, 0.0).n_pruned, 0);
        assert_eq!(PruneMask::smallest_weights(&w, 1.0).n_pruned, 3);
    }

    #[test]
    fn exact_count_with_ties() {
        let w = vec![1.0f32; 100];
        let m = PruneMask::smallest_weights(&w, 0.3);
        assert_eq!(m.n_pruned, 30);
        assert!((m.rate() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn property_count_matches_rate() {
        forall(
            "pruned count == round(rate·n) (±1 floor)",
            100,
            vec_f32(1..300, -10.0..10.0),
            |v| {
                let rate = 0.25;
                let m = PruneMask::smallest_weights(v, rate);
                let expect = crate::compress::topk::k_for_ratio(v.len(), rate);
                m.n_pruned == expect
            },
        );
    }

    #[test]
    fn property_pruned_have_no_larger_magnitude_than_kept() {
        let mut r = Pcg64::seeded(30);
        for _ in 0..30 {
            let n = 10 + r.index(200);
            let mut w = vec![0f32; n];
            r.fill_normal_f32(&mut w, 0.0, 2.0);
            let m = PruneMask::smallest_weights(&w, 0.4);
            let max_pruned = w
                .iter()
                .zip(&m.pruned)
                .filter(|&(_, &p)| p)
                .map(|(&x, _)| x.abs())
                .fold(0.0f32, f32::max);
            let min_kept = w
                .iter()
                .zip(&m.pruned)
                .filter(|&(_, &p)| !p)
                .map(|(&x, _)| x.abs())
                .fold(f32::MAX, f32::min);
            assert!(
                max_pruned <= min_kept,
                "pruned {max_pruned} > kept {min_kept}"
            );
        }
    }

    #[test]
    fn empty_weights() {
        let m = PruneMask::smallest_weights(&[], 0.5);
        assert_eq!(m.n_pruned, 0);
        assert_eq!(m.rate(), 0.0);
    }
}
