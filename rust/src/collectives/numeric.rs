//! Numeric halves of the collectives: the actual reductions, computed
//! exactly (chunked accumulation keeps the hot loop auto-vectorizable).

use crate::compress::SparseGradient;

/// Sum `others` into `acc` elementwise.
pub fn sum_dense(acc: &mut [f32], others: &[&[f32]]) {
    for o in others {
        assert_eq!(o.len(), acc.len(), "dense length mismatch");
        for (a, &b) in acc.iter_mut().zip(o.iter()) {
            *a += b;
        }
    }
}

/// Mean of `n` dense buffers: sums into the first and scales.
pub fn mean_dense(acc: &mut [f32], others: &[&[f32]]) {
    sum_dense(acc, others);
    let scale = 1.0 / (others.len() + 1) as f32;
    for a in acc.iter_mut() {
        *a *= scale;
    }
}

/// Sum sparse gradients into a dense accumulator (the all-gather receive
/// path: every worker materializes the sum of everyone's payloads).
pub fn sum_sparse(n_total: usize, payloads: &[SparseGradient]) -> Vec<f32> {
    let mut acc = vec![0f32; n_total];
    for p in payloads {
        assert_eq!(p.n_total, n_total, "sparse length mismatch");
        p.add_into(&mut acc);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quantize::Precision;
    use crate::compress::topk::top_k_indices;
    use crate::testing::prop::*;

    #[test]
    fn sum_dense_basic() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        let b = vec![10.0f32, 20.0, 30.0];
        let c = vec![100.0f32, 200.0, 300.0];
        sum_dense(&mut a, &[&b, &c]);
        assert_eq!(a, vec![111.0, 222.0, 333.0]);
    }

    #[test]
    fn mean_dense_basic() {
        let mut a = vec![3.0f32, 3.0];
        let b = vec![6.0f32, 0.0];
        mean_dense(&mut a, &[&b]);
        assert_eq!(a, vec![4.5, 1.5]);
    }

    #[test]
    fn sum_sparse_equals_dense_sum() {
        forall(
            "sparse-sum == dense-sum",
            50,
            vec_f32(8..128, -10.0..10.0),
            |v| {
                let k = (v.len() / 3).max(1);
                let s1 = SparseGradient::gather(v, top_k_indices(v, k), Precision::F32);
                let flipped: Vec<f32> = v.iter().map(|x| -x * 0.5).collect();
                let s2 = SparseGradient::gather(
                    &flipped,
                    top_k_indices(&flipped, k),
                    Precision::F32,
                );
                let got = sum_sparse(v.len(), &[s1.clone(), s2.clone()]);
                let mut want = s1.to_dense();
                let d2 = s2.to_dense();
                sum_dense(&mut want, &[&d2]);
                got == want
            },
        );
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        let mut a = vec![0f32; 3];
        let b = vec![0f32; 4];
        sum_dense(&mut a, &[&b]);
    }
}
