//! Timing models: schedule each collective's transfers on the simulator.

use crate::netsim::{NetSim, SimTime};

/// Timing outcome of one collective operation.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveTiming {
    pub start: SimTime,
    pub end: SimTime,
    /// Bytes each worker pushed into its uplink.
    pub sent_per_worker: Vec<u64>,
}

impl CollectiveTiming {
    /// The collective's wall time — the paper's per-interval "RTT"
    /// observable for gradient synchronization.
    pub fn elapsed(&self) -> SimTime {
        self.end - self.start
    }

    pub fn total_sent(&self) -> u64 {
        self.sent_per_worker.iter().sum()
    }
}

/// Ring all-reduce of a `total_bytes` dense buffer across all workers:
/// 2(N−1) phases, each moving a `total_bytes / N` chunk from every worker
/// to its ring successor (reduce-scatter then all-gather). Advances the
/// simulator to the completion time.
pub fn ring_allreduce(sim: &mut NetSim, total_bytes: u64) -> CollectiveTiming {
    let n = sim.topology.n_workers();
    let start = sim.now();
    if n == 1 {
        return CollectiveTiming {
            start,
            end: start,
            sent_per_worker: vec![0],
        };
    }
    let chunk = total_bytes.div_ceil(n as u64).max(1);
    let mut sent = vec![0u64; n];
    for _phase in 0..(2 * (n - 1)) {
        let transfers: Vec<(usize, usize, u64)> =
            (0..n).map(|i| (i, (i + 1) % n, chunk)).collect();
        sim.phase(&transfers);
        for s in sent.iter_mut() {
            *s += chunk;
        }
    }
    CollectiveTiming {
        start,
        end: sim.now(),
        sent_per_worker: sent,
    }
}

/// Ring all-gather of per-worker payloads (sizes may differ, e.g. sparse
/// gradients with different nnz): N−1 phases; in phase `p`, worker `i`
/// forwards the block that originated at worker `(i + n - p) % n` to its
/// successor. Every worker ends holding every payload.
pub fn ring_allgather(sim: &mut NetSim, payload_bytes: &[u64]) -> CollectiveTiming {
    let n = sim.topology.n_workers();
    assert_eq!(payload_bytes.len(), n, "payload per worker required");
    let start = sim.now();
    let mut sent = vec![0u64; n];
    if n == 1 {
        return CollectiveTiming {
            start,
            end: start,
            sent_per_worker: sent,
        };
    }
    for p in 0..(n - 1) {
        let transfers: Vec<(usize, usize, u64)> = (0..n)
            .map(|i| {
                let origin = (i + n - p) % n;
                (i, (i + 1) % n, payload_bytes[origin].max(1))
            })
            .collect();
        sim.phase(&transfers);
        for (i, t) in transfers.iter().enumerate() {
            sent[i] += t.2;
        }
    }
    CollectiveTiming {
        start,
        end: sim.now(),
        sent_per_worker: sent,
    }
}

/// Parameter-server push/pull: all workers push their payload to the
/// leader (worker 0), which reduces and broadcasts `result_bytes` back.
pub fn ps_pushpull(
    sim: &mut NetSim,
    payload_bytes: &[u64],
    result_bytes: u64,
) -> CollectiveTiming {
    let n = sim.topology.n_workers();
    assert_eq!(payload_bytes.len(), n);
    let start = sim.now();
    let mut sent = vec![0u64; n];
    if n == 1 {
        return CollectiveTiming {
            start,
            end: start,
            sent_per_worker: sent,
        };
    }
    // Push phase: workers 1..n → worker 0 (shares worker-0's downlink).
    let pushes: Vec<(usize, usize, u64)> = (1..n)
        .map(|i| (i, 0usize, payload_bytes[i].max(1)))
        .collect();
    sim.phase(&pushes);
    for (i, s) in sent.iter_mut().enumerate().skip(1) {
        *s += payload_bytes[i].max(1);
    }
    // Pull phase: worker 0 → everyone (serialized on worker-0's uplink).
    let pulls: Vec<(usize, usize, u64)> =
        (1..n).map(|i| (0usize, i, result_bytes.max(1))).collect();
    sim.phase(&pulls);
    sent[0] += (n as u64 - 1) * result_bytes.max(1);
    CollectiveTiming {
        start,
        end: sim.now(),
        sent_per_worker: sent,
    }
}

/// Incremental, dependency-driven ring all-gather over multiple payload
/// *buckets* — the transport half of the pipelined gradient exchange
/// ([`crate::coordinator::pipeline_exchange`]).
///
/// Each bucket runs the standard N−1 forwarding phases, but with two
/// relaxations over [`ring_allgather`]:
///
/// - **no phase barrier**: a worker forwards a block as soon as that block
///   has arrived, instead of waiting for the phase's slowest transfer;
/// - **bucket interleaving**: bucket *k+1* may enter the ring (at its
///   `ready` time, i.e. when its compression finishes) while bucket *k* is
///   still in flight — link FIFO queueing serializes them exactly where
///   they truly contend.
///
/// Transfers are scheduled with [`NetSim::transfer_at`] and the public
/// clock only advances at [`StagedAllGather::finish`].
pub struct StagedAllGather {
    start: SimTime,
    sent: Vec<u64>,
    last_arrival: SimTime,
}

impl StagedAllGather {
    pub fn new(sim: &NetSim) -> StagedAllGather {
        let n = sim.topology.n_workers();
        StagedAllGather {
            start: sim.now(),
            sent: vec![0u64; n],
            last_arrival: sim.now(),
        }
    }

    /// Schedule one bucket's full all-gather: every worker's payload for
    /// this bucket becomes available at `ready` (clamped to the collective
    /// start). Returns the time the last block of this bucket arrives.
    pub fn push(&mut self, sim: &mut NetSim, ready: SimTime, payload_bytes: &[u64]) -> SimTime {
        let n = sim.topology.n_workers();
        assert_eq!(payload_bytes.len(), n, "payload per worker required");
        let ready = ready.max(self.start);
        if n == 1 {
            self.last_arrival = self.last_arrival.max(ready);
            return ready;
        }
        // avail[i]: when worker i's next block-to-forward is in hand. In
        // phase p worker i forwards the block that originated at
        // (i + n − p) % n, which it received from its predecessor in phase
        // p − 1 (its own payload for p = 0).
        let mut avail = vec![ready; n];
        let mut done = ready;
        for p in 0..(n - 1) {
            let mut next_avail = vec![SimTime::ZERO; n];
            for i in 0..n {
                let origin = (i + n - p) % n;
                let bytes = payload_bytes[origin].max(1);
                let r = sim.transfer_at(i, (i + 1) % n, bytes, avail[i]);
                self.sent[i] += bytes;
                next_avail[(i + 1) % n] = r.arrival;
                done = done.max(r.arrival);
            }
            avail = next_avail;
        }
        self.last_arrival = self.last_arrival.max(done);
        done
    }

    /// Advance the clock past the last arrival and report the timing.
    pub fn finish(self, sim: &mut NetSim) -> CollectiveTiming {
        if self.last_arrival > sim.now() {
            sim.advance_to(self.last_arrival);
        }
        CollectiveTiming {
            start: self.start,
            end: self.last_arrival.max(self.start),
            sent_per_worker: self.sent,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;

    fn sim(n: usize, bw_mbps: f64, prop_ms: u64) -> NetSim {
        NetSim::quiet(StarTopology::constant(
            n,
            mbps(bw_mbps),
            SimTime::from_millis(prop_ms),
        ))
    }

    #[test]
    fn allreduce_volume_is_2_n_minus_1_chunks() {
        let mut s = sim(4, 1000.0, 1);
        let t = ring_allreduce(&mut s, 4_000_000);
        // chunk = 1 MB; 2·3 phases → 6 MB per worker
        assert_eq!(t.sent_per_worker, vec![6_000_000; 4]);
    }

    #[test]
    fn allreduce_time_scales_with_bottleneck() {
        // Halving bandwidth should ~double the makespan.
        let t_fast = ring_allreduce(&mut sim(4, 1000.0, 1), 8_000_000).elapsed();
        let t_slow = ring_allreduce(&mut sim(4, 500.0, 1), 8_000_000).elapsed();
        let ratio = t_slow.as_secs_f64() / t_fast.as_secs_f64();
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn allreduce_single_worker_is_free() {
        let mut s = sim(1, 100.0, 1);
        let t = ring_allreduce(&mut s, 1_000_000);
        assert_eq!(t.elapsed(), SimTime::ZERO);
    }

    #[test]
    fn allgather_moves_every_payload_to_every_worker() {
        let mut s = sim(3, 1000.0, 1);
        let payloads = vec![300_000u64, 600_000, 900_000];
        let t = ring_allgather(&mut s, &payloads);
        // Each worker forwards each origin's block exactly once (n−1
        // sends), so total wire volume = (n−1) × Σ payloads.
        assert_eq!(t.total_sent(), 2 * (300_000 + 600_000 + 900_000));
    }

    #[test]
    fn allgather_makespan_bounded_by_sum_over_bottleneck() {
        let mut s = sim(4, 100.0, 1);
        let payloads = vec![1_000_000u64; 4];
        let t = ring_allgather(&mut s, &payloads);
        // Lower bound: each worker must move 3 MB through a 100 Mbps
        // uplink+downlink pipeline → ≥ 0.24 s. Upper bound: generous 4×.
        let el = t.elapsed().as_secs_f64();
        assert!(el >= 0.24, "{el}");
        assert!(el <= 1.0, "{el}");
    }

    #[test]
    fn slow_worker_gates_the_ring() {
        use crate::netsim::link::LinkConfig;
        use crate::netsim::schedule::BandwidthSchedule;
        let fast = LinkConfig::new(BandwidthSchedule::constant(mbps(10_000.0)), SimTime::ZERO);
        let slow = LinkConfig::new(BandwidthSchedule::constant(mbps(200.0)), SimTime::ZERO);
        let topo = StarTopology::shaped(8, fast.clone(), &[3], slow);
        let mut s = NetSim::quiet(topo);
        let t = ring_allreduce(&mut s, 46_200_000); // ResNet18 gradients
        // Slow-worker bound: 2·7 phases × 5.775 MB chunk... chunk goes
        // through worker 3's 200 Mbps uplink once per phase: 14 × 5.775 MB
        // × 8 / 200 Mbps ≈ 3.2 s (downlink pipelines with uplink).
        let el = t.elapsed().as_secs_f64();
        assert!(el > 2.5, "too fast: {el}");
        // All-fast ring would take ~0.13 s.
        let mut s_fast = NetSim::quiet(StarTopology::uniform(8, fast));
        let el_fast = ring_allreduce(&mut s_fast, 46_200_000).elapsed().as_secs_f64();
        assert!(el > 10.0 * el_fast, "shaping had no effect: {el} vs {el_fast}");
    }

    #[test]
    fn ps_pushpull_serializes_on_leader_links() {
        let mut s = sim(4, 100.0, 1);
        let t = ps_pushpull(&mut s, &[0, 1_000_000, 1_000_000, 1_000_000], 1_000_000);
        // Push: 3 MB into worker-0 downlink (240 ms) then pull: 3 MB out of
        // worker-0 uplink (240 ms).
        let el = t.elapsed().as_secs_f64();
        assert!(el >= 0.45, "{el}");
        assert_eq!(t.sent_per_worker[0], 3_000_000);
    }

    #[test]
    fn staged_single_bucket_matches_barriered_allgather_when_uniform() {
        // Equal payloads on identical links: every phase's transfers finish
        // together, so removing the barrier changes nothing.
        let payloads = vec![1_000_000u64; 4];
        let mut s1 = sim(4, 100.0, 1);
        let barriered = ring_allgather(&mut s1, &payloads);
        let mut s2 = sim(4, 100.0, 1);
        let mut sag = StagedAllGather::new(&s2);
        sag.push(&mut s2, SimTime::ZERO, &payloads);
        let staged = sag.finish(&mut s2);
        assert_eq!(staged.end, barriered.end);
        assert_eq!(staged.sent_per_worker, barriered.sent_per_worker);
        assert_eq!(s2.now(), staged.end);
    }

    #[test]
    fn staged_is_no_slower_than_barriered_on_mixed_payloads() {
        let payloads = vec![200_000u64, 1_000_000, 50_000, 600_000];
        let mut s1 = sim(4, 100.0, 2);
        let barriered = ring_allgather(&mut s1, &payloads);
        let mut s2 = sim(4, 100.0, 2);
        let mut sag = StagedAllGather::new(&s2);
        sag.push(&mut s2, SimTime::ZERO, &payloads);
        let staged = sag.finish(&mut s2);
        assert!(staged.end <= barriered.end, "{} > {}", staged.end, barriered.end);
        assert_eq!(staged.total_sent(), barriered.total_sent());
    }

    #[test]
    fn staged_buckets_interleave_with_staggered_ready_times() {
        // Two buckets whose ready times are staggered by a compression
        // delay: the total must beat the fully serialized schedule
        // (wait-for-compression → send → wait → send).
        let n = 4;
        let bucket = vec![1_000_000u64; n];
        let compress = SimTime::from_millis(120);

        let mut s_pipe = sim(n, 100.0, 1);
        let mut sag = StagedAllGather::new(&s_pipe);
        sag.push(&mut s_pipe, compress, &bucket);
        sag.push(&mut s_pipe, compress + compress, &bucket);
        let pipe = sag.finish(&mut s_pipe);

        let mut s_serial = sim(n, 100.0, 1);
        s_serial.advance_by(compress);
        let t1 = ring_allgather(&mut s_serial, &bucket);
        s_serial.advance_to(t1.end.max(s_serial.now()) + compress);
        let serial = ring_allgather(&mut s_serial, &bucket);

        assert!(
            pipe.end < serial.end,
            "pipelined {} not faster than serialized {}",
            pipe.end,
            serial.end
        );
        assert_eq!(pipe.total_sent(), t1.total_sent() + serial.total_sent());
    }

    #[test]
    fn staged_single_worker_is_free() {
        let mut s = sim(1, 100.0, 1);
        let mut sag = StagedAllGather::new(&s);
        let done = sag.push(&mut s, SimTime::from_millis(5), &[1_000_000]);
        assert_eq!(done, SimTime::from_millis(5));
        let t = sag.finish(&mut s);
        assert_eq!(t.sent_per_worker, vec![0]);
    }

    #[test]
    fn degenerate_zero_payloads() {
        let mut s = sim(3, 100.0, 1);
        let t = ring_allgather(&mut s, &[0, 0, 0]);
        assert!(t.elapsed() > SimTime::ZERO); // 1-byte floors still move
        let t = ps_pushpull(&mut s, &[0, 0, 0], 0);
        assert!(t.elapsed() > SimTime::ZERO);
    }
}
