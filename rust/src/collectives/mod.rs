//! Collective communication over the simulated star network — the NCCL
//! stand-in (DESIGN.md §2). Each collective has two halves:
//!
//! - a **timing** half that schedules the constituent point-to-point
//!   transfers on [`crate::netsim::NetSim`] and reports the makespan, and
//! - a **numeric** half ([`numeric`]) that actually reduces the gradient
//!   buffers, so training results are real, not modeled.
//!
//! Patterns (paper §5.3): dense gradients ride a **ring all-reduce**
//! (NCCL's default; 2(N−1)/N × bytes per worker on the wire); sparse
//! (Top-K / NetSenseML) payloads ride a **ring all-gather** (the paper
//! notes "the use of the AllGather communication pattern by TopK"), and a
//! **parameter-server** push/pull is provided for ablations. Bucketed
//! payloads ride [`StagedAllGather`], the barrier-free all-gather that lets
//! the pipelined exchange interleave per-bucket transfers in the event
//! loop.

pub mod numeric;
pub mod patterns;

pub use numeric::{mean_dense, sum_dense, sum_sparse};
pub use patterns::{
    ps_pushpull, ring_allgather, ring_allreduce, CollectiveTiming, StagedAllGather,
};
