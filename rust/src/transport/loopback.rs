//! In-process loopback transport: a full mesh of unbounded channels, one
//! per ordered peer pair. Deterministic delivery order per peer, no
//! sockets, no sleeps — the reference implementation tests and benches
//! compare the real backends against. Payloads still travel as encoded
//! frames so the codec path is identical to TCP's.

use super::frame::{decode_frame_into, encode_frame};
use super::{Transport, TransferObs};
use crate::util::error::{anyhow, Result};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// One rank's endpoint of an in-process mesh (see
/// [`LoopbackTransport::mesh`]).
pub struct LoopbackTransport {
    rank: usize,
    n: usize,
    /// `txs[to]`: channel into peer `to`'s inbox for frames from us.
    txs: Vec<Option<Sender<Vec<u8>>>>,
    /// `rxs[from]`: our inbox for frames from peer `from`.
    rxs: Vec<Option<Receiver<Vec<u8>>>>,
    obs: Vec<TransferObs>,
    timeout: Duration,
}

impl LoopbackTransport {
    /// Build a fully connected group of `n` endpoints. Hand one to each
    /// worker thread (endpoints are `Send`, not `Sync`).
    pub fn mesh(n: usize) -> Vec<LoopbackTransport> {
        assert!(n >= 1, "empty group");
        // pairs[from][to]: (sender kept by `from`, receiver kept by `to`).
        let mut endpoints: Vec<LoopbackTransport> = (0..n)
            .map(|rank| LoopbackTransport {
                rank,
                n,
                txs: (0..n).map(|_| None).collect(),
                rxs: (0..n).map(|_| None).collect(),
                obs: Vec::new(),
                timeout: Duration::from_secs(30),
            })
            .collect();
        for from in 0..n {
            for to in 0..n {
                if from == to {
                    continue;
                }
                let (tx, rx) = channel();
                endpoints[from].txs[to] = Some(tx);
                endpoints[to].rxs[from] = Some(rx);
            }
        }
        endpoints
    }

    /// Replace the blocking-recv timeout (default 30 s).
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

impl Transport for LoopbackTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn group_size(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        if to >= self.n || to == self.rank {
            return Err(anyhow!("bad destination rank {to} (self is {})", self.rank));
        }
        let t0 = Instant::now();
        let frame = encode_frame(payload);
        let bytes = frame.len() as u64;
        self.txs[to]
            .as_ref()
            .ok_or_else(|| anyhow!("transport shut down"))?
            .send(frame)
            .map_err(|_| anyhow!("peer {to} hung up"))?;
        self.obs.push(TransferObs {
            bytes,
            elapsed: t0.elapsed(),
        });
        Ok(())
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.recv_into(from, &mut buf)?;
        Ok(buf)
    }

    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        if from >= self.n || from == self.rank {
            return Err(anyhow!("bad source rank {from} (self is {})", self.rank));
        }
        let rx = self.rxs[from]
            .as_ref()
            .ok_or_else(|| anyhow!("transport shut down"))?;
        let frame = match rx.recv_timeout(self.timeout) {
            Ok(f) => f,
            Err(RecvTimeoutError::Timeout) => {
                return Err(anyhow!("recv from rank {from} timed out"));
            }
            Err(RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("peer {from} shut down"));
            }
        };
        // Decode straight into the caller's buffer: the receiving thread
        // performs no allocation (the sender paid for the frame).
        decode_frame_into(&frame, buf)
    }

    fn take_observations(&mut self) -> Vec<TransferObs> {
        std::mem::take(&mut self.obs)
    }

    fn set_recv_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn shutdown(&mut self) -> Result<()> {
        // Dropping the senders signals Disconnected to peers still waiting.
        for tx in self.txs.iter_mut() {
            *tx = None;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_send_recv() {
        let mut mesh = LoopbackTransport::mesh(3);
        let mut c = mesh.pop().unwrap();
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        assert_eq!((a.rank(), b.rank(), c.rank()), (0, 1, 2));
        assert_eq!(a.group_size(), 3);
        a.send(1, b"zero to one").unwrap();
        a.send(2, b"zero to two").unwrap();
        c.send(1, b"two to one").unwrap();
        assert_eq!(b.recv(0).unwrap(), b"zero to one");
        assert_eq!(b.recv(2).unwrap(), b"two to one");
        assert_eq!(c.recv(0).unwrap(), b"zero to two");
    }

    #[test]
    fn per_peer_fifo_order() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        for i in 0..10u8 {
            a.send(1, &[i]).unwrap();
        }
        for i in 0..10u8 {
            assert_eq!(b.recv(0).unwrap(), vec![i]);
        }
    }

    #[test]
    fn recv_into_reuses_buffer_and_matches_recv() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut b = mesh.pop().unwrap();
        let mut a = mesh.pop().unwrap();
        a.send(1, &[5u8; 128]).unwrap();
        a.send(1, &[6u8; 32]).unwrap();
        let mut buf = Vec::new();
        b.recv_into(0, &mut buf).unwrap();
        assert_eq!(buf, vec![5u8; 128]);
        let ptr = buf.as_ptr();
        b.recv_into(0, &mut buf).unwrap();
        assert_eq!(buf, vec![6u8; 32]);
        assert!(std::ptr::eq(buf.as_ptr(), ptr), "smaller frame must not realloc");
        // Same validation as recv: bad ranks rejected.
        assert!(b.recv_into(1, &mut buf).is_err());
        assert!(b.recv_into(9, &mut buf).is_err());
    }

    #[test]
    fn observations_record_frame_bytes() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut a = mesh.remove(0);
        a.send(1, &[0u8; 100]).unwrap();
        a.send(1, &[0u8; 50]).unwrap();
        let obs = a.take_observations();
        assert_eq!(obs.len(), 2);
        assert_eq!(obs[0].bytes, 100 + super::super::FRAME_OVERHEAD);
        assert_eq!(obs[1].bytes, 50 + super::super::FRAME_OVERHEAD);
        assert!(a.take_observations().is_empty(), "drained");
    }

    #[test]
    fn recv_times_out_instead_of_hanging() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut a = mesh.remove(0).with_timeout(Duration::from_millis(20));
        let e = a.recv(1).unwrap_err();
        assert!(format!("{e}").contains("timed out"), "{e}");
    }

    #[test]
    fn set_recv_timeout_applies_at_runtime() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut a = mesh.remove(0);
        a.set_recv_timeout(Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(a.recv(1).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "runtime deadline ignored");
    }

    #[test]
    fn shutdown_surfaces_as_peer_error() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut b = mesh.pop().unwrap().with_timeout(Duration::from_secs(5));
        let mut a = mesh.pop().unwrap();
        a.shutdown().unwrap();
        drop(a);
        let e = b.recv(0).unwrap_err();
        assert!(format!("{e}").contains("shut down"), "{e}");
    }

    #[test]
    fn self_and_out_of_range_ranks_rejected() {
        let mut mesh = LoopbackTransport::mesh(2);
        let mut a = mesh.remove(0);
        assert!(a.send(0, b"x").is_err());
        assert!(a.send(7, b"x").is_err());
        assert!(a.recv(0).is_err());
    }
}
