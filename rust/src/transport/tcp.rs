//! Real-socket transport: a full TCP mesh between `world` ranks on
//! `std::net` only.
//!
//! Bootstrap (rank-0 rendezvous, the usual distributed-training shape):
//!
//! 1. rank 0 binds the rendezvous address plus a data listener on the
//!    same interface;
//! 2. ranks 1..N connect to the rendezvous, bind a data listener on the
//!    local interface that connection uses (reachable by construction,
//!    also cross-host), and send a `hello <rank> <data_addr>` frame;
//! 3. rank 0 replies to everyone with the address book
//!    (`book <addr0> <addr1> …`);
//! 4. rank *i* dials the data listener of every rank *j < i* (identifying
//!    itself with a `peer <rank>` frame) and accepts connections from every
//!    rank *k > i* — one duplex `TcpStream` per unordered pair.
//!
//! Each peer connection gets a reader thread that turns the byte stream
//! back into frames and parks them in a per-peer inbox; `send` writes
//! frames directly on the socket (with `TCP_NODELAY`, so small control
//! frames don't sit in Nagle buffers). A read error — peer crash, reset,
//! or graceful EOF — is pushed into the inbox as an `Err` observation
//! before the reader exits, so a blocked `recv` surfaces the disconnect
//! immediately instead of silently waiting out its full timeout (the
//! failure detector in [`crate::fault`] feeds on exactly this signal).
//! Shutdown closes the sockets, which lands reader threads on
//! `UnexpectedEof`, and joins them.

use super::frame::{read_frame, read_frame_into, write_frame, FRAME_OVERHEAD};
use super::{Transport, TransferObs};
use crate::util::error::{anyhow, Context, Result};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long to keep retrying a bootstrap connect (peers start in any
/// order).
const CONNECT_RETRY_FOR: Duration = Duration::from_secs(10);
const CONNECT_RETRY_EVERY: Duration = Duration::from_millis(10);
/// How long rendezvous/mesh accepts wait for the missing peers before the
/// bootstrap errors out (a crashed worker must not hang the run).
const ACCEPT_FOR: Duration = Duration::from_secs(30);

/// What a reader thread parks in the inbox: a frame, or the read error
/// that ended the connection (stringly — the reader can't share the
/// non-`Send`-safe error machinery across the channel).
type InboxItem = std::result::Result<Vec<u8>, String>;

/// A rank's endpoint of the TCP mesh.
pub struct TcpTransport {
    rank: usize,
    n: usize,
    /// `peers[j]`: write side of the connection to rank `j`.
    peers: Vec<Option<TcpStream>>,
    /// `inbox[j]`: frames read off the connection to rank `j`.
    inbox: Vec<Option<Receiver<InboxItem>>>,
    /// `recycle[j]`: return path handing spent payload buffers back to
    /// rank `j`'s reader thread, which refills them in place
    /// ([`read_frame_into`]) instead of allocating a fresh `Vec` per
    /// frame. Fed by [`Transport::recv_into`]; the owning
    /// [`Transport::recv`] path hands the buffer to the caller and skips
    /// the recycle.
    recycle: Vec<Option<Sender<Vec<u8>>>>,
    readers: Vec<JoinHandle<()>>,
    obs: Vec<TransferObs>,
    timeout: Duration,
    down: bool,
}

impl TcpTransport {
    /// Bind the rendezvous listener (rank 0 calls this first; its
    /// `local_addr()` is what the other ranks dial — bind port 0 to let
    /// the OS pick).
    pub fn bind_rendezvous(addr: &str) -> Result<TcpListener> {
        TcpListener::bind(addr).with_context(|| format!("binding rendezvous {addr}"))
    }

    /// Rank 0: run the rendezvous on an already-bound listener, then build
    /// the mesh.
    pub fn host(rendezvous: TcpListener, world: usize) -> Result<TcpTransport> {
        assert!(world >= 1);
        let data_listener = ephemeral_listener(&rendezvous)?;
        let mut book: Vec<Option<String>> = vec![None; world];
        book[0] = Some(data_listener.local_addr()?.to_string());
        let mut hellos: Vec<(usize, TcpStream)> = Vec::with_capacity(world - 1);
        for _ in 1..world {
            let mut conn = accept_with_deadline(&rendezvous, ACCEPT_FOR)
                .context("accepting rendezvous")?;
            conn.set_nodelay(true).ok();
            let hello = String::from_utf8(read_frame(&mut conn)?)
                .map_err(|_| anyhow!("non-utf8 hello"))?;
            let mut parts = hello.split_whitespace();
            let (tag, rank, addr) = (parts.next(), parts.next(), parts.next());
            if tag != Some("hello") {
                return Err(anyhow!("bad rendezvous greeting `{hello}`"));
            }
            let rank: usize = rank
                .and_then(|r| r.parse().ok())
                .context("unparsable hello rank")?;
            let addr = addr.context("hello missing data addr")?;
            if rank == 0 || rank >= world || book[rank].is_some() {
                return Err(anyhow!("duplicate or out-of-range hello rank {rank}"));
            }
            book[rank] = Some(addr.to_string());
            hellos.push((rank, conn));
        }
        let book: Vec<String> = book.into_iter().map(|a| a.unwrap()).collect();
        let book_frame = format!("book {}", book.join(" "));
        for (_, mut conn) in hellos {
            write_frame(&mut conn, book_frame.as_bytes())?;
        }
        Self::mesh(0, world, &book, data_listener)
    }

    /// Ranks 1..world: dial the rendezvous at `addr`, then build the mesh.
    pub fn join(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
        assert!(rank >= 1 && rank < world, "join is for ranks 1..world");
        let mut conn = connect_retry(addr)?;
        conn.set_nodelay(true).ok();
        // Bind the data listener on OUR side of the rendezvous connection —
        // the one local interface rank 0 (and, on a shared network, every
        // peer) can reach; binding the rendezvous *host's* IP would fail on
        // any multi-machine run.
        let local_ip = conn.local_addr()?.ip();
        let data_listener =
            TcpListener::bind((local_ip, 0)).context("binding data listener")?;
        let hello = format!("hello {rank} {}", data_listener.local_addr()?);
        write_frame(&mut conn, hello.as_bytes())?;
        let book = String::from_utf8(read_frame(&mut conn)?)
            .map_err(|_| anyhow!("non-utf8 book"))?;
        let mut parts = book.split_whitespace();
        if parts.next() != Some("book") {
            return Err(anyhow!("bad rendezvous reply `{book}`"));
        }
        let mut book: Vec<String> = parts.map(str::to_string).collect();
        if book.len() != world {
            return Err(anyhow!("address book has {} entries, want {world}", book.len()));
        }
        // Rank 0 advertises its data listener's bind IP; a wildcard bind
        // (0.0.0.0 / ::) is not routable from here — substitute the host
        // we actually reached over this rendezvous connection.
        if let Ok(sa) = book[0].parse::<std::net::SocketAddr>() {
            if sa.ip().is_unspecified() {
                let reach = conn.peer_addr()?.ip();
                book[0] = std::net::SocketAddr::new(reach, sa.port()).to_string();
            }
        }
        Self::mesh(rank, world, &book, data_listener)
    }

    /// Convenience: rank 0 hosts at `addr`, other ranks join it.
    pub fn connect(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
        if rank == 0 {
            Self::host(Self::bind_rendezvous(addr)?, world)
        } else {
            Self::join(addr, rank, world)
        }
    }

    /// Dial lower ranks, accept higher ranks, wire up reader threads.
    fn mesh(
        rank: usize,
        world: usize,
        book: &[String],
        data_listener: TcpListener,
    ) -> Result<TcpTransport> {
        let mut peers: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
        for (j, addr) in book.iter().enumerate().take(rank) {
            let mut s = connect_retry(addr)
                .with_context(|| format!("rank {rank} dialing peer {j} at {addr}"))?;
            s.set_nodelay(true).ok();
            write_frame(&mut s, format!("peer {rank}").as_bytes())?;
            peers[j] = Some(s);
        }
        for _ in rank + 1..world {
            let mut s = accept_with_deadline(&data_listener, ACCEPT_FOR)
                .with_context(|| format!("rank {rank} accepting peer"))?;
            s.set_nodelay(true).ok();
            let id = String::from_utf8(read_frame(&mut s)?)
                .map_err(|_| anyhow!("non-utf8 peer id"))?;
            let k: usize = id
                .strip_prefix("peer ")
                .and_then(|r| r.trim().parse().ok())
                .with_context(|| format!("bad peer id `{id}`"))?;
            if k <= rank || k >= world || peers[k].is_some() {
                return Err(anyhow!("duplicate or out-of-range peer {k}"));
            }
            peers[k] = Some(s);
        }
        let mut inbox: Vec<Option<Receiver<InboxItem>>> = (0..world).map(|_| None).collect();
        let mut recycle: Vec<Option<Sender<Vec<u8>>>> = (0..world).map(|_| None).collect();
        let mut readers = Vec::new();
        for (j, peer) in peers.iter().enumerate() {
            let Some(s) = peer else { continue };
            let (tx, rx) = channel();
            let (pool_tx, pool_rx) = channel();
            inbox[j] = Some(rx);
            recycle[j] = Some(pool_tx);
            let reader = s.try_clone().context("cloning stream for reader")?;
            readers.push(std::thread::spawn(move || reader_loop(reader, tx, pool_rx)));
        }
        Ok(TcpTransport {
            rank,
            n: world,
            peers,
            inbox,
            recycle,
            readers,
            obs: Vec::new(),
            timeout: Duration::from_secs(30),
            down: false,
        })
    }

    /// Replace the blocking-recv timeout (default 30 s).
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

/// Reader half of one peer connection: frames → inbox until EOF/close.
/// The terminating error is itself delivered as an observation — a
/// receiver blocked on this peer learns of the disconnect immediately
/// instead of parking until its timeout expires.
///
/// Buffers recycle: each frame is read into a spent payload `Vec` the
/// endpoint handed back through `pool` (capacity intact), so a receiver
/// that drains with [`Transport::recv_into`] keeps the reader thread
/// allocation-free per frame in steady state.
fn reader_loop(mut stream: TcpStream, tx: Sender<InboxItem>, pool: Receiver<Vec<u8>>) {
    loop {
        let mut buf = pool.try_recv().unwrap_or_default();
        match read_frame_into(&mut stream, &mut buf) {
            Ok(()) => {
                if tx.send(Ok(buf)).is_err() {
                    return; // endpoint dropped
                }
            }
            Err(e) => {
                // EOF (graceful close) or connection error: surface it,
                // then exit. Failure to send means the endpoint is gone
                // and nobody is listening anyway.
                let _ = tx.send(Err(e.to_string()));
                return;
            }
        }
    }
}

/// Bind a data listener on the same interface as the rendezvous listener.
fn ephemeral_listener(like: &TcpListener) -> Result<TcpListener> {
    let ip = like.local_addr()?.ip();
    TcpListener::bind((ip, 0)).context("binding data listener")
}


/// Accept one connection within `deadline`, or error — `std::net` has no
/// native accept timeout, so poll in nonblocking mode. The listener is
/// restored to blocking mode before returning.
fn accept_with_deadline(listener: &TcpListener, deadline: Duration) -> Result<TcpStream> {
    listener.set_nonblocking(true)?;
    let until = Instant::now() + deadline;
    let result = loop {
        match listener.accept() {
            Ok((s, _)) => {
                // Some platforms hand the accepted socket the listener's
                // nonblocking flag; the frame reader needs blocking reads.
                s.set_nonblocking(false)?;
                break Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= until {
                    break Err(anyhow!(
                        "no peer connected within {:.0}s",
                        deadline.as_secs_f64()
                    ));
                }
                std::thread::sleep(CONNECT_RETRY_EVERY);
            }
            Err(e) => break Err(e.into()),
        }
    };
    listener.set_nonblocking(false)?;
    result
}

fn connect_retry(addr: &str) -> Result<TcpStream> {
    connect_retry_for(addr, CONNECT_RETRY_FOR)
}

fn connect_retry_for(addr: &str, window: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!("connecting {addr}: {e}"));
            }
            Err(_) => std::thread::sleep(CONNECT_RETRY_EVERY),
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn group_size(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        if to >= self.n || to == self.rank {
            return Err(anyhow!("bad destination rank {to} (self is {})", self.rank));
        }
        let stream = self.peers[to]
            .as_mut()
            .with_context(|| format!("connection to rank {to} closed"))?;
        let t0 = Instant::now();
        write_frame(stream, payload).with_context(|| format!("sending to rank {to}"))?;
        self.obs.push(TransferObs {
            bytes: payload.len() as u64 + FRAME_OVERHEAD,
            elapsed: t0.elapsed(),
        });
        Ok(())
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        // Delegate so the validation and error mapping live once; the
        // fresh Vec swaps with the reader's filled buffer in recv_into
        // (the empty spent buffer going back to the pool is harmless).
        let mut buf = Vec::new();
        self.recv_into(from, &mut buf)?;
        Ok(buf)
    }

    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        if from >= self.n || from == self.rank {
            return Err(anyhow!("bad source rank {from} (self is {})", self.rank));
        }
        let rx = self.inbox[from]
            .as_ref()
            .with_context(|| format!("connection to rank {from} closed"))?;
        match rx.recv_timeout(self.timeout) {
            Ok(Ok(mut payload)) => {
                // Swap, don't copy: the caller gets the reader-filled
                // buffer, and the caller's spent buffer (capacity intact)
                // goes back to the reader thread for a later frame —
                // steady state moves payloads with no copy and no
                // allocation on either side of the inbox.
                std::mem::swap(buf, &mut payload);
                if let Some(pool) = self.recycle[from].as_ref() {
                    let _ = pool.send(payload);
                }
                Ok(())
            }
            Ok(Err(e)) => Err(anyhow!("peer {from} disconnected: {e}")),
            Err(RecvTimeoutError::Timeout) => Err(anyhow!("recv from rank {from} timed out")),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow!("peer {from} closed")),
        }
    }

    fn take_observations(&mut self) -> Vec<TransferObs> {
        std::mem::take(&mut self.obs)
    }

    fn set_recv_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn shutdown(&mut self) -> Result<()> {
        if self.down {
            return Ok(());
        }
        self.down = true;
        for peer in self.peers.iter_mut() {
            if let Some(s) = peer.take() {
                s.shutdown(Shutdown::Both).ok();
            }
        }
        self.inbox.iter_mut().for_each(|r| *r = None);
        self.recycle.iter_mut().for_each(|r| *r = None);
        for h in self.readers.drain(..) {
            h.join().map_err(|_| anyhow!("reader thread panicked"))?;
        }
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Spin up a localhost mesh of `world` ranks, run `f` on each rank in
    /// its own thread, and collect the outputs in rank order.
    pub(crate) fn with_mesh<T: Send + 'static>(
        world: usize,
        f: impl Fn(TcpTransport) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let rendezvous = TcpTransport::bind_rendezvous("127.0.0.1:0").unwrap();
        let addr = rendezvous.local_addr().unwrap().to_string();
        let f = std::sync::Arc::new(f);
        let mut handles = Vec::new();
        for rank in 1..world {
            let addr = addr.clone();
            let f = f.clone();
            handles.push(std::thread::spawn(move || {
                let t = TcpTransport::join(&addr, rank, world)
                    .unwrap()
                    .with_timeout(Duration::from_secs(10));
                f(t)
            }));
        }
        let t0 = TcpTransport::host(rendezvous, world)
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let mut out = vec![f(t0)];
        for h in handles {
            out.push(h.join().expect("worker thread panicked"));
        }
        out
    }

    #[test]
    fn two_rank_exchange_over_localhost() {
        let out = with_mesh(2, |mut t| {
            let peer = 1 - t.rank();
            t.send(peer, format!("from {}", t.rank()).as_bytes())
                .unwrap();
            let got = t.recv(peer).unwrap();
            t.shutdown().unwrap();
            (t.rank(), got)
        });
        assert_eq!(out[0], (0, b"from 1".to_vec()));
        assert_eq!(out[1], (1, b"from 0".to_vec()));
    }

    #[test]
    fn four_rank_mesh_all_pairs() {
        let out = with_mesh(4, |mut t| {
            let me = t.rank();
            for p in 0..4 {
                if p != me {
                    t.send(p, &[me as u8, p as u8]).unwrap();
                }
            }
            let mut got = Vec::new();
            for p in 0..4 {
                if p != me {
                    got.push(t.recv(p).unwrap());
                }
            }
            t.shutdown().unwrap();
            got
        });
        for (me, got) in out.iter().enumerate() {
            let peers: Vec<usize> = (0..4).filter(|&p| p != me).collect();
            for (g, &p) in got.iter().zip(&peers) {
                assert_eq!(g, &vec![p as u8, me as u8]);
            }
        }
    }

    /// The recycled receive path: repeated `recv_into` over one
    /// connection keeps frames intact while inbox buffers rotate back
    /// through the reader thread's pool.
    #[test]
    fn recv_into_recycles_inbox_buffers_without_corruption() {
        let rounds = 16usize;
        let out = with_mesh(2, move |mut t| {
            let peer = 1 - t.rank();
            let mut buf = Vec::new();
            let mut ok = true;
            for i in 0..rounds {
                // Alternate sizes so recycled buffers shrink and regrow.
                let len = if i % 2 == 0 { 4096 } else { 64 };
                t.send(peer, &vec![i as u8; len]).unwrap();
                t.recv_into(peer, &mut buf).unwrap();
                ok &= buf == vec![i as u8; len];
            }
            t.shutdown().unwrap();
            ok
        });
        assert!(out.iter().all(|&ok| ok), "recycled buffers corrupted a frame");
    }

    #[test]
    fn observations_cover_sent_frames() {
        let out = with_mesh(2, |mut t| {
            let peer = 1 - t.rank();
            t.send(peer, &[0u8; 1000]).unwrap();
            t.recv(peer).unwrap();
            let obs = t.take_observations();
            t.shutdown().unwrap();
            obs
        });
        for obs in &out {
            assert_eq!(obs.len(), 1);
            assert_eq!(obs[0].bytes, 1000 + FRAME_OVERHEAD);
        }
    }

    #[test]
    fn shutdown_is_idempotent_and_recv_after_fails() {
        let out = with_mesh(2, |mut t| {
            t.shutdown().unwrap();
            t.shutdown().unwrap();
            t.recv(1 - t.rank()).is_err()
        });
        assert!(out.iter().all(|&failed| failed));
    }

    /// Satellite fix: a peer crash/close must surface as an `Err`
    /// observation the moment the reader thread sees it — not as a
    /// silent park until the receiver's full timeout expires.
    #[test]
    fn peer_disconnect_surfaces_immediately_not_after_timeout() {
        let out = with_mesh(2, |mut t| {
            if t.rank() == 1 {
                t.shutdown().unwrap();
                (Duration::ZERO, String::new())
            } else {
                let t0 = Instant::now();
                let e = t.recv(1).unwrap_err();
                let waited = t0.elapsed();
                t.shutdown().unwrap();
                (waited, format!("{e}"))
            }
        });
        let (waited, msg) = &out[0];
        assert!(
            msg.contains("disconnected") || msg.contains("closed"),
            "unexpected error: {msg}"
        );
        // The mesh timeout is 10 s; the disconnect must beat it by far.
        assert!(
            *waited < Duration::from_secs(5),
            "recv parked for {waited:?} instead of observing the disconnect"
        );
    }

    #[test]
    fn set_recv_timeout_applies_at_runtime() {
        let out = with_mesh(2, |mut t| {
            if t.rank() == 0 {
                t.set_recv_timeout(Duration::from_millis(30));
                let t0 = Instant::now();
                let e = t.recv(1).unwrap_err();
                let waited = t0.elapsed();
                assert!(format!("{e}").contains("timed out"), "{e}");
                t.shutdown().unwrap();
                waited < Duration::from_secs(2)
            } else {
                // Keep the peer alive (no frames, no close) past the
                // other side's shortened deadline.
                std::thread::sleep(Duration::from_millis(300));
                t.shutdown().unwrap();
                true
            }
        });
        assert!(out.iter().all(|&ok| ok));
    }

    #[test]
    fn connect_retry_gives_up_with_named_error() {
        // A port nobody listens on: bind-then-drop to find a free one.
        // Exercises the real retry loop with a short window so the test
        // verifies the deadline logic, not a reimplementation.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        let e = connect_retry_for(&addr, Duration::from_millis(80)).unwrap_err();
        assert!(format!("{e}").contains("connecting"), "{e}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "retry window not honored: {:?}",
            t0.elapsed()
        );
    }
}
