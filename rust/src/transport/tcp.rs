//! Real-socket transport: a full TCP mesh between `world` ranks on
//! `std::net` only, multiplexed over the shared event-loop pool in
//! [`crate::util::poller`].
//!
//! Bootstrap (rank-0 rendezvous, the usual distributed-training shape):
//!
//! 1. rank 0 binds the rendezvous address plus a data listener on the
//!    same interface;
//! 2. ranks 1..N connect to the rendezvous, bind a data listener on the
//!    local interface that connection uses (reachable by construction,
//!    also cross-host), and send a `hello <rank> <data_addr>` frame;
//! 3. rank 0 replies to everyone with the address book
//!    (`book <addr0> <addr1> …`);
//! 4. rank *i* dials the data listener of every rank *j < i* (identifying
//!    itself with a `peer <rank>` frame) and accepts connections from every
//!    rank *k > i* — one duplex `TcpStream` per unordered pair.
//!
//! After bootstrap every connection is switched nonblocking and
//! registered with the global [`Poller`]: a fixed pool of event-loop
//! threads owns all reads (incremental frame parsing into pooled
//! buffers), so an N-worker mesh costs the pool size in threads instead
//! of the old reader-thread-per-peer O(N²). `send` stays on the caller's
//! thread as a vectored write — header and payload as two iovecs, no
//! concatenation copy — parking on the poller's write gate only when the
//! kernel buffer is full (with `TCP_NODELAY`, so small control frames
//! don't sit in Nagle buffers). A read error — peer crash, reset, or
//! graceful EOF — marks the connection dead in the event loop and wakes
//! every waiter at once, so a blocked `recv` surfaces the disconnect
//! immediately instead of silently waiting out its full timeout (the
//! failure detector in [`crate::fault`] feeds on exactly this signal).
//! Shutdown closes the sockets; the loops observe EOF and drop the
//! connections — there are no per-transport threads left to join.

use super::frame::{frame_header, read_frame, write_frame, FRAME_OVERHEAD};
use super::{Transport, TransferObs};
use crate::util::error::{anyhow, Context, Result};
use crate::util::poller::{ConnHandle, Poller, RecvError};
use std::io::{IoSlice, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// How long to keep retrying a bootstrap connect (peers start in any
/// order).
const CONNECT_RETRY_FOR: Duration = Duration::from_secs(10);
const CONNECT_RETRY_EVERY: Duration = Duration::from_millis(10);
/// How long rendezvous/mesh accepts wait for the missing peers before the
/// bootstrap errors out (a crashed worker must not hang the run).
const ACCEPT_FOR: Duration = Duration::from_secs(30);

/// A rank's endpoint of the TCP mesh.
pub struct TcpTransport {
    rank: usize,
    n: usize,
    /// `peers[j]`: write side of the (nonblocking) connection to rank `j`.
    peers: Vec<Option<TcpStream>>,
    /// `conns[j]`: the poller-side handle for rank `j`'s connection —
    /// completed inbound frames and write-readiness signalling.
    conns: Vec<Option<ConnHandle>>,
    obs: Vec<TransferObs>,
    timeout: Duration,
    /// Nanoseconds this endpoint spent blocked on the wire since the last
    /// [`Transport::take_wire_wait_ns`] — recv waits plus send
    /// backpressure stalls (feeds the `evloop` trace span).
    wire_wait_ns: u64,
    down: bool,
}

impl TcpTransport {
    /// Bind the rendezvous listener (rank 0 calls this first; its
    /// `local_addr()` is what the other ranks dial — bind port 0 to let
    /// the OS pick).
    pub fn bind_rendezvous(addr: &str) -> Result<TcpListener> {
        TcpListener::bind(addr).with_context(|| format!("binding rendezvous {addr}"))
    }

    /// Rank 0: run the rendezvous on an already-bound listener, then build
    /// the mesh.
    pub fn host(rendezvous: TcpListener, world: usize) -> Result<TcpTransport> {
        assert!(world >= 1);
        let data_listener = ephemeral_listener(&rendezvous)?;
        let mut book: Vec<Option<String>> = vec![None; world];
        book[0] = Some(data_listener.local_addr()?.to_string());
        let mut hellos: Vec<(usize, TcpStream)> = Vec::with_capacity(world - 1);
        for _ in 1..world {
            let mut conn = accept_with_deadline(&rendezvous, ACCEPT_FOR)
                .context("accepting rendezvous")?;
            conn.set_nodelay(true).ok();
            let hello = String::from_utf8(read_frame(&mut conn)?)
                .map_err(|_| anyhow!("non-utf8 hello"))?;
            let mut parts = hello.split_whitespace();
            let (tag, rank, addr) = (parts.next(), parts.next(), parts.next());
            if tag != Some("hello") {
                return Err(anyhow!("bad rendezvous greeting `{hello}`"));
            }
            let rank: usize = rank
                .and_then(|r| r.parse().ok())
                .context("unparsable hello rank")?;
            let addr = addr.context("hello missing data addr")?;
            if rank == 0 || rank >= world || book[rank].is_some() {
                return Err(anyhow!("duplicate or out-of-range hello rank {rank}"));
            }
            book[rank] = Some(addr.to_string());
            hellos.push((rank, conn));
        }
        let book: Vec<String> = book.into_iter().map(|a| a.unwrap()).collect();
        let book_frame = format!("book {}", book.join(" "));
        for (_, mut conn) in hellos {
            write_frame(&mut conn, book_frame.as_bytes())?;
        }
        Self::mesh(0, world, &book, data_listener)
    }

    /// Ranks 1..world: dial the rendezvous at `addr`, then build the mesh.
    pub fn join(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
        assert!(rank >= 1 && rank < world, "join is for ranks 1..world");
        let mut conn = connect_retry(addr)?;
        conn.set_nodelay(true).ok();
        // Bind the data listener on OUR side of the rendezvous connection —
        // the one local interface rank 0 (and, on a shared network, every
        // peer) can reach; binding the rendezvous *host's* IP would fail on
        // any multi-machine run.
        let local_ip = conn.local_addr()?.ip();
        let data_listener =
            TcpListener::bind((local_ip, 0)).context("binding data listener")?;
        let hello = format!("hello {rank} {}", data_listener.local_addr()?);
        write_frame(&mut conn, hello.as_bytes())?;
        let book = String::from_utf8(read_frame(&mut conn)?)
            .map_err(|_| anyhow!("non-utf8 book"))?;
        let mut parts = book.split_whitespace();
        if parts.next() != Some("book") {
            return Err(anyhow!("bad rendezvous reply `{book}`"));
        }
        let mut book: Vec<String> = parts.map(str::to_string).collect();
        if book.len() != world {
            return Err(anyhow!("address book has {} entries, want {world}", book.len()));
        }
        // Rank 0 advertises its data listener's bind IP; a wildcard bind
        // (0.0.0.0 / ::) is not routable from here — substitute the host
        // we actually reached over this rendezvous connection.
        if let Ok(sa) = book[0].parse::<std::net::SocketAddr>() {
            if sa.ip().is_unspecified() {
                let reach = conn.peer_addr()?.ip();
                book[0] = std::net::SocketAddr::new(reach, sa.port()).to_string();
            }
        }
        Self::mesh(rank, world, &book, data_listener)
    }

    /// Convenience: rank 0 hosts at `addr`, other ranks join it.
    pub fn connect(addr: &str, rank: usize, world: usize) -> Result<TcpTransport> {
        if rank == 0 {
            Self::host(Self::bind_rendezvous(addr)?, world)
        } else {
            Self::join(addr, rank, world)
        }
    }

    /// Dial lower ranks, accept higher ranks, hand every connection to
    /// the event-loop pool.
    fn mesh(
        rank: usize,
        world: usize,
        book: &[String],
        data_listener: TcpListener,
    ) -> Result<TcpTransport> {
        let mut peers: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
        for (j, addr) in book.iter().enumerate().take(rank) {
            let mut s = connect_retry(addr)
                .with_context(|| format!("rank {rank} dialing peer {j} at {addr}"))?;
            s.set_nodelay(true).ok();
            write_frame(&mut s, format!("peer {rank}").as_bytes())?;
            peers[j] = Some(s);
        }
        for _ in rank + 1..world {
            let mut s = accept_with_deadline(&data_listener, ACCEPT_FOR)
                .with_context(|| format!("rank {rank} accepting peer"))?;
            s.set_nodelay(true).ok();
            let id = String::from_utf8(read_frame(&mut s)?)
                .map_err(|_| anyhow!("non-utf8 peer id"))?;
            let k: usize = id
                .strip_prefix("peer ")
                .and_then(|r| r.trim().parse().ok())
                .with_context(|| format!("bad peer id `{id}`"))?;
            if k <= rank || k >= world || peers[k].is_some() {
                return Err(anyhow!("duplicate or out-of-range peer {k}"));
            }
            peers[k] = Some(s);
        }
        // Bootstrap done: go nonblocking and register the read side of
        // every connection with the shared poller. The clone and the
        // original refer to the same file description, so the
        // nonblocking flag the poller sets covers the write side too.
        let mut conns: Vec<Option<ConnHandle>> = (0..world).map(|_| None).collect();
        for (j, peer) in peers.iter().enumerate() {
            let Some(s) = peer else { continue };
            let reader = s.try_clone().context("cloning stream for the poller")?;
            let handle = Poller::global()
                .register(reader)
                .with_context(|| format!("registering peer {j} with the poller"))?;
            conns[j] = Some(handle);
        }
        Ok(TcpTransport {
            rank,
            n: world,
            peers,
            conns,
            obs: Vec::new(),
            timeout: Duration::from_secs(30),
            wire_wait_ns: 0,
            down: false,
        })
    }

    /// Replace the blocking-recv timeout (default 30 s).
    pub fn with_timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }
}

/// Bind a data listener on the same interface as the rendezvous listener.
fn ephemeral_listener(like: &TcpListener) -> Result<TcpListener> {
    let ip = like.local_addr()?.ip();
    TcpListener::bind((ip, 0)).context("binding data listener")
}


/// Accept one connection within `deadline`, or error — `std::net` has no
/// native accept timeout, so poll in nonblocking mode. The listener is
/// restored to blocking mode before returning.
fn accept_with_deadline(listener: &TcpListener, deadline: Duration) -> Result<TcpStream> {
    listener.set_nonblocking(true)?;
    let until = Instant::now() + deadline;
    let result = loop {
        match listener.accept() {
            Ok((s, _)) => {
                // Some platforms hand the accepted socket the listener's
                // nonblocking flag; the bootstrap frame reads need
                // blocking mode (the poller flips it back later).
                s.set_nonblocking(false)?;
                break Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= until {
                    break Err(anyhow!(
                        "no peer connected within {:.0}s",
                        deadline.as_secs_f64()
                    ));
                }
                std::thread::sleep(CONNECT_RETRY_EVERY);
            }
            Err(e) => break Err(e.into()),
        }
    };
    listener.set_nonblocking(false)?;
    result
}

fn connect_retry(addr: &str) -> Result<TcpStream> {
    connect_retry_for(addr, CONNECT_RETRY_FOR)
}

fn connect_retry_for(addr: &str, window: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + window;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!("connecting {addr}: {e}"));
            }
            Err(_) => std::thread::sleep(CONNECT_RETRY_EVERY),
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn group_size(&self) -> usize {
        self.n
    }

    /// Vectored zero-copy send: the 8-byte header (stack array) and the
    /// caller's payload go to the kernel as two iovecs — the payload is
    /// never copied into a concatenated frame buffer. On `EAGAIN` the
    /// sender arms `EPOLLOUT` through the poller and parks on the write
    /// gate; the retry loop never depends on the wakeup arriving.
    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        if to >= self.n || to == self.rank {
            return Err(anyhow!("bad destination rank {to} (self is {})", self.rank));
        }
        if self.peers[to].is_none() || self.conns[to].is_none() {
            return Err(anyhow!("connection to rank {to} closed"));
        }
        let t0 = Instant::now();
        let header = frame_header(payload.len());
        let total = 8 + payload.len();
        let mut written = 0usize;
        let mut blocked_ns: u64 = 0;
        while written < total {
            let stream = self.peers[to].as_mut().unwrap();
            let result = if written < 8 {
                let iov = [IoSlice::new(&header[written..]), IoSlice::new(payload)];
                stream.write_vectored(&iov)
            } else {
                stream.write(&payload[written - 8..])
            };
            match result {
                Ok(0) => {
                    return Err(anyhow!("sending to rank {to}: socket accepted zero bytes"));
                }
                Ok(k) => written += k,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // Kernel buffer full: ask the loop for an EPOLLOUT
                    // wakeup and wait (bounded — see the poller docs).
                    let conn = self.conns[to].as_ref().unwrap();
                    let parked = Instant::now();
                    conn.request_writable();
                    conn.wait_writable();
                    blocked_ns += parked.elapsed().as_nanos() as u64;
                    if conn.is_dead() {
                        return Err(anyhow!(
                            "sending to rank {to}: peer disconnected mid-frame"
                        ));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(anyhow!("sending to rank {to}: {e}")),
            }
        }
        self.wire_wait_ns += blocked_ns;
        self.obs.push(TransferObs {
            bytes: payload.len() as u64 + FRAME_OVERHEAD,
            elapsed: t0.elapsed(),
        });
        Ok(())
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        // Delegate so the validation and error mapping live once.
        let mut buf = Vec::new();
        self.recv_into(from, &mut buf)?;
        Ok(buf)
    }

    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        if from >= self.n || from == self.rank {
            return Err(anyhow!("bad source rank {from} (self is {})", self.rank));
        }
        let conn = self.conns[from]
            .as_ref()
            .with_context(|| format!("connection to rank {from} closed"))?;
        let t0 = Instant::now();
        let result = conn.recv_frame_into(buf, self.timeout);
        self.wire_wait_ns += t0.elapsed().as_nanos() as u64;
        match result {
            Ok(()) => Ok(()),
            Err(RecvError::TimedOut) => Err(anyhow!("recv from rank {from} timed out")),
            Err(RecvError::Closed(e)) => Err(anyhow!("peer {from} disconnected: {e}")),
        }
    }

    fn take_observations(&mut self) -> Vec<TransferObs> {
        std::mem::take(&mut self.obs)
    }

    fn set_recv_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    fn take_wire_wait_ns(&mut self) -> u64 {
        std::mem::take(&mut self.wire_wait_ns)
    }

    fn shutdown(&mut self) -> Result<()> {
        if self.down {
            return Ok(());
        }
        self.down = true;
        for peer in self.peers.iter_mut() {
            if let Some(s) = peer.take() {
                s.shutdown(Shutdown::Both).ok();
            }
        }
        // Dropping the handles deregisters the connections from their
        // loops; the socket shutdown above lands each loop on EOF anyway.
        // No per-transport threads exist to join.
        self.conns.iter_mut().for_each(|c| *c = None);
        Ok(())
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Spin up a localhost mesh of `world` ranks, run `f` on each rank in
    /// its own thread, and collect the outputs in rank order.
    pub(crate) fn with_mesh<T: Send + 'static>(
        world: usize,
        f: impl Fn(TcpTransport) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let rendezvous = TcpTransport::bind_rendezvous("127.0.0.1:0").unwrap();
        let addr = rendezvous.local_addr().unwrap().to_string();
        let f = std::sync::Arc::new(f);
        let mut handles = Vec::new();
        for rank in 1..world {
            let addr = addr.clone();
            let f = f.clone();
            handles.push(std::thread::spawn(move || {
                let t = TcpTransport::join(&addr, rank, world)
                    .unwrap()
                    .with_timeout(Duration::from_secs(10));
                f(t)
            }));
        }
        let t0 = TcpTransport::host(rendezvous, world)
            .unwrap()
            .with_timeout(Duration::from_secs(10));
        let mut out = vec![f(t0)];
        for h in handles {
            out.push(h.join().expect("worker thread panicked"));
        }
        out
    }

    #[test]
    fn two_rank_exchange_over_localhost() {
        let out = with_mesh(2, |mut t| {
            let peer = 1 - t.rank();
            t.send(peer, format!("from {}", t.rank()).as_bytes())
                .unwrap();
            let got = t.recv(peer).unwrap();
            t.shutdown().unwrap();
            (t.rank(), got)
        });
        assert_eq!(out[0], (0, b"from 1".to_vec()));
        assert_eq!(out[1], (1, b"from 0".to_vec()));
    }

    #[test]
    fn four_rank_mesh_all_pairs() {
        let out = with_mesh(4, |mut t| {
            let me = t.rank();
            for p in 0..4 {
                if p != me {
                    t.send(p, &[me as u8, p as u8]).unwrap();
                }
            }
            let mut got = Vec::new();
            for p in 0..4 {
                if p != me {
                    got.push(t.recv(p).unwrap());
                }
            }
            t.shutdown().unwrap();
            got
        });
        for (me, got) in out.iter().enumerate() {
            let peers: Vec<usize> = (0..4).filter(|&p| p != me).collect();
            for (g, &p) in got.iter().zip(&peers) {
                assert_eq!(g, &vec![p as u8, me as u8]);
            }
        }
    }

    /// The recycled receive path: repeated `recv_into` over one
    /// connection keeps frames intact while payload buffers rotate back
    /// through the event loop's per-connection pool.
    #[test]
    fn recv_into_recycles_inbox_buffers_without_corruption() {
        let rounds = 16usize;
        let out = with_mesh(2, move |mut t| {
            let peer = 1 - t.rank();
            let mut buf = Vec::new();
            let mut ok = true;
            for i in 0..rounds {
                // Alternate sizes so recycled buffers shrink and regrow.
                let len = if i % 2 == 0 { 4096 } else { 64 };
                t.send(peer, &vec![i as u8; len]).unwrap();
                t.recv_into(peer, &mut buf).unwrap();
                ok &= buf == vec![i as u8; len];
            }
            t.shutdown().unwrap();
            ok
        });
        assert!(out.iter().all(|&ok| ok), "recycled buffers corrupted a frame");
    }

    #[test]
    fn observations_cover_sent_frames() {
        let out = with_mesh(2, |mut t| {
            let peer = 1 - t.rank();
            t.send(peer, &[0u8; 1000]).unwrap();
            t.recv(peer).unwrap();
            let obs = t.take_observations();
            t.shutdown().unwrap();
            obs
        });
        for obs in &out {
            assert_eq!(obs.len(), 1);
            assert_eq!(obs[0].bytes, 1000 + FRAME_OVERHEAD);
        }
    }

    #[test]
    fn shutdown_is_idempotent_and_recv_after_fails() {
        let out = with_mesh(2, |mut t| {
            t.shutdown().unwrap();
            t.shutdown().unwrap();
            t.recv(1 - t.rank()).is_err()
        });
        assert!(out.iter().all(|&failed| failed));
    }

    /// Satellite: a peer crash/close must surface as a named `Err` the
    /// moment the event loop sees it — not as a silent park until the
    /// receiver's full timeout expires.
    #[test]
    fn peer_disconnect_surfaces_immediately_not_after_timeout() {
        let out = with_mesh(2, |mut t| {
            if t.rank() == 1 {
                t.shutdown().unwrap();
                (Duration::ZERO, String::new())
            } else {
                let t0 = Instant::now();
                let e = t.recv(1).unwrap_err();
                let waited = t0.elapsed();
                t.shutdown().unwrap();
                (waited, format!("{e}"))
            }
        });
        let (waited, msg) = &out[0];
        assert!(
            msg.contains("disconnected") || msg.contains("closed"),
            "unexpected error: {msg}"
        );
        // The mesh timeout is 10 s; the disconnect must beat it by far.
        assert!(
            *waited < Duration::from_secs(5),
            "recv parked for {waited:?} instead of observing the disconnect"
        );
    }

    #[test]
    fn set_recv_timeout_applies_at_runtime() {
        let out = with_mesh(2, |mut t| {
            if t.rank() == 0 {
                t.set_recv_timeout(Duration::from_millis(30));
                let t0 = Instant::now();
                let e = t.recv(1).unwrap_err();
                let waited = t0.elapsed();
                assert!(format!("{e}").contains("timed out"), "{e}");
                t.shutdown().unwrap();
                waited < Duration::from_secs(2)
            } else {
                // Keep the peer alive (no frames, no close) past the
                // other side's shortened deadline.
                std::thread::sleep(Duration::from_millis(300));
                t.shutdown().unwrap();
                true
            }
        });
        assert!(out.iter().all(|&ok| ok));
    }

    #[test]
    fn connect_retry_gives_up_with_named_error() {
        // A port nobody listens on: bind-then-drop to find a free one.
        // Exercises the real retry loop with a short window so the test
        // verifies the deadline logic, not a reimplementation.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        let e = connect_retry_for(&addr, Duration::from_millis(80)).unwrap_err();
        assert!(format!("{e}").contains("connecting"), "{e}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "retry window not honored: {:?}",
            t0.elapsed()
        );
    }

    /// ISSUE satellite: the zero-alloc steady state holds with the
    /// poller on — warmed-up send + `recv_into` rounds perform zero
    /// allocations on the *caller's* thread (the counting allocator is
    /// per-thread, so the event loop's own buffers don't mask a caller
    /// regression). The old mpsc inbox could never pass this: every
    /// channel send boxed a node on the sending side.
    #[test]
    fn steady_state_send_recv_is_alloc_free_on_caller_thread() {
        use crate::testing::alloc::thread_alloc_count;
        let out = with_mesh(2, |mut t| {
            let peer = 1 - t.rank();
            let mut buf = Vec::with_capacity(8192);
            let payload = vec![3u8; 2048];
            // Warm every pool: the receive buffer, the poller's
            // per-connection recycle pool, and the observations vector
            // (never drained here, so reserve past the measured rounds).
            t.obs.reserve(256);
            for _ in 0..40 {
                t.send(peer, &payload).unwrap();
                t.recv_into(peer, &mut buf).unwrap();
            }
            let before = thread_alloc_count();
            for _ in 0..10 {
                t.send(peer, &payload).unwrap();
                t.recv_into(peer, &mut buf).unwrap();
            }
            let allocs = thread_alloc_count() - before;
            t.shutdown().unwrap();
            allocs
        });
        for (rank, allocs) in out.iter().enumerate() {
            assert_eq!(
                *allocs, 0,
                "rank {rank}: {allocs} caller-side allocations in warmed send+recv rounds"
            );
        }
    }
}
