//! The simulated backend of the [`GroupTransport`] seam.
//!
//! Two forms, same semantics:
//!
//! - a blanket `impl GroupTransport for NetSim` — every existing call site
//!   that hands the coordinator a `&mut NetSim` keeps working, but the
//!   byte movement now flows through the trait (the coordinator no longer
//!   names `NetSim` in its sync path);
//! - [`SimTransport`], an owning adapter that additionally records the
//!   per-exchange `(bytes, rtt)` observations — the virtual-clock mirror
//!   of what the rank-level transports log with
//!   [`Transport::take_observations`](super::Transport::take_observations).

use super::{GroupTransport, TransferObs};
use crate::collectives::{ring_allgather, ring_allreduce, CollectiveTiming};
use crate::coordinator::pipeline_exchange::{pipelined_exchange, ExchangeTiming, PipelineStage};
use crate::netsim::{NetSim, SimTime};
use std::time::Duration;

impl GroupTransport for NetSim {
    fn group_size(&self) -> usize {
        self.topology.n_workers()
    }

    fn allreduce(&mut self, dense_bytes: u64) -> CollectiveTiming {
        ring_allreduce(self, dense_bytes)
    }

    fn allgather(&mut self, payload_bytes: &[u64]) -> CollectiveTiming {
        ring_allgather(self, payload_bytes)
    }

    fn pipelined(&mut self, stages: &[PipelineStage], depth: usize) -> ExchangeTiming {
        pipelined_exchange(self, stages, depth)
    }
}

/// Owning [`GroupTransport`] over a [`NetSim`] that keeps an observation
/// log: one `(max payload bytes, network elapsed)` record per exchange —
/// the same observable stream the live transports produce, read off the
/// virtual clock instead of a wall clock.
pub struct SimTransport {
    sim: NetSim,
    obs: Vec<TransferObs>,
}

impl SimTransport {
    pub fn new(sim: NetSim) -> SimTransport {
        SimTransport {
            sim,
            obs: Vec::new(),
        }
    }

    /// The wrapped simulator (e.g. to advance compute time between
    /// rounds).
    pub fn sim_mut(&mut self) -> &mut NetSim {
        &mut self.sim
    }

    pub fn into_inner(self) -> NetSim {
        self.sim
    }

    /// Drain the per-exchange observations recorded so far.
    pub fn take_observations(&mut self) -> Vec<TransferObs> {
        std::mem::take(&mut self.obs)
    }

    fn record(&mut self, bytes: u64, elapsed: SimTime) {
        self.obs.push(TransferObs {
            bytes,
            elapsed: Duration::from_nanos(elapsed.as_nanos()),
        });
    }
}

impl GroupTransport for SimTransport {
    fn group_size(&self) -> usize {
        self.sim.topology.n_workers()
    }

    fn allreduce(&mut self, dense_bytes: u64) -> CollectiveTiming {
        let t = self.sim.allreduce(dense_bytes);
        self.record(dense_bytes, t.elapsed());
        t
    }

    fn allgather(&mut self, payload_bytes: &[u64]) -> CollectiveTiming {
        let t = self.sim.allgather(payload_bytes);
        let max = payload_bytes.iter().copied().max().unwrap_or(0);
        self.record(max, t.elapsed());
        t
    }

    fn pipelined(&mut self, stages: &[PipelineStage], depth: usize) -> ExchangeTiming {
        let t = self.sim.pipelined(stages, depth);
        let max: u64 = (0..self.sim.topology.n_workers())
            .map(|w| stages.iter().map(|s| s.payload_bytes[w]).sum::<u64>())
            .max()
            .unwrap_or(0);
        self.record(max, t.net_elapsed());
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;

    fn sim(n: usize, bw: f64) -> NetSim {
        NetSim::quiet(StarTopology::constant(n, mbps(bw), SimTime::from_millis(5)))
    }

    #[test]
    fn netsim_impl_matches_direct_collectives() {
        let payloads = vec![500_000u64, 1_000_000, 750_000, 250_000];
        let mut a = sim(4, 100.0);
        let mut b = sim(4, 100.0);
        let via_trait = GroupTransport::allgather(&mut a, &payloads);
        let direct = ring_allgather(&mut b, &payloads);
        assert_eq!(via_trait, direct);

        let mut a = sim(4, 100.0);
        let mut b = sim(4, 100.0);
        assert_eq!(
            GroupTransport::allreduce(&mut a, 4_000_000),
            ring_allreduce(&mut b, 4_000_000)
        );
    }

    #[test]
    fn sim_transport_records_observations() {
        let mut t = SimTransport::new(sim(4, 100.0));
        assert_eq!(t.group_size(), 4);
        t.allgather(&[100_000, 300_000, 200_000, 50_000]);
        t.allreduce(1_000_000);
        let obs = t.take_observations();
        assert_eq!(obs.len(), 2);
        assert_eq!(obs[0].bytes, 300_000); // max payload
        assert_eq!(obs[1].bytes, 1_000_000);
        assert!(obs.iter().all(|o| o.elapsed > Duration::ZERO));
        assert!(t.take_observations().is_empty());
    }

    #[test]
    fn sim_transport_pipelined_records_net_elapsed() {
        let stages: Vec<PipelineStage> = (0..3)
            .map(|_| PipelineStage {
                payload_bytes: vec![400_000; 4],
                compress_time: SimTime::from_millis(50),
                decode_time: SimTime::from_millis(5),
            })
            .collect();
        let mut t = SimTransport::new(sim(4, 100.0));
        let x = t.pipelined(&stages, 2);
        let obs = t.take_observations();
        assert_eq!(obs.len(), 1);
        assert_eq!(obs[0].bytes, 3 * 400_000);
        // The observation is the network portion, not the whole exchange.
        assert_eq!(
            obs[0].elapsed,
            Duration::from_nanos(x.net_elapsed().as_nanos())
        );
    }
}
