//! Real collectives over a [`Transport`]: the data-moving twins of the
//! timing-only schedules in [`crate::collectives::patterns`]. Same ring
//! algorithms, but actual bytes travel — every rank runs its own copy of
//! these functions concurrently (one thread or process per rank) and the
//! ring phases synchronize through the transport itself.
//!
//! Determinism contract (tested): the reduced result is a pure function of
//! the inputs and the ring algorithm — identical bits over
//! [`LoopbackTransport`](super::LoopbackTransport) and
//! [`TcpTransport`](super::TcpTransport), and (for two ranks, where ring
//! accumulation order coincides with rank order up to commutativity)
//! identical bits to the in-memory
//! [`collectives::numeric`](crate::collectives::numeric) reduction.

use super::Transport;
use crate::util::error::{anyhow, Result};
use std::time::{Duration, Instant};

/// Wall-clock timing of one collective round at this rank — the live
/// analogue of [`crate::collectives::CollectiveTiming`], and the source of
/// the `(data_size, RTT)` observation the paper's Algorithm 1 consumes.
#[derive(Clone, Copy, Debug)]
pub struct RoundTiming {
    /// Start-to-finish wall time of the collective at this rank.
    pub elapsed: Duration,
    /// Payload bytes this rank pushed into the ring (frame headers
    /// excluded).
    pub sent_bytes: u64,
}

/// Ring all-gather of one byte payload per rank: N−1 phases; in phase `p`
/// this rank forwards the block that originated at `(rank + n − p) % n` to
/// its successor and receives the predecessor's. Returns every rank's
/// block, indexed by origin rank (own payload included), plus timing.
pub fn ring_allgather_frames(
    t: &mut dyn Transport,
    payload: &[u8],
) -> Result<(Vec<Vec<u8>>, RoundTiming)> {
    let n = t.group_size();
    let rank = t.rank();
    let t0 = Instant::now();
    let mut blocks: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    blocks[rank] = Some(payload.to_vec());
    let succ = (rank + 1) % n;
    let pred = (rank + n - 1) % n;
    let mut sent = 0u64;
    for p in 0..n.saturating_sub(1) {
        let origin = (rank + n - p) % n;
        let out = blocks[origin]
            .as_ref()
            .ok_or_else(|| anyhow!("phase {p}: block {origin} not yet received"))?;
        sent += out.len() as u64;
        t.send(succ, out)?;
        let incoming_origin = (pred + n - p) % n;
        let incoming = t.recv(pred)?;
        blocks[incoming_origin] = Some(incoming);
    }
    let blocks = blocks
        .into_iter()
        .map(|b| b.expect("all blocks received"))
        .collect();
    Ok((
        blocks,
        RoundTiming {
            elapsed: t0.elapsed(),
            sent_bytes: sent,
        },
    ))
}

/// In-place ring all-reduce (sum) of a flat f32 tensor: reduce-scatter
/// then all-gather over `n` near-equal chunks, the standard bandwidth-
/// optimal schedule. Values move as raw little-endian f32 — bit-exact
/// across transports.
pub fn ring_allreduce_f32(t: &mut dyn Transport, data: &mut [f32]) -> Result<RoundTiming> {
    let n = t.group_size();
    let rank = t.rank();
    let t0 = Instant::now();
    if n == 1 {
        return Ok(RoundTiming {
            elapsed: t0.elapsed(),
            sent_bytes: 0,
        });
    }
    let len = data.len();
    let q = len.div_ceil(n);
    let chunk = |c: usize| -> std::ops::Range<usize> {
        let start = (c * q).min(len);
        start..((c + 1) * q).min(len)
    };
    let succ = (rank + 1) % n;
    let pred = (rank + n - 1) % n;
    let mut sent = 0u64;
    // One reused staging buffer for every outgoing chunk and one for
    // every incoming chunk (§Perf: the staged schedule moves 2·(n−1)
    // chunks per call in each direction — a fresh Vec per phase was pure
    // reallocation churn; `recv_into` also lets the transport recycle its
    // inbox buffers).
    let mut out_buf: Vec<u8> = Vec::with_capacity(q * 4);
    let mut in_buf: Vec<u8> = Vec::with_capacity(q * 4);
    let mut fill_out = |buf: &mut Vec<u8>, r: std::ops::Range<usize>, data: &[f32]| {
        buf.clear();
        for x in &data[r] {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    };

    // Reduce-scatter: after phase p this rank holds the partial sum of
    // chunk (rank − p) % n over ranks {rank−p, …, rank}; after n−1 phases
    // it owns the fully reduced chunk (rank + 1) % n.
    for p in 0..n - 1 {
        let out_c = (rank + n - p) % n;
        fill_out(&mut out_buf, chunk(out_c), data);
        sent += out_buf.len() as u64;
        t.send(succ, &out_buf)?;
        let in_c = (rank + n - 1 - p) % n;
        t.recv_into(pred, &mut in_buf)?;
        let dst = &mut data[chunk(in_c)];
        if in_buf.len() != dst.len() * 4 {
            return Err(anyhow!(
                "reduce-scatter phase {p}: got {} bytes for a {}-element chunk",
                in_buf.len(),
                dst.len()
            ));
        }
        for (d, b) in dst.iter_mut().zip(in_buf.chunks_exact(4)) {
            *d += f32::from_le_bytes(b.try_into().unwrap());
        }
    }

    // All-gather of the reduced chunks: forward, don't add.
    for p in 0..n - 1 {
        let out_c = (rank + 1 + n - p) % n;
        fill_out(&mut out_buf, chunk(out_c), data);
        sent += out_buf.len() as u64;
        t.send(succ, &out_buf)?;
        let in_c = (rank + n - p) % n;
        t.recv_into(pred, &mut in_buf)?;
        let dst = &mut data[chunk(in_c)];
        if in_buf.len() != dst.len() * 4 {
            return Err(anyhow!(
                "all-gather phase {p}: got {} bytes for a {}-element chunk",
                in_buf.len(),
                dst.len()
            ));
        }
        for (d, b) in dst.iter_mut().zip(in_buf.chunks_exact(4)) {
            *d = f32::from_le_bytes(b.try_into().unwrap());
        }
    }
    Ok(RoundTiming {
        elapsed: t0.elapsed(),
        sent_bytes: sent,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::numeric::sum_dense;
    use crate::transport::LoopbackTransport;
    use crate::util::rng::Pcg64;

    fn randn(n: usize, seed: u64, stream: u64) -> Vec<f32> {
        let mut r = Pcg64::new(seed, stream);
        let mut v = vec![0f32; n];
        r.fill_normal_f32(&mut v, 0.0, 1.0);
        v
    }

    /// Reference: the in-memory reduction every transport must match.
    fn numeric_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
        let mut acc = inputs[0].clone();
        let others: Vec<&[f32]> = inputs[1..].iter().map(|v| v.as_slice()).collect();
        sum_dense(&mut acc, &others);
        acc
    }

    fn allgather_on_loopback(n: usize, payload_len: usize) -> Vec<Vec<Vec<u8>>> {
        let mesh = LoopbackTransport::mesh(n);
        let handles: Vec<_> = mesh
            .into_iter()
            .map(|mut t| {
                std::thread::spawn(move || {
                    let payload = vec![t.rank() as u8; payload_len + t.rank()];
                    let (blocks, timing) = ring_allgather_frames(&mut t, &payload).unwrap();
                    assert!(timing.sent_bytes > 0 || t.group_size() == 1);
                    blocks
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn allgather_delivers_every_origin_to_every_rank() {
        for n in [2, 3, 5] {
            let per_rank = allgather_on_loopback(n, 10);
            for blocks in &per_rank {
                assert_eq!(blocks.len(), n);
                for (origin, b) in blocks.iter().enumerate() {
                    assert_eq!(b, &vec![origin as u8; 10 + origin], "origin {origin}");
                }
            }
        }
    }

    fn allreduce_on<T: Transport + 'static>(
        endpoints: Vec<T>,
        inputs: &[Vec<f32>],
    ) -> Vec<Vec<f32>> {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut t| {
                let mut data = inputs[t.rank()].clone();
                std::thread::spawn(move || {
                    ring_allreduce_f32(&mut t, &mut data).unwrap();
                    t.shutdown().unwrap();
                    data
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn loopback_allreduce_matches_numeric_bitwise_two_ranks() {
        let inputs = vec![randn(10_000, 1, 0), randn(10_000, 1, 1)];
        let want = numeric_sum(&inputs);
        let reduced = allreduce_on(LoopbackTransport::mesh(2), &inputs);
        for (rank, got) in reduced.iter().enumerate() {
            assert_eq!(got, &want, "rank {rank} diverged from numeric sum");
        }
    }

    #[test]
    fn loopback_allreduce_all_ranks_agree_and_track_numeric() {
        // n > 2: ring accumulation order differs from rank order per
        // chunk, so bitwise equality holds across ranks/transports while
        // the numeric reference is matched to float tolerance.
        let n = 4;
        let len = 4097; // ragged tail chunk
        let inputs: Vec<Vec<f32>> = (0..n).map(|w| randn(len, 2, w as u64)).collect();
        let want = numeric_sum(&inputs);
        let reduced = allreduce_on(LoopbackTransport::mesh(n), &inputs);
        for got in &reduced[1..] {
            assert_eq!(got, &reduced[0], "ranks must agree bitwise");
        }
        for (g, w) in reduced[0].iter().zip(&want) {
            assert!((g - w).abs() <= 1e-4 * w.abs().max(1.0), "{g} vs {w}");
        }
    }

    /// The ISSUE acceptance check: a 2-worker TcpTransport ring all-reduce
    /// over localhost produces gradients bit-identical to
    /// LoopbackTransport and to the in-memory numeric reduction.
    #[test]
    fn tcp_allreduce_bit_identical_to_loopback_and_numeric() {
        let inputs = vec![randn(50_000, 7, 0), randn(50_000, 7, 1)];
        let want = numeric_sum(&inputs);
        let via_loopback = allreduce_on(LoopbackTransport::mesh(2), &inputs);

        let inputs_tcp = inputs.clone();
        let via_tcp = crate::transport::tcp::tests::with_mesh(2, move |mut t| {
            let mut data = inputs_tcp[t.rank()].clone();
            ring_allreduce_f32(&mut t, &mut data).unwrap();
            t.shutdown().unwrap();
            data
        });

        for rank in 0..2 {
            assert_eq!(via_tcp[rank], via_loopback[rank], "tcp vs loopback, rank {rank}");
            assert_eq!(via_tcp[rank], want, "tcp vs numeric, rank {rank}");
        }
    }

    #[test]
    fn tcp_allgather_matches_loopback() {
        let payloads: Vec<Vec<u8>> = (0..3).map(|r| vec![0xA0 + r as u8; 100 * (r + 1)]).collect();
        let expect = payloads.clone();
        let out = crate::transport::tcp::tests::with_mesh(3, move |mut t| {
            let (blocks, _) = ring_allgather_frames(&mut t, &payloads[t.rank()]).unwrap();
            t.shutdown().unwrap();
            blocks
        });
        for blocks in &out {
            assert_eq!(blocks, &expect);
        }
    }

    /// Satellite cross-check: the simulator timing model
    /// ([`crate::collectives::patterns::ring_allreduce`]) must account
    /// the same wire volume the data-moving twin actually pushes.
    #[test]
    fn timing_model_sent_per_worker_matches_data_mover() {
        use crate::collectives::patterns;
        use crate::netsim::schedule::mbps;
        use crate::netsim::topology::StarTopology;
        use crate::netsim::{NetSim, SimTime};

        let run_actual = |n: usize, len: usize| -> Vec<u64> {
            let handles: Vec<_> = LoopbackTransport::mesh(n)
                .into_iter()
                .map(|mut t| {
                    std::thread::spawn(move || {
                        let mut data = vec![1.0f32; len];
                        ring_allreduce_f32(&mut t, &mut data).unwrap().sent_bytes
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };

        // Even split: the model's uniform chunk is exact — simulated
        // bytes must equal measured wire bytes rank by rank.
        let (n, len) = (4usize, 8192usize);
        let mut sim =
            NetSim::quiet(StarTopology::constant(n, mbps(100.0), SimTime::from_millis(1)));
        let model = patterns::ring_allreduce(&mut sim, 4 * len as u64);
        assert_eq!(model.sent_per_worker, run_actual(n, len));

        // Ragged split: the model rounds every chunk up to ceil(total/n);
        // the data mover's element-aligned chunks sum to exactly the
        // tensor, so the aggregate discrepancy is exactly
        // 2(n−1)·(n·ceil − total), and per rank it stays under one chunk.
        let (n, len) = (3usize, 10_000usize);
        let total = 4 * len as u64;
        let mut sim =
            NetSim::quiet(StarTopology::constant(n, mbps(100.0), SimTime::from_millis(1)));
        let model = patterns::ring_allreduce(&mut sim, total);
        let actual = run_actual(n, len);
        let actual_total: u64 = actual.iter().sum();
        assert_eq!(actual_total, 2 * (n as u64 - 1) * total);
        let chunk = total.div_ceil(n as u64);
        assert_eq!(
            model.total_sent() - actual_total,
            2 * (n as u64 - 1) * (n as u64 * chunk - total)
        );
        for (m, a) in model.sent_per_worker.iter().zip(&actual) {
            assert!(m.abs_diff(*a) <= chunk, "model {m} vs measured {a}");
        }
    }

    #[test]
    fn single_rank_allreduce_is_identity() {
        let mut mesh = LoopbackTransport::mesh(1);
        let mut data = randn(100, 3, 0);
        let orig = data.clone();
        let timing = ring_allreduce_f32(&mut mesh[0], &mut data).unwrap();
        assert_eq!(data, orig);
        assert_eq!(timing.sent_bytes, 0);
    }

    #[test]
    fn empty_tensor_allreduce() {
        let reduced = allreduce_on(LoopbackTransport::mesh(2), &[vec![], vec![]]);
        assert!(reduced.iter().all(|v| v.is_empty()));
    }
}
