//! The transport layer: one seam, two backends — simulated and real.
//!
//! Everything the coordinator knows about moving gradient bytes goes
//! through this module, at two altitudes:
//!
//! - [`Transport`] — a *rank-level* endpoint: length-prefixed frame
//!   send/recv to peers ([`frame`]), with per-transfer `(bytes, elapsed)`
//!   observations ([`TransferObs`]) for the sensing estimator. Three
//!   implementations:
//!   [`LoopbackTransport`](loopback::LoopbackTransport) (in-process
//!   channels, deterministic, for tests and single-host drills),
//!   [`TcpTransport`](tcp::TcpTransport) (`std::net` only: full mesh over
//!   real sockets with a rank-0 rendezvous, multiplexed over the
//!   thread-per-core epoll event loop in [`crate::util::poller`],
//!   graceful shutdown), and the token-bucket
//!   [`ShapedTransport`](shaped::ShapedTransport) wrapper that rate-limits
//!   any inner transport (rate + burst + optional step schedule, mirroring
//!   [`crate::netsim::schedule`] so the paper's degrading/fluctuating
//!   scenarios reproduce on real sockets).
//! - [`GroupTransport`] — a *group-level* exchange seam: the collective
//!   operations one synchronization round needs, returning the timing
//!   observables. [`crate::coordinator::sync`] and the pipelined exchange
//!   drive this trait instead of calling [`NetSim`](crate::netsim::NetSim)
//!   directly; the simulator is just one implementation
//!   ([`sim::SimTransport`], or `NetSim` itself via a blanket impl).
//!
//! Real collectives — ring all-gather / all-reduce that move actual bytes
//! over a [`Transport`] — live in [`collective`]; the live multi-worker
//! training loop that feeds the [`RatioController`] with *measured* RTTs
//! is [`crate::experiments::live`] (`netsenseml live` on the CLI).
//!
//! [`RatioController`]: crate::sensing::RatioController

pub mod collective;
pub mod frame;
pub mod loopback;
pub mod shaped;
pub mod sim;
pub mod tcp;

use crate::collectives::CollectiveTiming;
use crate::coordinator::pipeline_exchange::{ExchangeTiming, PipelineStage};
use crate::util::error::Result;
use std::time::Duration;

pub use collective::{ring_allgather_frames, ring_allreduce_f32, RoundTiming};
pub use frame::{
    decode_frame, decode_frame_into, encode_frame, encode_frame_into, frame_header,
    frame_payload, parse_frame_header, read_frame, read_frame_into, write_frame,
    FRAME_OVERHEAD,
};
pub use loopback::LoopbackTransport;
pub use shaped::{ShapedTransport, ShapingConfig};
pub use sim::SimTransport;
pub use tcp::TcpTransport;

/// One observed transfer: how many wire bytes moved and how long the send
/// took end-to-end at this endpoint (the only observables a real
/// deployment has — the paper's §4.1 requirement).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferObs {
    /// Wire bytes, frame header included.
    pub bytes: u64,
    /// Wall-clock duration of the transfer as seen by the sender.
    pub elapsed: Duration,
}

/// A rank-level transport endpoint in a fixed-size worker group.
///
/// Framing, delivery order per peer, and reliability are the
/// implementation's job; callers see whole payloads. Implementations
/// record a [`TransferObs`] per send so the sensing layer can estimate
/// bandwidth from real transfers ([`Transport::take_observations`]).
pub trait Transport: Send {
    /// This endpoint's rank in `[0, group_size)`.
    fn rank(&self) -> usize;

    /// Number of ranks in the group.
    fn group_size(&self) -> usize;

    /// Send one payload to `to` as a length-prefixed frame. Blocks until
    /// the frame is handed to the wire (which, under backpressure or
    /// shaping, is where transfer time becomes observable).
    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()>;

    /// Receive the next payload from `from` (blocking, with an
    /// implementation timeout so a dead peer errors instead of hanging).
    fn recv(&mut self, from: usize) -> Result<Vec<u8>>;

    /// [`Transport::recv`] into a caller-owned buffer, reusing its
    /// capacity across frames — the receive-side half of the zero-copy
    /// hot path. Receive loops that consume each payload in place (the
    /// elastic exchange, the ring collectives) call this so steady state
    /// moves payloads without allocating per frame; implementations with
    /// internal buffering ([`TcpTransport`]) additionally recycle their
    /// inbox buffers through it. On error the buffer contents are
    /// unspecified. The default falls back to `recv` + copy, so custom
    /// transports stay correct without opting in.
    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        let payload = self.recv(from)?;
        buf.clear();
        buf.extend_from_slice(&payload);
        Ok(())
    }

    /// Replace the blocking-recv deadline at runtime. The failure-recovery
    /// protocol ([`crate::fault`]) tightens this during collective rounds
    /// and relaxes it for probe rounds; implementations without a
    /// meaningful deadline may ignore it (the default is a no-op).
    fn set_recv_timeout(&mut self, _timeout: Duration) {}

    /// Drain the `(bytes, elapsed)` observations recorded since the last
    /// call — the sensing estimator's feed.
    fn take_observations(&mut self) -> Vec<TransferObs>;

    /// Drain the nanoseconds this endpoint spent *blocked on the wire*
    /// since the last call: receive waits, send backpressure stalls, and
    /// (for shaped/fault layers) pacing or injected delays. Feeds the
    /// `evloop` span the live loop nests under each `round` in the
    /// Perfetto trace. The default reports 0 — transports without a
    /// blocking wire (loopback, the simulator) need no bookkeeping.
    fn take_wire_wait_ns(&mut self) -> u64 {
        0
    }

    /// Graceful teardown: close peer connections and join any helper
    /// threads. Idempotent.
    fn shutdown(&mut self) -> Result<()>;
}

/// The group-level exchange seam the coordinator drives: one object stands
/// for the whole worker group and performs a round's collective byte
/// movement, reporting its timing. All byte movement in
/// [`crate::coordinator::sync::SyncEngine`] goes through this trait — the
/// simulator ([`crate::netsim::NetSim`] / [`sim::SimTransport`]) is an
/// implementation detail behind it.
pub trait GroupTransport {
    /// Number of workers in the group.
    fn group_size(&self) -> usize;

    /// Dense ring all-reduce of `dense_bytes` per worker.
    fn allreduce(&mut self, dense_bytes: u64) -> CollectiveTiming;

    /// Ring all-gather of per-worker payloads (sizes may differ).
    fn allgather(&mut self, payload_bytes: &[u64]) -> CollectiveTiming;

    /// The bucketed pipelined exchange: stages compress sequentially and
    /// enter a barrier-free staged all-gather as the `depth` window allows
    /// ([`crate::coordinator::pipeline_exchange`]).
    fn pipelined(&mut self, stages: &[PipelineStage], depth: usize) -> ExchangeTiming;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::schedule::mbps;
    use crate::netsim::topology::StarTopology;
    use crate::netsim::{NetSim, SimTime};

    #[test]
    fn netsim_coerces_to_group_transport_object() {
        // The coordinator takes `&mut dyn GroupTransport`; a bare NetSim
        // must coerce (that is what keeps every existing call site valid).
        let mut sim = NetSim::quiet(StarTopology::constant(
            4,
            mbps(100.0),
            SimTime::from_millis(1),
        ));
        let net: &mut dyn GroupTransport = &mut sim;
        assert_eq!(net.group_size(), 4);
        let t = net.allgather(&[1000, 2000, 3000, 4000]);
        assert!(t.elapsed() > SimTime::ZERO);
    }
}
