//! Length-prefixed frame codec — the unit every [`super::Transport`]
//! moves.
//!
//! Wire layout (little-endian):
//! `[u32 magic "NSML"][u32 payload_len][payload_len bytes]`
//!
//! The magic word catches stream desynchronization (a torn read on a real
//! socket shows up as a named error, not garbage gradients), and the
//! length prefix is what lets one TCP stream carry back-to-back sparse
//! payloads of different sizes. The payload itself is opaque — typically a
//! [`SparseGradient::encode`](crate::compress::SparseGradient::encode)
//! buffer or a raw f32 block.
//!
//! ```
//! use netsenseml::transport::frame::{decode_frame, encode_frame};
//!
//! let wire = encode_frame(b"hello");
//! assert_eq!(decode_frame(&wire).unwrap(), b"hello");
//! ```

use crate::util::error::{anyhow, Result};
use std::io::{Read, Write};

/// Frame magic: `"NSML"` little-endian.
pub const FRAME_MAGIC: u32 = 0x4c4d_534e;

/// Header bytes prepended to every payload (magic + length).
pub const FRAME_OVERHEAD: u64 = 8;

/// Refuse frames larger than this (1 GiB) — a corrupted length prefix must
/// not turn into an OOM allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Encode one payload as a frame.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + payload.len());
    encode_frame_into(payload, &mut out);
    out
}

/// [`encode_frame`] appending into a caller-owned buffer (§Perf: zero
/// allocations once the buffer has capacity — the send-path variant).
pub fn encode_frame_into(payload: &[u8], out: &mut Vec<u8>) {
    out.reserve(8 + payload.len());
    encode_frame_header_into(payload.len(), out);
    out.extend_from_slice(payload);
}

/// Write just the 8-byte frame header for a payload of `payload_len`
/// bytes the caller appends next — the fused compress→wire path knows the
/// exact payload size before emitting a single payload byte, so the frame
/// needs no backpatching and no intermediate copy.
pub fn encode_frame_header_into(payload_len: usize, out: &mut Vec<u8>) {
    assert!(payload_len <= MAX_FRAME_BYTES, "payload too large");
    // Every encoded frame passes this choke point — one histogram
    // observation gives the wire-size distribution for free (relaxed
    // atomic, allocation-free; the zero-alloc gates cover this path).
    crate::obs::hot()
        .frame_bytes
        .observe(payload_len as u64 + FRAME_OVERHEAD);
    out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
}

/// Decode one complete frame (the buffer must hold exactly one frame).
pub fn decode_frame(buf: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(buf.len().saturating_sub(8));
    decode_frame_into(buf, &mut out)?;
    Ok(out)
}

/// [`decode_frame`] into a caller-owned buffer, reusing its capacity —
/// the receive-side twin of [`encode_frame_into`] (§Perf: zero
/// allocations once the buffer has capacity). On error `out` is left
/// untouched, so a corrupt frame can never leak partial payload bytes
/// into a reused receive buffer.
pub fn decode_frame_into(buf: &[u8], out: &mut Vec<u8>) -> Result<()> {
    let payload = frame_payload(buf)?;
    out.clear();
    out.extend_from_slice(payload);
    Ok(())
}

/// Validate a complete frame's header (magic, size cap, declared length)
/// and return the payload as a borrowed slice — the zero-copy core every
/// frame consumer shares, so the 8-byte frame contract lives in exactly
/// one place (the fused decode-reduce path borrows through this too).
pub fn frame_payload(buf: &[u8]) -> Result<&[u8]> {
    if buf.len() < 8 {
        return Err(anyhow!("short frame: {} bytes", buf.len()));
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(anyhow!("bad frame magic {magic:#010x}"));
    }
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(anyhow!("frame length {len} exceeds cap"));
    }
    if buf.len() != 8 + len {
        return Err(anyhow!("frame length {} != header-declared {}", buf.len() - 8, len));
    }
    Ok(&buf[8..])
}

/// Build the 8-byte header for a payload of `payload_len` bytes as a
/// stack array — the vectored-write path hands this and the payload to
/// `write_vectored` as two iovecs, so the payload is never copied into a
/// concatenated buffer. Unlike [`encode_frame_header_into`] this does
/// *not* observe the frame-size histogram: the envelope path already
/// observes every enveloped frame at encode time, and observing again at
/// the socket would double-count.
pub fn frame_header(payload_len: usize) -> [u8; 8] {
    assert!(payload_len <= MAX_FRAME_BYTES, "payload too large");
    let mut header = [0u8; 8];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4..8].copy_from_slice(&(payload_len as u32).to_le_bytes());
    header
}

/// Validate a complete 8-byte header (magic + length cap) and return the
/// declared payload length — the incremental read-state machine in
/// [`crate::util::poller`] parses headers byte-by-byte as they arrive and
/// needs the header contract without a blocking `Read`.
pub fn parse_frame_header(header: &[u8; 8]) -> std::io::Result<usize> {
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("bad frame magic {magic:#010x}"),
        ));
    }
    let len = u32::from_le_bytes(header[4..8].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds cap"),
        ));
    }
    Ok(len)
}

/// Write one frame to a byte sink (socket hot path: header then payload,
/// no intermediate copy of the payload).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    let header = frame_header(payload.len());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame from a byte source. An EOF before the first header byte
/// yields `UnexpectedEof` (the reader-thread shutdown signal); a torn
/// header or bad magic yields `InvalidData`.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut payload = Vec::new();
    read_frame_into(r, &mut payload)?;
    Ok(payload)
}

/// [`read_frame`] into a caller-owned buffer, reusing its capacity across
/// frames — for receive loops that consume each payload in place before
/// reading the next. Receivers that hand payload ownership onward (the
/// TCP reader thread pushing into its inbox channel) still need one owned
/// `Vec` per frame and keep using [`read_frame`]. On error the buffer
/// contents are unspecified.
pub fn read_frame_into(r: &mut impl Read, payload: &mut Vec<u8>) -> std::io::Result<()> {
    let mut header = [0u8; 8];
    r.read_exact(&mut header)?;
    let len = parse_frame_header(&header)?;
    // Grow the buffer in bounded chunks as bytes actually arrive: a length
    // prefix under the cap can still lie by hundreds of megabytes, and a
    // single up-front `resize(len)` would hand that lie a huge reservation
    // before the stream runs dry. Chunked, a lying header on a short
    // stream costs at most one chunk of memory before `UnexpectedEof`.
    payload.clear();
    let mut filled = 0;
    while filled < len {
        let chunk = (len - filled).min(READ_CHUNK_BYTES);
        payload.resize(filled + chunk, 0);
        r.read_exact(&mut payload[filled..])?;
        filled += chunk;
    }
    Ok(())
}

/// Granularity of incremental frame-buffer growth (1 MiB): the most
/// memory a lying length prefix can reserve beyond what the stream
/// actually delivers. Shared with the event-loop read-state machine in
/// [`crate::util::poller`], which grows its pooled payload buffers at the
/// same pace.
pub(crate) const READ_CHUNK_BYTES: usize = 1 << 20;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::quantize::Precision;
    use crate::compress::topk::top_k_indices;
    use crate::compress::SparseGradient;
    use crate::testing::prop::*;

    #[test]
    fn roundtrip_basic() {
        for payload in [&b""[..], b"x", b"hello world", &[0u8; 1024][..]] {
            let wire = encode_frame(payload);
            assert_eq!(wire.len() as u64, payload.len() as u64 + FRAME_OVERHEAD);
            assert_eq!(decode_frame(&wire).unwrap(), payload);
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let wire = encode_frame(b"payload");
        assert!(decode_frame(&wire[..4]).is_err()); // short
        let mut bad = wire.clone();
        bad[0] ^= 0xff; // magic
        assert!(decode_frame(&bad).is_err());
        let mut long = wire.clone();
        long.push(0); // trailing garbage
        assert!(decode_frame(&long).is_err());
        let mut short = wire;
        short.pop(); // truncated payload
        assert!(decode_frame(&short).is_err());
    }

    #[test]
    fn encode_frame_into_reuses_buffer_and_matches() {
        let mut buf = Vec::new();
        encode_frame_into(b"first payload", &mut buf);
        assert_eq!(buf, encode_frame(b"first payload"));
        let ptr = buf.as_ptr();
        buf.clear();
        encode_frame_into(b"second", &mut buf);
        assert_eq!(buf, encode_frame(b"second"));
        assert!(std::ptr::eq(buf.as_ptr(), ptr), "shorter frame must not realloc");
        // Header-then-payload split emission is byte-identical.
        buf.clear();
        encode_frame_header_into(5, &mut buf);
        buf.extend_from_slice(b"hello");
        assert_eq!(buf, encode_frame(b"hello"));
    }

    #[test]
    fn decode_frame_into_reuses_buffer_and_preserves_on_error() {
        let mut out = Vec::new();
        decode_frame_into(&encode_frame(&[7u8; 64]), &mut out).unwrap();
        assert_eq!(out, vec![7u8; 64]);
        let ptr = out.as_ptr();
        decode_frame_into(&encode_frame(&[9u8; 16]), &mut out).unwrap();
        assert_eq!(out, vec![9u8; 16]);
        assert!(std::ptr::eq(out.as_ptr(), ptr), "smaller frame must not realloc");
        // A corrupt frame must leave the reused buffer untouched.
        let mut bad = encode_frame(b"x");
        bad[0] ^= 0xff;
        assert!(decode_frame_into(&bad, &mut out).is_err());
        assert_eq!(out, vec![9u8; 16], "error path clobbered the buffer");
    }

    #[test]
    fn read_frame_into_reuses_buffer() {
        let mut stream = Vec::new();
        write_frame(&mut stream, &[7u8; 64]).unwrap();
        write_frame(&mut stream, &[9u8; 16]).unwrap();
        let mut cursor = std::io::Cursor::new(stream);
        let mut buf = Vec::new();
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(buf, vec![7u8; 64]);
        let ptr = buf.as_ptr();
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(buf, vec![9u8; 16]);
        assert!(std::ptr::eq(buf.as_ptr(), ptr), "smaller frame must not realloc");
    }

    /// The stack-array header builder and the incremental header parser
    /// are exact inverses, and both agree byte-for-byte with the
    /// streaming codec.
    #[test]
    fn frame_header_roundtrips_and_matches_streaming_codec() {
        for len in [0usize, 1, 7, 1024, MAX_FRAME_BYTES] {
            let header = frame_header(len);
            assert_eq!(parse_frame_header(&header).unwrap(), len);
        }
        let header = frame_header(5);
        let mut wire = header.to_vec();
        wire.extend_from_slice(b"hello");
        assert_eq!(wire, encode_frame(b"hello"));
        // Corruption classes: magic flip and over-cap length are the same
        // named InvalidData errors the streaming reader raises.
        let mut bad = frame_header(5);
        bad[0] ^= 0xff;
        let e = parse_frame_header(&bad).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        assert!(e.to_string().contains("bad frame magic"));
        let mut lie = [0u8; 8];
        lie[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        lie[4..8].copy_from_slice(&(MAX_FRAME_BYTES as u32 + 1).to_le_bytes());
        let e = parse_frame_header(&lie).unwrap_err();
        assert!(e.to_string().contains("exceeds cap"));
    }

    #[test]
    fn io_framing_roundtrips_back_to_back() {
        // Two frames on one stream — the length prefix must split them.
        let mut stream = Vec::new();
        write_frame(&mut stream, b"first").unwrap();
        write_frame(&mut stream, b"second, longer").unwrap();
        let mut cursor = std::io::Cursor::new(stream);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"first");
        assert_eq!(read_frame(&mut cursor).unwrap(), b"second, longer");
        let eof = read_frame(&mut cursor).unwrap_err();
        assert_eq!(eof.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    /// A length prefix over the 1 GiB cap is a named error on every
    /// decode path — never an attempted reservation (satellite of the
    /// fuzzing PR: the mutator's "length-field lie" class hits this).
    #[test]
    fn length_prefix_over_cap_is_named_error_not_reservation() {
        let mut lie = Vec::new();
        lie.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        lie.extend_from_slice(&(MAX_FRAME_BYTES as u32 + 1).to_le_bytes());
        // Borrowing validator.
        let e = frame_payload(&lie).unwrap_err().to_string();
        assert!(e.contains("exceeds cap"), "unexpected error: {e}");
        // Buffer-reusing decoder: same rejection, out untouched.
        let mut out = vec![1u8, 2, 3];
        let e = decode_frame_into(&lie, &mut out).unwrap_err().to_string();
        assert!(e.contains("exceeds cap"), "unexpected error: {e}");
        assert_eq!(out, vec![1u8, 2, 3]);
        // Streaming reader: rejected from the header alone, before any
        // payload byte is read or reserved.
        let mut payload = Vec::new();
        let e = read_frame_into(&mut std::io::Cursor::new(&lie), &mut payload).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
        assert!(e.to_string().contains("exceeds cap"), "unexpected error: {e}");
        assert_eq!(payload.capacity(), 0, "over-cap lie reserved memory");
    }

    /// An *under*-cap length lie (say 512 MiB) on a stream that dries up
    /// must fail with EOF having reserved at most one read chunk — the
    /// chunked-growth contract of `read_frame_into`.
    #[test]
    fn read_frame_into_bounds_reservation_under_length_lie() {
        let mut lie = Vec::new();
        lie.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
        lie.extend_from_slice(&(512u32 << 20).to_le_bytes());
        lie.extend_from_slice(&[0xabu8; 100]); // far fewer bytes than declared
        let mut payload = Vec::new();
        let e = read_frame_into(&mut std::io::Cursor::new(&lie), &mut payload).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::UnexpectedEof);
        assert!(
            payload.capacity() <= 2 * READ_CHUNK_BYTES,
            "length lie reserved {} bytes",
            payload.capacity()
        );
    }

    #[test]
    fn read_rejects_bad_magic_on_stream() {
        let mut stream = encode_frame(b"ok");
        stream[1] ^= 0x55;
        let e = read_frame(&mut std::io::Cursor::new(stream)).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn property_frame_roundtrip_arbitrary_bytes() {
        forall(
            "decode(encode(p)) == p",
            100,
            vec_f32(0..300, -1e30..1e30),
            |v| {
                let payload: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
                decode_frame(&encode_frame(&payload)).map(|d| d == payload).unwrap_or(false)
            },
        );
    }

    /// The COO wire codec must survive the frame codec — the exact path a
    /// sparse gradient takes over a real socket, including nnz = 0 and
    /// values at the edge of f32 precision.
    #[test]
    fn property_coo_payload_survives_framing() {
        forall(
            "SparseGradient -> frame -> SparseGradient",
            100,
            pair(vec_f32(1..200, -1.7e38..1.7e38), usize_in(0..64)),
            |(v, k)| {
                let k = (*k).min(v.len());
                let idx = top_k_indices(v, k);
                for prec in [Precision::F32, Precision::F16, Precision::Bf16] {
                    let raw = SparseGradient::gather(v, idx.clone(), prec);
                    // Canonicalize to receiver-visible (wire-precision)
                    // values, then the framed roundtrip must be lossless.
                    let canon = SparseGradient::decode(&raw.encode()).unwrap();
                    let framed = encode_frame(&canon.encode());
                    let Ok(payload) = decode_frame(&framed) else {
                        return false;
                    };
                    let Ok(decoded) = SparseGradient::decode(&payload) else {
                        return false;
                    };
                    if decoded != canon {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn coo_nnz_zero_survives_framing() {
        let s = SparseGradient {
            n_total: 10,
            indices: vec![],
            values: vec![],
            precision: Precision::F16,
        };
        let payload = decode_frame(&encode_frame(&s.encode())).unwrap();
        assert_eq!(SparseGradient::decode(&payload).unwrap(), s);
    }
}
