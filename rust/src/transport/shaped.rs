//! Token-bucket shaping for any [`Transport`] — the real-socket analogue
//! of [`crate::netsim::schedule::BandwidthSchedule`]'s link shaping, so the
//! paper's degrading/fluctuating scenarios can be reproduced over localhost
//! TCP with nothing but wall-clock sleeps.
//!
//! Every outgoing frame spends tokens equal to its wire size; tokens refill
//! at the configured rate (integrated piecewise across schedule steps) up
//! to `burst_bytes`. A send that finds the bucket short computes the exact
//! *deadline* at which the deficit will have accrued
//! ([`ShapingConfig::deadline_for`] integrates piecewise across schedule
//! steps) and parks on an event-loop timer
//! ([`crate::util::poller::sleep_until`]) until then — one deadline per
//! send, no chunked sleep loop, so pacing error stays bounded by timer
//! precision instead of sleep-clamp granularity. That is what makes the
//! *measured* transfer time — the only observable the sensing stack is
//! allowed ([`TransferObs`]) — reflect the shaped rate.

use super::{Transport, TransferObs};
use crate::util::error::Result;
use std::time::{Duration, Instant};

/// Rate-limit configuration (`[transport]` table in config TOML).
#[derive(Clone, Debug, PartialEq)]
pub struct ShapingConfig {
    /// Steady token refill rate, bytes per second.
    pub rate_bytes_per_sec: f64,
    /// Bucket capacity: how many bytes may burst through at line rate.
    pub burst_bytes: f64,
    /// Optional rate steps: `(seconds since transport creation, bytes/s)`,
    /// sorted by offset — the step-schedule mirror of
    /// [`crate::netsim::schedule::BandwidthSchedule::piecewise`].
    pub schedule: Vec<(f64, f64)>,
    /// Propagation-delay floor per send, seconds: every frame takes at
    /// least this long regardless of tokens (the link-emulation analogue
    /// of [`crate::netsim::link::LinkConfig`]'s prop delay — it is what
    /// gives the sensing loop a meaningful RTprop over loopback).
    pub prop_delay_s: f64,
}

impl ShapingConfig {
    /// Constant rate with a default one-frame-ish burst and no delay floor.
    pub fn constant(rate_bytes_per_sec: f64) -> ShapingConfig {
        ShapingConfig {
            rate_bytes_per_sec,
            burst_bytes: 64.0 * 1024.0,
            schedule: Vec::new(),
            prop_delay_s: 0.0,
        }
    }

    /// Validate rates, burst, and schedule monotonicity.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if !(self.rate_bytes_per_sec > 0.0) || !self.rate_bytes_per_sec.is_finite() {
            return Err(format!("shaping rate must be positive, got {}", self.rate_bytes_per_sec));
        }
        if !(self.burst_bytes >= 0.0) || !self.burst_bytes.is_finite() {
            return Err(format!("shaping burst must be ≥ 0, got {}", self.burst_bytes));
        }
        if !(self.prop_delay_s >= 0.0) || !self.prop_delay_s.is_finite() {
            return Err(format!("shaping prop delay must be ≥ 0, got {}", self.prop_delay_s));
        }
        let mut last = 0.0f64;
        for &(at, rate) in &self.schedule {
            if at < last {
                return Err(format!("shaping schedule offsets must be ascending (at {at})"));
            }
            if !(rate > 0.0) || !rate.is_finite() {
                return Err(format!("shaping schedule rate must be positive, got {rate}"));
            }
            last = at;
        }
        Ok(())
    }

    /// The rate in force `elapsed` seconds after creation.
    pub fn rate_at(&self, elapsed: f64) -> f64 {
        let mut rate = self.rate_bytes_per_sec;
        for &(at, r) in &self.schedule {
            if elapsed >= at {
                rate = r;
            } else {
                break;
            }
        }
        rate
    }

    /// The earliest time (seconds since creation) at which `deficit`
    /// tokens will have accrued starting from `now` — the inverse of
    /// [`ShapingConfig::tokens_earned`], walking the same schedule
    /// segments. This is the single deadline a short bucket sleeps to;
    /// rates are validated positive and finite, so the walk terminates.
    fn deadline_for(&self, now: f64, deficit: f64) -> f64 {
        let mut t = now;
        let mut need = deficit;
        loop {
            let rate = self.rate_at(t);
            let next_step = self
                .schedule
                .iter()
                .map(|&(at, _)| at)
                .find(|&at| at > t)
                .unwrap_or(f64::INFINITY);
            let earned = rate * (next_step - t);
            if earned >= need {
                return t + need / rate;
            }
            need -= earned;
            t = next_step;
        }
    }

    /// Tokens accrued over `[t0, t1]` (seconds since creation), integrated
    /// piecewise across schedule steps.
    fn tokens_earned(&self, t0: f64, t1: f64) -> f64 {
        let mut total = 0.0;
        let mut t = t0;
        while t < t1 {
            let rate = self.rate_at(t);
            let next_step = self
                .schedule
                .iter()
                .map(|&(at, _)| at)
                .find(|&at| at > t)
                .unwrap_or(f64::INFINITY);
            let seg_end = t1.min(next_step);
            total += rate * (seg_end - t);
            t = seg_end;
        }
        total
    }
}

/// A [`Transport`] wrapper that rate-limits sends with a token bucket.
pub struct ShapedTransport<T: Transport> {
    inner: T,
    config: ShapingConfig,
    tokens: f64,
    /// Seconds since `t0` at which `tokens` was last brought current.
    refilled_at: f64,
    t0: Instant,
    obs: Vec<TransferObs>,
    /// Nanoseconds spent in pacing + propagation-delay waits since the
    /// last [`Transport::take_wire_wait_ns`].
    wire_wait_ns: u64,
}

impl<T: Transport> ShapedTransport<T> {
    pub fn new(inner: T, config: ShapingConfig) -> ShapedTransport<T> {
        assert!(config.validate().is_ok(), "invalid shaping config");
        ShapedTransport {
            inner,
            // Start with a full burst allowance.
            tokens: config.burst_bytes,
            refilled_at: 0.0,
            t0: Instant::now(),
            config,
            obs: Vec::new(),
            wire_wait_ns: 0,
        }
    }

    pub fn config(&self) -> &ShapingConfig {
        &self.config
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    fn refill(&mut self, now: f64) {
        let earned = self.config.tokens_earned(self.refilled_at, now);
        self.tokens = (self.tokens + earned).min(self.config.burst_bytes.max(0.0));
        self.refilled_at = now;
    }

    /// Spend `cost` tokens, waiting out any deficit before returning.
    /// The bucket may go negative (cost > burst): an oversized frame
    /// borrows against future refill and pays the debt down inside this
    /// call, exactly like a big message serializing on a slow link.
    ///
    /// Deadline-based: the deficit maps to *one* schedule-aware deadline
    /// ([`ShapingConfig::deadline_for`]) and the thread parks on an
    /// event-loop timer until exactly then. (The loop re-checks only to
    /// absorb float rounding; [`crate::util::poller::sleep_until`] never
    /// wakes early, so one pass is the norm.)
    fn acquire(&mut self, cost: f64) {
        let now = self.t0.elapsed().as_secs_f64();
        self.refill(now);
        self.tokens -= cost;
        while self.tokens < 0.0 {
            // Accrual resumes from the last refill point, so the deadline
            // credits every token earned since then.
            let deadline_s = self.config.deadline_for(self.refilled_at, -self.tokens);
            crate::util::poller::sleep_until(self.t0 + Duration::from_secs_f64(deadline_s));
            self.refill(self.t0.elapsed().as_secs_f64());
        }
    }
}

impl<T: Transport> Transport for ShapedTransport<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn group_size(&self) -> usize {
        self.inner.group_size()
    }

    fn send(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        let bytes = payload.len() as u64 + super::FRAME_OVERHEAD;
        let t0 = Instant::now();
        self.acquire(bytes as f64);
        // Propagation floor: pad the transfer up to the configured delay
        // (before the inner send, so the receiver is held back too).
        if t0.elapsed().as_secs_f64() < self.config.prop_delay_s {
            crate::util::poller::sleep_until(
                t0 + Duration::from_secs_f64(self.config.prop_delay_s),
            );
        }
        // Everything up to here was shaping-imposed wire wait.
        self.wire_wait_ns += t0.elapsed().as_nanos() as u64;
        self.inner.send(to, payload)?;
        self.obs.push(TransferObs {
            bytes,
            elapsed: t0.elapsed(),
        });
        Ok(())
    }

    fn recv(&mut self, from: usize) -> Result<Vec<u8>> {
        self.inner.recv(from)
    }

    fn recv_into(&mut self, from: usize, buf: &mut Vec<u8>) -> Result<()> {
        // Shaping is send-side; forward so the inner transport's buffer
        // recycling stays on the path.
        self.inner.recv_into(from, buf)
    }

    /// The wrapper's observations (which include shaping delay) supersede
    /// the inner transport's; the inner log is drained and dropped so
    /// transfers are not double-counted.
    fn take_observations(&mut self) -> Vec<TransferObs> {
        let _ = self.inner.take_observations();
        std::mem::take(&mut self.obs)
    }

    fn set_recv_timeout(&mut self, timeout: Duration) {
        self.inner.set_recv_timeout(timeout);
    }

    /// Shaping delays count as wire wait, on top of whatever the inner
    /// transport was itself blocked on.
    fn take_wire_wait_ns(&mut self) -> u64 {
        std::mem::take(&mut self.wire_wait_ns) + self.inner.take_wire_wait_ns()
    }

    fn shutdown(&mut self) -> Result<()> {
        self.inner.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensing::{BandwidthEstimator, EstimatorConfig};
    use crate::netsim::SimTime;
    use crate::transport::LoopbackTransport;

    fn shaped_pair(cfg: ShapingConfig) -> (ShapedTransport<LoopbackTransport>, LoopbackTransport) {
        let mut mesh = LoopbackTransport::mesh(2);
        let b = mesh.pop().unwrap();
        let a = mesh.pop().unwrap();
        (ShapedTransport::new(a, cfg), b)
    }

    #[test]
    fn throughput_converges_to_configured_rate() {
        // 2 MB/s with a small burst: 20 × 20 kB ≈ 400 kB must take
        // ≈ 0.2 s. Tolerance is wide for CI scheduling noise (sleep
        // overshoot only ever slows the shaped path down), but the band
        // still rules out an unshaped (GB/s) or doubly-shaped link.
        let rate = 2e6;
        let cfg = ShapingConfig {
            rate_bytes_per_sec: rate,
            burst_bytes: 4096.0,
            schedule: vec![],
            prop_delay_s: 0.0,
        };
        let (mut a, mut b) = shaped_pair(cfg);
        let payload = vec![0u8; 20_000];
        let n = 20;
        let t0 = Instant::now();
        for _ in 0..n {
            a.send(1, &payload).unwrap();
            b.recv(0).unwrap();
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let sent = n as f64 * (payload.len() as f64 + super::super::FRAME_OVERHEAD as f64);
        let measured = sent / elapsed;
        assert!(
            (0.4 * rate..1.6 * rate).contains(&measured),
            "measured {measured:.0} B/s vs configured {rate:.0} B/s"
        );
    }

    #[test]
    fn sensed_btlbw_tracks_a_rate_step_within_one_window() {
        // Step the shaped rate down 8 MB/s → 1 MB/s mid-run; the estimator
        // fed with the wrapper's own (bytes, elapsed) observations must
        // follow within one BtlBw window of observations after the step.
        let hi = 8e6;
        let lo = 1e6;
        let window = 5;
        let cfg = ShapingConfig {
            rate_bytes_per_sec: hi,
            burst_bytes: 1024.0, // smaller than a frame: every send is paced
            schedule: vec![(0.0, hi), (0.15, lo)],
            prop_delay_s: 0.0,
        };
        let (mut a, mut b) = shaped_pair(cfg);
        let mut est = BandwidthEstimator::new(EstimatorConfig {
            btlbw_window: window,
            rtprop_window: 1000,
        });
        let payload = vec![0u8; 20_000]; // 2.5 ms at hi, 20 ms at lo
        // Collect window + 2 post-step samples so the send that straddles
        // the step itself has aged out of the max-filter window.
        let mut after_step = 0;
        while after_step < window + 2 {
            a.send(1, &payload).unwrap();
            b.recv(0).unwrap();
            if a.t0.elapsed().as_secs_f64() > 0.15 {
                after_step += 1;
            }
        }
        for o in a.take_observations() {
            let rtt = SimTime::from_secs_f64(o.elapsed.as_secs_f64().max(1e-6));
            est.observe(o.bytes, rtt);
        }
        let sensed = est.estimate().unwrap().btlbw_bytes_per_sec;
        // Within one window of the step, the high-rate samples have aged
        // out: the sensed bandwidth must be near `lo`, far from `hi`.
        assert!(
            sensed < (hi + lo) / 2.0,
            "sensed {sensed:.0} B/s still near pre-step rate {hi:.0}"
        );
        assert!(
            sensed > 0.3 * lo && sensed < 3.0 * lo,
            "sensed {sensed:.0} B/s vs stepped-down rate {lo:.0}"
        );
    }

    #[test]
    fn burst_allows_initial_line_rate() {
        // A burst larger than the whole workload: sends are effectively
        // unshaped (no sleeps), so this must finish almost instantly.
        let cfg = ShapingConfig {
            rate_bytes_per_sec: 1.0, // 1 B/s steady — only the burst moves bytes
            burst_bytes: 1e6,
            schedule: vec![],
            prop_delay_s: 0.0,
        };
        let (mut a, mut b) = shaped_pair(cfg);
        let t0 = Instant::now();
        for _ in 0..10 {
            a.send(1, &[0u8; 10_000]).unwrap();
            b.recv(0).unwrap();
        }
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn schedule_validation_rejects_nonsense() {
        assert!(ShapingConfig::constant(0.0).validate().is_err());
        assert!(ShapingConfig {
            rate_bytes_per_sec: 1e6,
            burst_bytes: -1.0,
            schedule: vec![],
            prop_delay_s: 0.0,
        }
        .validate()
        .is_err());
        assert!(ShapingConfig {
            rate_bytes_per_sec: 1e6,
            burst_bytes: 0.0,
            schedule: vec![(5.0, 1e6), (1.0, 2e6)], // out of order
            prop_delay_s: 0.0,
        }
        .validate()
        .is_err());
        assert!(ShapingConfig {
            rate_bytes_per_sec: 1e6,
            burst_bytes: 0.0,
            schedule: vec![(0.0, 1e6), (1.0, -2.0)], // negative rate
            prop_delay_s: 0.0,
        }
        .validate()
        .is_err());
        assert!(ShapingConfig {
            rate_bytes_per_sec: 1e6,
            burst_bytes: 0.0,
            schedule: vec![],
            prop_delay_s: -0.5, // negative delay floor
        }
        .validate()
        .is_err());
    }

    /// ISSUE satellite: deadline-based token accounting pins pacing
    /// error under 10%. The old loop slept in `clamp(1e-4, 1.0)` chunks
    /// and re-derived the wait each lap, compounding overshoot; one
    /// schedule-aware deadline per send keeps the error at timer
    /// precision.
    #[test]
    fn pacing_error_stays_under_ten_percent() {
        let rate = 5e5; // 500 kB/s
        let cfg = ShapingConfig {
            rate_bytes_per_sec: rate,
            burst_bytes: 0.0, // every frame fully paced
            schedule: vec![],
            prop_delay_s: 0.0,
        };
        let (mut a, mut b) = shaped_pair(cfg);
        let wire = 2000u64; // bytes per frame, header included
        let payload = vec![0u8; wire as usize - super::super::FRAME_OVERHEAD as usize];
        let n = 100u64;
        let t0 = Instant::now();
        for _ in 0..n {
            a.send(1, &payload).unwrap();
            b.recv(0).unwrap();
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let ideal = (n * wire) as f64 / rate; // 0.4 s
        assert!(
            elapsed >= 0.9 * ideal,
            "paced run finished in {elapsed:.3}s — shaping is not applying (ideal {ideal:.3}s)"
        );
        let err = (elapsed - ideal) / ideal;
        assert!(
            err < 0.10,
            "pacing error {:.1}% over ideal ({elapsed:.3}s vs {ideal:.3}s)",
            err * 100.0
        );
        // The pacing waits are reported as wire wait for the trace span.
        assert!(a.take_wire_wait_ns() > 0, "pacing waits not counted as wire wait");
        assert_eq!(a.take_wire_wait_ns(), 0, "take_wire_wait_ns must drain");
    }

    #[test]
    fn deadline_for_integrates_across_schedule_steps() {
        let cfg = ShapingConfig {
            rate_bytes_per_sec: 10.0,
            burst_bytes: 0.0,
            schedule: vec![(1.0, 20.0)],
            prop_delay_s: 0.0,
        };
        // 25 tokens from t=0: 10 earned over [0,1) at 10 B/s, the
        // remaining 15 at 20 B/s → 1.75 s.
        assert!((cfg.deadline_for(0.0, 25.0) - 1.75).abs() < 1e-9);
        // Entirely within one segment: plain deficit/rate.
        assert!((cfg.deadline_for(2.0, 10.0) - 2.5).abs() < 1e-9);
        // Zero deficit resolves to now.
        assert!((cfg.deadline_for(0.3, 0.0) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn rate_at_follows_schedule() {
        let cfg = ShapingConfig {
            rate_bytes_per_sec: 10.0,
            burst_bytes: 0.0,
            schedule: vec![(1.0, 20.0), (2.0, 5.0)],
            prop_delay_s: 0.0,
        };
        assert_eq!(cfg.rate_at(0.5), 10.0);
        assert_eq!(cfg.rate_at(1.0), 20.0);
        assert_eq!(cfg.rate_at(1.99), 20.0);
        assert_eq!(cfg.rate_at(100.0), 5.0);
        // Piecewise integral across both steps: 1 s at 10 + 1 s at 20 +
        // 2 s at 5.
        assert!((cfg.tokens_earned(0.0, 4.0) - 40.0).abs() < 1e-9);
    }
}
