//! Fig. 2 — Network status sensing: RTT and delivery rate vs in-flight
//! payload size on a known link, with the app-limited / bandwidth-limited
//! knee at the BDP.
//!
//! The runner sweeps payload sizes across a link with known ground truth
//! (BtlBw, RTprop) and reports the measured RTT and delivery rate at each
//! size, plus what the [`crate::sensing::BandwidthEstimator`] recovered —
//! the estimator-vs-truth check the paper's testbed cannot do.

use super::report::Table;
use super::scenario::RunOpts;
use crate::netsim::schedule::mbps;
use crate::netsim::topology::StarTopology;
use crate::netsim::{NetSim, SimTime};
use crate::sensing::{BandwidthEstimator, EstimatorConfig};

pub struct Fig2Result {
    /// (payload_bytes, rtt_ms, delivery_rate_mbps)
    pub points: Vec<(u64, f64, f64)>,
    pub true_btlbw_mbps: f64,
    pub true_rtprop_ms: f64,
    pub est_btlbw_mbps: f64,
    pub est_rtprop_ms: f64,
    pub est_bdp_bytes: f64,
}

pub fn fig2(opts: &RunOpts) -> (Table, Fig2Result) {
    let bw = mbps(200.0);
    let prop = SimTime::from_millis(20);
    let mut est = BandwidthEstimator::new(EstimatorConfig {
        btlbw_window: 1000,
        rtprop_window: 1000,
    });
    let mut points = Vec::new();
    let mut size = 16_384u64; // 16 kB → ~64 MB sweep
    let mut table = Table::new(
        "Fig 2: sensing sweep on a 200 Mbps / 40 ms-RTprop path",
        &["Payload", "RTT (ms)", "Delivery rate (Mbps)", "Regime"],
    );
    // Path: two hops of 200 Mbps with 20 ms each → effective payload
    // bandwidth 100 Mbps, RTprop 40 ms, BDP = 100 Mbps × 40 ms = 500 kB.
    let true_btlbw = bw / 2.0;
    let true_rtprop_ms = 40.0;
    let bdp_bytes = true_btlbw / 8.0 * (true_rtprop_ms / 1e3);
    while size <= 64 << 20 {
        // Fresh quiet network per probe: independent measurements.
        let mut sim = NetSim::quiet(StarTopology::uniform(
            2,
            crate::netsim::link::LinkConfig::new(
                crate::netsim::schedule::BandwidthSchedule::constant(bw),
                prop,
            ),
        ));
        let r = sim.transfer(0, 1, size);
        let rtt_ms = r.rtt().as_millis_f64();
        let rate_mbps = size as f64 * 8.0 / (r.rtt().as_secs_f64() * 1e6);
        est.observe(size, r.rtt());
        let regime = if (size as f64) < bdp_bytes {
            "app-limited"
        } else {
            "bandwidth-limited"
        };
        table.row(vec![
            human_bytes(size),
            format!("{rtt_ms:.1}"),
            format!("{rate_mbps:.1}"),
            regime.to_string(),
        ]);
        points.push((size, rtt_ms, rate_mbps));
        size *= 2;
    }
    let e = est.estimate().unwrap();
    let result = Fig2Result {
        points,
        true_btlbw_mbps: true_btlbw / 1e6,
        true_rtprop_ms,
        est_btlbw_mbps: e.btlbw_bytes_per_sec * 8.0 / 1e6,
        est_rtprop_ms: e.rtprop.as_millis_f64(),
        est_bdp_bytes: e.bdp_bytes,
    };
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir).ok();
        let series = vec![
            (
                "rtt_ms".to_string(),
                result
                    .points
                    .iter()
                    .map(|&(s, r, _)| (s as f64, r))
                    .collect::<Vec<_>>(),
            ),
            (
                "rate_mbps".to_string(),
                result
                    .points
                    .iter()
                    .map(|&(s, _, d)| (s as f64, d))
                    .collect::<Vec<_>>(),
            ),
        ];
        super::report::write_series_csv(&dir.join("fig2.csv"), "payload_bytes", "value", &series)
            .ok();
    }
    (table, result)
}

fn human_bytes(b: u64) -> String {
    if b >= 1 << 20 {
        format!("{:.0} MB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.0} kB", b as f64 / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shows_bbr_knee_and_estimator_recovers_truth() {
        let (_, r) = fig2(&RunOpts::default());
        // App-limited regime: RTT flat at RTprop, rate grows with size.
        let small = &r.points[0];
        let smallish = &r.points[2];
        assert!((small.1 - r.true_rtprop_ms).abs() < 3.0, "rtt {}", small.1);
        assert!(smallish.2 > small.2 * 2.0, "rate should grow app-limited");
        // Bandwidth-limited regime: rate saturates at BtlBw, RTT grows.
        let big = r.points.last().unwrap();
        assert!(
            (big.2 - r.true_btlbw_mbps).abs() / r.true_btlbw_mbps < 0.1,
            "rate {} vs true {}",
            big.2,
            r.true_btlbw_mbps
        );
        assert!(big.1 > 10.0 * r.true_rtprop_ms);
        // Estimator vs ground truth.
        assert!(
            (r.est_btlbw_mbps - r.true_btlbw_mbps).abs() / r.true_btlbw_mbps < 0.1,
            "est btlbw {} vs {}",
            r.est_btlbw_mbps,
            r.true_btlbw_mbps
        );
        assert!((r.est_rtprop_ms - r.true_rtprop_ms).abs() < 3.0);
        // BDP estimate within 2× of truth (windowed max/min interplay).
        let true_bdp = r.true_btlbw_mbps * 1e6 / 8.0 * (r.true_rtprop_ms / 1e3);
        assert!(r.est_bdp_bytes > 0.4 * true_bdp && r.est_bdp_bytes < 2.5 * true_bdp);
    }
}
