//! Tables 1 & 2 — Performance comparison under static bottleneck
//! bandwidths: best test accuracy, training throughput (samples/s), and
//! convergence time, for NetSenseML / AllReduce / TopK-0.1.
//!
//! Protocol (paper §5.3): run NetSenseML to its best accuracy; terminate
//! the baselines at that same virtual-time cut; report each run's best
//! accuracy, mean throughput, and convergence time ("N/A" if it never
//! stabilized before the cut).

use super::report::{f1, f2, opt_time, Table};
use super::scenario::{RunOpts, Scenario};
use crate::coordinator::{run_sim_training, SimTrainConfig, SyncStrategy};
use crate::netsim::schedule::{gbps, mbps};
use crate::trainer::metrics::TrainLog;
use crate::trainer::models::PaperModel;

/// One (bandwidth, method) cell of a table.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub method: String,
    pub bw_label: String,
    pub best_acc: f64,
    pub throughput: f64,
    pub convergence: Option<f64>,
    pub log: TrainLog,
}

/// Run the three methods at one bandwidth; cut baselines at NetSenseML's
/// plateau time.
pub fn run_bandwidth_point(
    model: &'static PaperModel,
    bw_bps: f64,
    bw_label: &str,
    horizon_s: f64,
    opts: &RunOpts,
) -> Vec<CellResult> {
    let mut results = Vec::new();
    // NetSenseML first — it defines the cut.
    let ns_log = run_one(model, SyncStrategy::NetSense, bw_bps, horizon_s, opts);
    let cut = ns_log
        .convergence_time()
        .unwrap_or_else(|| ns_log.total_vtime());
    for (strategy, log) in [
        (SyncStrategy::NetSense, Some(ns_log)),
        (SyncStrategy::AllReduce, None),
        (SyncStrategy::TopK(0.1), None),
    ] {
        let log = log.unwrap_or_else(|| {
            run_one(model, strategy.clone(), bw_bps, horizon_s, opts)
        });
        // Evaluate at the cut: restrict records to vtime ≤ max(cut, a bit).
        let cut_time = cut.max(horizon_s * 0.25);
        let cut_log = restrict(&log, cut_time);
        results.push(CellResult {
            method: strategy.label(),
            bw_label: bw_label.to_string(),
            best_acc: cut_log.best_acc(),
            throughput: cut_log.mean_throughput(),
            convergence: cut_log.convergence_time(),
            log,
        });
    }
    results
}

fn run_one(
    model: &'static PaperModel,
    strategy: SyncStrategy,
    bw_bps: f64,
    horizon_s: f64,
    opts: &RunOpts,
) -> TrainLog {
    let mut config = SimTrainConfig::new(model, strategy);
    config.n_workers = opts.n_workers;
    config.max_vtime_s = horizon_s;
    config.fidelity_every = opts.fidelity_every;
    config.seed = opts.seed;
    let mut sim = Scenario::static_bottleneck(opts.n_workers, bw_bps);
    run_sim_training(&config, &mut sim).expect("sim sync decodes its own frames")
}

fn restrict(log: &TrainLog, t_max: f64) -> TrainLog {
    let mut out = TrainLog::new(&log.method, &log.model, log.samples_per_step);
    out.records = log
        .records
        .iter()
        .filter(|r| r.vtime_s <= t_max)
        .cloned()
        .collect();
    out
}

/// Table 1: ResNet18 @ 200/500/800 Mbps.
pub fn table1(opts: &RunOpts) -> (Table, Vec<CellResult>) {
    let model = PaperModel::by_name("resnet18").unwrap();
    let points = [
        (mbps(200.0), "200Mbps"),
        (mbps(500.0), "500Mbps"),
        (mbps(800.0), "800Mbps"),
    ];
    build_table(
        "Table 1: ResNet18 under NetSenseML and other methods",
        model,
        &points,
        opts.horizon(2500.0),
        opts,
    )
}

/// Table 2: VGG16 @ 2.5/5/10 Gbps.
pub fn table2(opts: &RunOpts) -> (Table, Vec<CellResult>) {
    let model = PaperModel::by_name("vgg16").unwrap();
    let points = [
        (gbps(2.5), "2.5Gbps"),
        (gbps(5.0), "5Gbps"),
        (gbps(10.0), "10Gbps"),
    ];
    build_table(
        "Table 2: VGG16 under NetSenseML and other methods",
        model,
        &points,
        opts.horizon(2800.0),
        opts,
    )
}

fn build_table(
    title: &str,
    model: &'static PaperModel,
    points: &[(f64, &str)],
    horizon: f64,
    opts: &RunOpts,
) -> (Table, Vec<CellResult>) {
    let mut table = Table::new(
        title,
        &[
            "Method",
            "Bottleneck Bandwidth",
            "Test Accuracy (%)",
            "Training Throughput (samples/s)",
            "Convergence Time (s)",
        ],
    );
    let mut all = Vec::new();
    for &(bw, label) in points {
        let cells = run_bandwidth_point(model, bw, label, horizon, opts);
        for c in &cells {
            table.row(vec![
                c.method.clone(),
                c.bw_label.clone(),
                f2(c.best_acc),
                f1(c.throughput),
                opt_time(c.convergence),
            ]);
        }
        all.extend(cells);
    }
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir).ok();
        let name = if model.name == "resnet18" {
            "table1.csv"
        } else {
            "table2.csv"
        };
        table.write_csv(&dir.join(name)).ok();
    }
    (table, all)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> RunOpts {
        RunOpts {
            fast: true,
            fidelity_every: 0, // timing-only for speed
            ..Default::default()
        }
    }

    #[test]
    fn table1_shape_matches_paper() {
        let (table, cells) = table1(&fast_opts());
        assert_eq!(table.rows.len(), 9);
        // Within every bandwidth: NetSenseML throughput > both baselines.
        for chunk in cells.chunks(3) {
            let ns = &chunk[0];
            let ar = &chunk[1];
            let tk = &chunk[2];
            assert_eq!(ns.method, "NetSenseML");
            assert!(
                ns.throughput > ar.throughput && ns.throughput > tk.throughput,
                "{}: NS {:.0} AR {:.0} TK {:.0}",
                ns.bw_label,
                ns.throughput,
                ar.throughput,
                tk.throughput
            );
            // Accuracy: NetSenseML ≥ both baselines at the cut.
            assert!(ns.best_acc + 1.0 >= ar.best_acc, "{}", ns.bw_label);
            assert!(ns.best_acc + 1.0 >= tk.best_acc, "{}", ns.bw_label);
        }
        // 200 Mbps: TopK beats AllReduce (paper's observation).
        assert!(cells[2].throughput > cells[1].throughput);
        // Speedup falls in the paper's 1.55–9.84× band (we assert > 1.55).
        let speedup = cells[0].throughput / cells[1].throughput.max(1e-9);
        assert!(speedup > 1.55, "speedup {speedup:.2}");
    }

    #[test]
    fn table2_runs_and_orders() {
        let (table, cells) = table2(&fast_opts());
        assert_eq!(table.rows.len(), 9);
        for chunk in cells.chunks(3) {
            assert!(chunk[0].throughput >= chunk[1].throughput * 0.99);
        }
    }
}
