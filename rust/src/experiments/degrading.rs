//! Fig. 7 — Training throughput as the bottleneck bandwidth degrades from
//! 2000 to 200 Mbps in −200 Mbps steps.
//!
//! Each method trains through the same stepped bandwidth schedule; the
//! reported series is the mean throughput within each bandwidth level's
//! window, labeled by the level (exactly the figure's x-axis).

use super::report::{write_series_csv, Table};
use super::scenario::{RunOpts, Scenario};
use crate::coordinator::{run_sim_training, SimTrainConfig, SyncStrategy};
use crate::trainer::metrics::TrainLog;
use crate::trainer::models::PaperModel;

/// Result: per-method (bandwidth_mbps, throughput) series.
pub struct DegradingResult {
    pub step_secs: f64,
    pub logs: Vec<TrainLog>,
    pub series: Vec<(String, Vec<(f64, f64)>)>,
}

pub fn fig7(opts: &RunOpts) -> (Table, DegradingResult) {
    let model = PaperModel::by_name("resnet18").unwrap();
    let step_secs = opts.horizon(1800.0) / 10.0; // 10 levels: 2000..200
    let horizon = step_secs * 10.0;
    let mut logs = Vec::new();
    for strategy in [
        SyncStrategy::NetSense,
        SyncStrategy::AllReduce,
        SyncStrategy::TopK(0.1),
    ] {
        let mut config = SimTrainConfig::new(model, strategy);
        config.n_workers = opts.n_workers;
        config.max_vtime_s = horizon;
        config.fidelity_every = opts.fidelity_every;
        config.seed = opts.seed;
        let mut sim = Scenario::degrading(opts.n_workers, step_secs);
        logs.push(run_sim_training(&config, &mut sim).expect("sim sync decodes its own frames"));
    }

    let mut table = Table::new(
        "Fig 7: Throughput under degrading bandwidth (2000→200 Mbps), ResNet18",
        &["Bandwidth (Mbps)", "NetSenseML", "AllReduce", "TopK-0.1"],
    );
    let mut series: Vec<(String, Vec<(f64, f64)>)> =
        logs.iter().map(|l| (l.method.clone(), Vec::new())).collect();
    for level in 0..10 {
        let bw = 2000.0 - 200.0 * level as f64;
        let t0 = step_secs * level as f64;
        let t1 = step_secs * (level + 1) as f64;
        let mut row = vec![format!("{bw:.0}")];
        for (log, serie) in logs.iter().zip(series.iter_mut()) {
            let tp = log.throughput_in_window(t0, t1).unwrap_or(0.0);
            serie.1.push((bw, tp));
            row.push(format!("{tp:.1}"));
        }
        table.row(row);
    }
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir).ok();
        write_series_csv(&dir.join("fig7.csv"), "bandwidth_mbps", "throughput", &series).ok();
    }
    (
        table,
        DegradingResult {
            step_secs,
            logs,
            series,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn netsense_stays_flat_while_baselines_collapse() {
        let opts = RunOpts {
            fast: true,
            fidelity_every: 0,
            ..Default::default()
        };
        let (_, result) = fig7(&opts);
        let get = |m: &str| {
            result
                .series
                .iter()
                .find(|(name, _)| name == m)
                .unwrap()
                .1
                .clone()
        };
        let ns = get("NetSenseML");
        let ar = get("AllReduce");
        // Compare the first level (2000 Mbps) against the last (200 Mbps),
        // skipping level 0 for NetSense (startup warm-up) per the paper's
        // own caveat about the first epoch.
        let ns_hi = ns[1].1;
        let ns_lo = ns.last().unwrap().1;
        let ar_hi = ar[0].1.max(ar[1].1);
        let ar_lo = ar.last().unwrap().1;
        assert!(ns_lo > 0.5 * ns_hi, "NetSense collapsed: {ns_hi:.0} → {ns_lo:.0}");
        assert!(ar_lo < 0.45 * ar_hi, "AllReduce did not degrade: {ar_hi:.0} → {ar_lo:.0}");
        // At the final (most constrained) level NetSense leads everyone.
        let tk_lo = get("TopK-0.1").last().unwrap().1;
        assert!(ns_lo > ar_lo && ns_lo > tk_lo);
    }
}
