//! Ablation: which Algorithm-2 component buys what (DESIGN.md calls this
//! out as the design-choice validation the paper's evaluation omits).
//!
//! For a fixed gradient stream and ratio, toggle error feedback, pruning,
//! and quantization, and report: wire bytes per step, mean aggregation
//! error vs the dense mean (relative L2 over a horizon), and the terminal
//! residual norm. Error feedback is the component that turns "lossy each
//! step" into "delayed but delivered".

use super::report::Table;
use super::scenario::RunOpts;
use crate::compress::{CompressionConfig, NetSenseCompressor};
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct AblationRow {
    pub label: String,
    pub wire_bytes: u64,
    /// Relative L2 error between cumulative transmitted mass and the
    /// cumulative true gradient (lower = less information lost).
    pub cum_rel_err: f64,
    pub residual_norm: f64,
}

fn variant(label: &str, cfg: CompressionConfig, ratio: f64, steps: usize) -> AblationRow {
    let n = 200_000usize;
    let mut rng = Pcg64::seeded(77);
    let mut weights = vec![0f32; n];
    rng.fill_normal_f32(&mut weights, 0.0, 0.1);
    let mut c = NetSenseCompressor::new(n, cfg);
    let mut cum_true = vec![0f64; n];
    let mut cum_sent = vec![0f64; n];
    let mut grad = vec![0f32; n];
    let mut wire = 0u64;
    for _ in 0..steps {
        // slowly drifting gradient stream
        for g in grad.iter_mut() {
            *g = 0.95 * *g + 0.3 * rng.normal() as f32;
        }
        for (t, &g) in cum_true.iter_mut().zip(&grad) {
            *t += g as f64;
        }
        let out = c.compress(&grad, &weights, ratio);
        wire = out.wire_bytes;
        for (&i, &v) in out.payload.indices.iter().zip(&out.payload.values) {
            cum_sent[i as usize] += v as f64;
        }
    }
    let (mut err, mut mag) = (0f64, 0f64);
    for (t, s) in cum_true.iter().zip(&cum_sent) {
        err += (t - s) * (t - s);
        mag += t * t;
    }
    AblationRow {
        label: label.to_string(),
        wire_bytes: wire,
        cum_rel_err: (err / mag.max(1e-12)).sqrt(),
        residual_norm: c.residual_norm(),
    }
}

pub fn ablation(_opts: &RunOpts) -> (Table, Vec<AblationRow>) {
    let ratio = 0.02;
    let steps = 60;
    let full = CompressionConfig::default();
    let rows = vec![
        variant("full Algorithm 2", full.clone(), ratio, steps),
        variant(
            "no error feedback",
            CompressionConfig {
                error_feedback: false,
                ..full.clone()
            },
            ratio,
            steps,
        ),
        variant(
            "no pruning",
            CompressionConfig {
                enable_pruning: false,
                ..full.clone()
            },
            ratio,
            steps,
        ),
        variant(
            "no quantization",
            CompressionConfig {
                quant_ratio_threshold: 0.0,
                ..full.clone()
            },
            ratio,
            steps,
        ),
    ];
    let mut table = Table::new(
        "Ablation: Algorithm-2 components (ratio 0.02, 60 steps, 200k params)",
        &["Variant", "Wire bytes/step", "Cumulative rel. error", "Residual ‖·‖₂"],
    );
    for r in &rows {
        table.row(vec![
            r.label.clone(),
            r.wire_bytes.to_string(),
            format!("{:.4}", r.cum_rel_err),
            format!("{:.2}", r.residual_norm),
        ]);
    }
    (table, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_feedback_dominates_information_retention() {
        let (_, rows) = ablation(&RunOpts::default());
        let get = |l: &str| rows.iter().find(|r| r.label.contains(l)).unwrap();
        let full = get("full");
        let no_ef = get("no error feedback");
        // Without EF, cumulative gradient mass is permanently lost; with
        // EF it is merely delayed (the margin is bounded here because the
        // stream is autocorrelated, which favors memoryless top-k too).
        assert!(
            no_ef.cum_rel_err > 1.15 * full.cum_rel_err,
            "EF off: {} vs full {}",
            no_ef.cum_rel_err,
            full.cum_rel_err
        );
        assert_eq!(no_ef.residual_norm, 0.0);
        assert!(full.residual_norm > 0.0);
        // Quantization halves the value bytes: wire shrinks vs no-quant at
        // the same nominal ratio (2×k at 6 B vs k at 8 B ⇒ 1.5× — compare
        // directionally via per-element cost instead).
        let no_q = get("no quantization");
        assert!(no_q.wire_bytes != full.wire_bytes);
    }

    #[test]
    fn pruning_changes_selection_not_budget() {
        let (_, rows) = ablation(&RunOpts::default());
        let get = |l: &str| rows.iter().find(|r| r.label.contains(l)).unwrap();
        // Pruning redirects the budget; the wire size is ratio-determined.
        assert_eq!(get("full").wire_bytes, get("no pruning").wire_bytes);
    }
}
