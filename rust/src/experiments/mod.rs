//! Experiment harness: one runner per paper table/figure (index with
//! paper mapping: `EXPERIMENTS.md`).
//!
//! | Runner        | Paper artifact                                   |
//! |---------------|--------------------------------------------------|
//! | [`fig2`]      | Fig. 2 — sensing: RTT & delivery rate vs payload |
//! | [`fig3`]      | Fig. 3 — adaptive-quantization decision table    |
//! | [`tta`] (fig5/fig6) | Figs. 5–6 — TTA curves per bandwidth       |
//! | [`tables`] (table1/table2) | Tables 1–2 — acc/throughput/conv  |
//! | [`degrading`] | Fig. 7 — throughput under degrading bandwidth    |
//! | [`fluctuating`] | Fig. 8 — throughput under competing traffic    |
//! | [`pipelined`] | pipelined vs monolithic exchange (overlap study) |
//! | [`live`]      | live socket training (paper's §5 testbed runs), including the chaos scenarios (`configs/elastic.toml`) |
//!
//! Every runner prints a markdown table (and optionally CSV curves) built
//! with [`report`]; scenarios come from [`scenario`]. [`live`] is the odd
//! one out: it runs over the real [`crate::transport`] layer (threads +
//! sockets + wall clock) instead of the simulator — elastically, through
//! the fault-tolerant membership layer ([`crate::fault`]), so chaos
//! schedules (kills, stragglers, flapping links) degrade the group
//! instead of deadlocking it.

pub mod ablation;
pub mod degrading;
pub mod fig2;
pub mod fig3;
pub mod fluctuating;
pub mod live;
pub mod pipelined;
pub mod report;
pub mod scenario;
pub mod tables;
pub mod tta;

pub use report::Table;
pub use scenario::{RunOpts, Scenario};
