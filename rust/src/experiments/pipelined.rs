//! Pipelined vs monolithic gradient exchange on the fluctuating-bandwidth
//! scenario (scenario 3: competing iperf-like traffic) — the overlap
//! benchmark behind `netsenseml repro pipeline` and `bench_pipeline`.
//!
//! Every variant ships the *same* Top-K payloads over the *same* network
//! trace and pays the *same* total compression cost; only the schedule
//! differs. The baseline is the true pre-pipeline path — compress the
//! whole gradient, then one *barriered* ring all-gather; the pipelined
//! variants compress bucket *k+1* while bucket *k* is in flight on the
//! barrier-free staged ring. Reported overlap efficiency is
//! `saved_time / hideable_compression` where hideable = total compression
//! minus the unhidable first stage (it can exceed 1 because barrier
//! removal saves transport time on top of hiding compression).

use super::report::{f1, f2, Table};
use super::scenario::{RunOpts, Scenario};
use crate::coordinator::{PipelineConfig, SyncEngine, SyncStrategy};
use crate::netsim::SimTime;
use crate::trainer::models::PaperModel;

/// One schedule variant's aggregate timing.
#[derive(Clone, Debug)]
pub struct PipelineVariant {
    pub label: String,
    pub bucket_bytes: u64,
    pub depth: usize,
    /// Total exchange time over all rounds (compression + transport), s.
    pub total_s: f64,
    pub mean_round_ms: f64,
    /// Wall-clock speedup vs the monolithic variant.
    pub speedup: f64,
    /// Fraction of hideable compression actually hidden (can exceed 1 when
    /// bucketing also smooths link contention).
    pub overlap_efficiency: f64,
}

pub struct PipelineResult {
    pub variants: Vec<PipelineVariant>,
    pub rounds: usize,
    /// Per-round compression cost every variant pays, seconds.
    pub compress_per_round_s: f64,
}

/// Dense-input compression throughput modeled for this experiment
/// (conservative vs `bench_compress` measurements, which also fold in the
/// error-feedback and gather passes).
const COMPRESS_BYTES_PER_SEC: f64 = 1e9;

fn run_variant(
    opts: &RunOpts,
    model: &PaperModel,
    cfg: PipelineConfig,
    rounds: usize,
) -> f64 {
    let mut engine = SyncEngine::new(SyncStrategy::TopK(0.1), opts.n_workers, model.n_params)
        .with_pipeline(cfg);
    let mut sim = Scenario::fluctuating(opts.n_workers, opts.seed);
    let compute = SimTime::from_secs_f64(model.compute_time_s);
    let mut total = 0.0;
    for _ in 0..rounds {
        sim.advance_by(compute);
        let out = engine.sync_predicted(&mut sim);
        total += out.comm.elapsed().as_secs_f64();
    }
    total
}

/// The true pre-pipeline path: Algorithm 2 over the whole tensor
/// (compression fully exposed on the virtual clock), then one *barriered*
/// ring all-gather — exactly what the coordinator did before bucketing,
/// with the same compression-cost model the pipelined variants pay.
fn run_monolithic_baseline(opts: &RunOpts, model: &PaperModel, rounds: usize) -> f64 {
    let mut engine = SyncEngine::new(SyncStrategy::TopK(0.1), opts.n_workers, model.n_params);
    let mut sim = Scenario::fluctuating(opts.n_workers, opts.seed);
    let compute = SimTime::from_secs_f64(model.compute_time_s);
    let compress =
        SimTime::from_secs_f64(model.dense_bytes() as f64 / COMPRESS_BYTES_PER_SEC);
    let mut total = 0.0;
    for _ in 0..rounds {
        sim.advance_by(compute);
        // Compression serializes ahead of the wire: no byte moves until
        // the whole gradient is processed.
        sim.advance_by(compress);
        let out = engine.sync_predicted(&mut sim);
        total += compress.as_secs_f64() + out.comm.elapsed().as_secs_f64();
    }
    total
}

pub fn pipeline_overlap(opts: &RunOpts) -> (Table, PipelineResult) {
    let model = PaperModel::by_name("resnet18").unwrap();
    let rounds = if opts.fast { 30 } else { 150 };
    let dense = model.dense_bytes();
    let base = PipelineConfig {
        compress_bytes_per_sec: COMPRESS_BYTES_PER_SEC,
        adaptive: false,
        ..Default::default()
    };
    let variants: Vec<(String, PipelineConfig)> = vec![
        (
            "pipelined 8 MB buckets, depth 2".to_string(),
            PipelineConfig {
                bucket_size_bytes: 8 << 20,
                pipeline_depth: 2,
                ..base.clone()
            },
        ),
        (
            "pipelined 4 MB buckets, depth 2".to_string(),
            PipelineConfig {
                bucket_size_bytes: 4 << 20,
                pipeline_depth: 2,
                ..base.clone()
            },
        ),
        (
            "pipelined 1 MB buckets, depth 4".to_string(),
            PipelineConfig {
                bucket_size_bytes: 1 << 20,
                pipeline_depth: 4,
                ..base
            },
        ),
    ];

    let compress_per_round = dense as f64 / COMPRESS_BYTES_PER_SEC;
    let mut rows = Vec::new();
    let mono_total = run_monolithic_baseline(opts, model, rounds);
    rows.push(PipelineVariant {
        label: "monolithic (barriered compress-then-send)".to_string(),
        bucket_bytes: dense,
        depth: 0,
        total_s: mono_total,
        mean_round_ms: mono_total / rounds as f64 * 1e3,
        speedup: 1.0,
        overlap_efficiency: 0.0,
    });
    for (label, cfg) in &variants {
        let total = run_variant(opts, model, cfg.clone(), rounds);
        // What overlap could hide per round: everything but the first
        // stage's compression.
        let first_stage = cfg.bucket_size_bytes.min(dense) as f64 / COMPRESS_BYTES_PER_SEC;
        let hideable = (compress_per_round - first_stage).max(0.0) * rounds as f64;
        let saved = mono_total - total;
        rows.push(PipelineVariant {
            label: label.clone(),
            bucket_bytes: cfg.bucket_size_bytes,
            depth: cfg.pipeline_depth,
            total_s: total,
            mean_round_ms: total / rounds as f64 * 1e3,
            speedup: if total > 0.0 { mono_total / total } else { 1.0 },
            overlap_efficiency: if hideable > 0.0 { saved / hideable } else { 0.0 },
        });
    }

    let mut table = Table::new(
        &format!(
            "Pipelined vs monolithic exchange — ResNet18, TopK-0.1, fluctuating bandwidth, {rounds} rounds"
        ),
        &[
            "Schedule",
            "Bucket (MB)",
            "Depth",
            "Total exchange (s)",
            "Mean round (ms)",
            "Speedup",
            "Overlap eff.",
        ],
    );
    for v in &rows {
        table.row(vec![
            v.label.clone(),
            f1(v.bucket_bytes as f64 / 1e6),
            v.depth.to_string(),
            f2(v.total_s),
            f1(v.mean_round_ms),
            format!("{:.3}×", v.speedup),
            f2(v.overlap_efficiency),
        ]);
    }
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir).ok();
        table.write_csv(&dir.join("pipeline.csv")).ok();
    }
    (
        table,
        PipelineResult {
            variants: rows,
            rounds,
            compress_per_round_s: compress_per_round,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipelined_beats_monolithic_on_fluctuating_bandwidth() {
        let opts = RunOpts {
            fast: true,
            ..Default::default()
        };
        let (_, result) = pipeline_overlap(&opts);
        let mono = &result.variants[0];
        assert!(mono.total_s > 0.0);
        for v in &result.variants[1..] {
            assert!(
                v.total_s < mono.total_s,
                "{}: {:.3}s not faster than monolithic {:.3}s",
                v.label,
                v.total_s,
                mono.total_s
            );
            assert!(v.speedup > 1.0);
        }
        // The best pipelined variant should hide a solid majority of the
        // hideable compression.
        let best = result
            .variants[1..]
            .iter()
            .map(|v| v.overlap_efficiency)
            .fold(0.0, f64::max);
        assert!(best > 0.5, "best overlap efficiency only {best:.2}");
    }

    #[test]
    fn variants_ship_identical_bytes() {
        // Static Top-K payloads: scheduling must not change what is sent
        // (up to the extra per-bucket headers, which are reported bytes).
        let opts = RunOpts {
            fast: true,
            ..Default::default()
        };
        let model = PaperModel::by_name("resnet18").unwrap();
        let tot_bytes = |bucket: u64| {
            let cfg = PipelineConfig {
                bucket_size_bytes: bucket,
                compress_bytes_per_sec: COMPRESS_BYTES_PER_SEC,
                adaptive: false,
                ..Default::default()
            };
            let mut engine =
                SyncEngine::new(SyncStrategy::TopK(0.1), opts.n_workers, model.n_params)
                    .with_pipeline(cfg);
            let mut sim = Scenario::fluctuating(opts.n_workers, opts.seed);
            let out = engine.sync_predicted(&mut sim);
            out.payload_bytes.iter().sum::<u64>()
        };
        let mono = tot_bytes(model.dense_bytes());
        let pipe = tot_bytes(4 << 20);
        // Identical modulo the 12-byte header per extra bucket and ±1
        // element of per-bucket k rounding.
        let diff = pipe.abs_diff(mono);
        let nb = model.dense_bytes().div_ceil(4 << 20);
        assert!(diff < nb * (12 + 8) * opts.n_workers as u64, "diff {diff}");
        // And K itself is unchanged: payload dominated by the same 8-byte
        // COO entries.
        assert!(mono > 1_000_000);
    }
}
