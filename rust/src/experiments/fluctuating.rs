//! Fig. 8 — Training throughput under fluctuating bandwidth with competing
//! (iperf-like) traffic. Reports per-window mean throughput over time and a
//! stability summary (the paper's claim is NetSenseML's visibly steadier
//! series).

use super::report::{f1, write_series_csv, Table};
use super::scenario::{RunOpts, Scenario};
use crate::coordinator::{run_sim_training, SimTrainConfig, SyncStrategy};
use crate::trainer::metrics::TrainLog;
use crate::trainer::models::PaperModel;
use crate::util::stats::Summary;

pub struct FluctuatingResult {
    pub logs: Vec<TrainLog>,
    /// Per-method (window_end_s, throughput) series.
    pub series: Vec<(String, Vec<(f64, f64)>)>,
    /// Per-method coefficient of variation of the windowed throughput.
    pub cv: Vec<(String, f64)>,
}

pub fn fig8(opts: &RunOpts) -> (Table, FluctuatingResult) {
    let model = PaperModel::by_name("resnet18").unwrap();
    let horizon = opts.horizon(1200.0);
    let window = horizon / 24.0;
    let mut logs = Vec::new();
    for strategy in [
        SyncStrategy::NetSense,
        SyncStrategy::AllReduce,
        SyncStrategy::TopK(0.1),
    ] {
        let mut config = SimTrainConfig::new(model, strategy);
        config.n_workers = opts.n_workers;
        config.max_vtime_s = horizon;
        config.fidelity_every = opts.fidelity_every;
        config.seed = opts.seed;
        let mut sim = Scenario::fluctuating(opts.n_workers, opts.seed);
        logs.push(run_sim_training(&config, &mut sim).expect("sim sync decodes its own frames"));
    }

    let mut series: Vec<(String, Vec<(f64, f64)>)> =
        logs.iter().map(|l| (l.method.clone(), Vec::new())).collect();
    let n_windows = 24usize;
    for w in 0..n_windows {
        let t0 = window * w as f64;
        let t1 = window * (w + 1) as f64;
        for (log, serie) in logs.iter().zip(series.iter_mut()) {
            if let Some(tp) = log.throughput_in_window(t0, t1) {
                serie.1.push((t1, tp));
            }
        }
    }
    // Stability: coefficient of variation of windowed throughput,
    // excluding each method's first two windows (warm-up).
    let mut cv = Vec::new();
    let mut table = Table::new(
        "Fig 8: Throughput under fluctuating bandwidth + competing traffic, ResNet18",
        &["Method", "Mean Throughput", "Std", "CV (stability; lower=steadier)"],
    );
    for (name, points) in &series {
        let ys: Vec<f64> = points.iter().skip(2).map(|&(_, y)| y).collect();
        let s = Summary::of(&ys).unwrap_or(Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p90: 0.0,
            p99: 0.0,
        });
        let c = if s.mean > 0.0 { s.std / s.mean } else { f64::INFINITY };
        cv.push((name.clone(), c));
        table.row(vec![name.clone(), f1(s.mean), f1(s.std), format!("{c:.3}")]);
    }
    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir).ok();
        write_series_csv(&dir.join("fig8.csv"), "time_s", "throughput", &series).ok();
    }
    (table, FluctuatingResult { logs, series, cv })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn netsense_is_steadier_and_faster_than_baselines() {
        let opts = RunOpts {
            fast: true,
            fidelity_every: 0,
            ..Default::default()
        };
        let (_, result) = fig8(&opts);
        let cv_of = |m: &str| result.cv.iter().find(|(n, _)| n == m).unwrap().1;
        let mean_of = |m: &str| {
            let pts = &result.series.iter().find(|(n, _)| n == m).unwrap().1;
            pts.iter().skip(2).map(|&(_, y)| y).sum::<f64>() / (pts.len() - 2) as f64
        };
        // Throughput: NetSenseML leads under interference.
        assert!(mean_of("NetSenseML") > mean_of("AllReduce"));
        assert!(mean_of("NetSenseML") > mean_of("TopK-0.1"));
        // Stability: NetSenseML's CV is not worse than AllReduce's.
        assert!(
            cv_of("NetSenseML") <= cv_of("AllReduce") * 1.2,
            "NS cv {} vs AR cv {}",
            cv_of("NetSenseML"),
            cv_of("AllReduce")
        );
    }
}
