//! Live multi-worker training over the real transport layer — the
//! counterpart of the paper's physical testbed runs (§5 setup), with the
//! simulator nowhere in the loop.
//!
//! Every worker runs in its own thread with its own rank-level
//! [`Transport`] endpoint, its own Algorithm-2 compressor, and its own
//! Algorithm-1 [`RatioController`] fed exclusively by *measured*
//! observables: the bytes it saw move, the wall-clock time its ring round
//! took, and whether the round *lost* anything (a recv deadline or a
//! membership recovery — the controller's backoff trigger). Nothing in
//! this module reads configured rates — shaped runs demonstrate that the
//! controller reacts to what the wire actually does, which is the paper's
//! central claim.
//!
//! Every exchange — sparse and the dense baseline alike — rides the
//! **elastic** collective ([`crate::fault::ElasticExchange`]): payloads
//! travel in epoch-tagged envelopes over the ring of *live* ranks
//! ([`crate::fault::Membership`]), a silent rank is suspected on a
//! deadline, the group agrees on a new epoch through a probe round,
//! rebuilds the ring over survivors, and replays the interrupted round.
//! Chaos scenarios ([`crate::fault::FaultSchedule`]) inject kills, stalls
//! and flapping links per rank through a
//! [`FaultInjector`](crate::fault::FaultInjector); the same schedule
//! replayed on the simulator ([`crate::fault::sim_trajectory`]) must
//! produce the same epoch/live-set trajectory
//! ([`LiveReport::trajectory`]) — asserted in the chaos tests below.
//!
//! Per step, per worker (sparse strategies): drifting synthetic gradients
//! → fused Algorithm 2 straight into a reused wire buffer
//! ([`NetSenseCompressor::compress_payload_into`] — the send side never
//! materializes a [`SparseGradient`](crate::compress::SparseGradient) and
//! allocates nothing in steady state) → elastic ring all-gather handing
//! each live rank's payload to this worker as a **borrowed slice**
//! ([`ElasticExchange::round_reduce`]) → fused decode-reduce straight
//! into the reused dense accumulator
//! ([`decode_reduce_into`](crate::compress::decode_reduce_into) — no
//! `SparseGradient` on the receive side either) → controller
//! observation. Reduced gradients are hashed per step and compared
//! across ranks at the end — survivors must stay bit-identical through
//! every recovery.

use crate::compress::{decode_reduce_into, NetSenseCompressor, Workspace};
use crate::coordinator::SyncStrategy;
use crate::fault::{
    ElasticExchange, FaultConfig, FaultInjector, FaultSchedule, Membership, SyncTrajectory,
};
use crate::netsim::SimTime;
use crate::obs::{
    self, analyze, chrome_trace_json_with_offsets, gather_at_rank0, merge_aligned,
    respond_to_collector, Analysis, DecisionJournal, DecisionKind, DecisionRecord, RankTelemetry,
    SpanRecord, Tracer,
};
use crate::sensing::{Branch, Phase, RatioController};
use crate::transport::{
    LoopbackTransport, ShapedTransport, ShapingConfig, TcpTransport, Transport,
};
use crate::util::error::{anyhow, Result};
use crate::util::rng::Pcg64;
use std::time::{Duration, Instant};

/// Which sockets a live run uses.
#[derive(Clone, Debug, PartialEq)]
pub enum LiveBackend {
    /// In-process channels (deterministic; the default for tests).
    Loopback,
    /// Localhost TCP mesh with a rank-0 rendezvous at `bind`.
    Tcp { bind: String },
}

/// Configuration of one live run.
#[derive(Clone, Debug)]
pub struct LiveOpts {
    pub n_workers: usize,
    pub steps: usize,
    /// Flat gradient length per worker.
    pub n_params: usize,
    pub strategy: SyncStrategy,
    pub backend: LiveBackend,
    /// Token-bucket shaping applied to every worker's endpoint (None =
    /// unshaped).
    pub shaping: Option<ShapingConfig>,
    /// Simulated local fwd+bwd time per step (thread sleep).
    pub compute_ms: u64,
    pub seed: u64,
    /// Chaos schedule: per-rank kills / stalls / link flaps, keyed by
    /// step. Empty = healthy run (the injector is still in the path, as a
    /// pass-through, so membership checks are always exercised).
    pub faults: FaultSchedule,
    /// Failure-detector deadlines (recv + probe).
    pub fault: FaultConfig,
    /// Event-loop threads for the shared socket poller
    /// ([`crate::util::poller`]); 0 = auto (one per core, capped). Only a
    /// hint, and only effective before the first TCP endpoint registers —
    /// the pool is process-global and sized once.
    pub poller_threads: usize,
    /// Telemetry capture (spans + decision journal). Off by default; the
    /// always-on metrics registry ([`crate::obs::hot`]) ticks regardless.
    pub obs: ObsOpts,
}

/// What telemetry a live run captures beyond the always-on registry.
#[derive(Clone, Debug)]
pub struct ObsOpts {
    /// Record per-rank tracing spans (step/compress/round/decode) into
    /// preallocated rings, exported via [`LiveReport::trace_json`].
    pub trace: bool,
    /// Span-ring capacity per rank (oldest spans overwritten past it).
    pub trace_capacity: usize,
    /// Record every rank's controller decision journal (rank 0's is
    /// exported via [`LiveReport::journal_json`]; the rest land in
    /// [`LiveReport::journals`] and ride the collection gather).
    pub journal: bool,
    /// End-of-run cluster gather ([`crate::obs::collect`]): every
    /// surviving rank ships its span ring + journal + counters to rank 0
    /// behind a clock ping/pong, the merged timeline is clock-aligned
    /// ([`crate::obs::align`]), and the critical-path analyzer runs
    /// ([`LiveReport::analysis`]). Strictly post-loop — the training hot
    /// path never sees any of it.
    pub collect: bool,
}

impl Default for ObsOpts {
    fn default() -> Self {
        ObsOpts {
            trace: false,
            trace_capacity: 4096,
            journal: false,
            collect: false,
        }
    }
}

impl ObsOpts {
    /// Everything on — what `--trace-out`/`--journal-out` runs use.
    pub fn all() -> ObsOpts {
        ObsOpts {
            trace: true,
            trace_capacity: 4096,
            journal: true,
            collect: true,
        }
    }
}

impl Default for LiveOpts {
    fn default() -> Self {
        LiveOpts {
            n_workers: 2,
            steps: 30,
            n_params: 100_000,
            strategy: SyncStrategy::NetSense,
            backend: LiveBackend::Loopback,
            shaping: None,
            compute_ms: 0,
            seed: 42,
            faults: FaultSchedule::default(),
            fault: FaultConfig::default(),
            poller_threads: 0,
            obs: ObsOpts::default(),
        }
    }
}

/// One step of rank 0's telemetry.
#[derive(Clone, Debug)]
pub struct LiveStepRecord {
    pub step: usize,
    /// Wall-clock offset since the worker started, seconds.
    pub at_s: f64,
    /// Compression ratio used this step (1.0 = dense).
    pub ratio: f64,
    /// Largest payload any live rank contributed (bytes).
    pub payload_bytes: u64,
    /// Measured ring-round time, milliseconds (recoveries included).
    pub round_ms: f64,
    /// Sensed bottleneck bandwidth, Mbps (None before first estimate).
    pub btlbw_mbps: Option<f64>,
    /// Membership epoch the step's round completed at.
    pub epoch: u64,
    /// Live ranks when the round completed.
    pub live: usize,
    /// Did the round need a deadline abort / recovery?
    pub lost: bool,
}

/// What one live run produced.
#[derive(Clone, Debug)]
pub struct LiveReport {
    /// Rank 0's per-step trace.
    pub steps: Vec<LiveStepRecord>,
    /// Did every rank's reduced gradient match bit-for-bit on every step
    /// it was alive for? (A killed rank is compared on its prefix.)
    pub consistent: bool,
    pub final_ratio: f64,
    pub controller_decreases: u64,
    pub controller_increases: u64,
    pub wall_s: f64,
    /// Membership recoveries rank 0 performed (epoch bumps).
    pub recoveries: u64,
    /// Intervals rank 0 reported as lost to its controller.
    pub lost_intervals: u64,
    /// Live ranks at the end of the run.
    pub final_live: usize,
    /// Tracing spans from every rank, merged and start-ordered (empty
    /// unless [`ObsOpts::trace`] was set).
    pub spans: Vec<SpanRecord>,
    /// Spans overwritten by ring wrap, summed across ranks.
    pub spans_dropped: u64,
    /// Rank 0's decision journal (empty unless [`ObsOpts::journal`]),
    /// with the analyzer's `Straggler`/`Congestion` verdicts appended
    /// when collection ran.
    pub journal: Vec<DecisionRecord>,
    /// Journal records refused past capacity.
    pub journal_dropped: u64,
    /// Every rank's decision journal, indexed by rank (each empty unless
    /// [`ObsOpts::journal`]; a killed rank keeps the prefix it recorded).
    pub journals: Vec<Vec<DecisionRecord>>,
    /// Per-peer clock offsets applied to the merged timeline, ns, indexed
    /// by rank (empty unless [`ObsOpts::collect`]; entry 0 is always 0).
    pub clock_offsets_ns: Vec<i64>,
    /// Workers that aborted with an error, `"rank N: cause"` (flight
    /// recorder: their partial trace/journal is still in the report).
    pub worker_errors: Vec<String>,
    /// Collection-gather diagnostics (silent peers, malformed payloads).
    pub collect_notes: Vec<String>,
    /// Critical-path attribution over the merged timeline (None unless
    /// [`ObsOpts::collect`] gathered spans).
    pub analysis: Option<Analysis>,
}

impl LiveReport {
    /// Mean ratio of the last `n` steps.
    pub fn mean_ratio_last(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(|r| r.ratio).sum::<f64>() / tail.len() as f64
    }

    /// Mean ratio of the steps whose wall offset falls in `[t0_s, t1_s)`.
    pub fn mean_ratio_between(&self, t0_s: f64, t1_s: f64) -> f64 {
        let window: Vec<f64> = self
            .steps
            .iter()
            .filter(|r| r.at_s >= t0_s && r.at_s < t1_s)
            .map(|r| r.ratio)
            .collect();
        if window.is_empty() {
            return 0.0;
        }
        window.iter().sum::<f64>() / window.len() as f64
    }

    /// The epoch/live-set trajectory of the run — compared against the
    /// netsim mirror ([`crate::fault::sim_trajectory`]) by the chaos
    /// determinism test.
    pub fn trajectory(&self) -> SyncTrajectory {
        let mut t = SyncTrajectory::default();
        for r in &self.steps {
            t.record(r.epoch, r.live);
        }
        t
    }

    /// The run's spans as Chrome `trace_event` JSON — load in Perfetto or
    /// `chrome://tracing` (one track per rank). When collection ran, the
    /// spans are clock-aligned and the applied offsets are embedded as
    /// `clockOffsetsNs` trace metadata.
    pub fn trace_json(&self) -> String {
        chrome_trace_json_with_offsets(&self.spans, &self.clock_offsets_ns)
    }

    /// The run's decision journal as a JSON document
    /// ([`crate::obs::journal`] schema).
    pub fn journal_json(&self) -> String {
        obs::journal::records_to_json(&self.journal, self.journal_dropped)
    }

    /// The critical-path attribution report as `ANALYSIS.json` (None
    /// unless collection ran).
    pub fn analysis_json(&self) -> Option<String> {
        self.analysis.as_ref().map(|a| a.to_json())
    }
}

struct WorkerOut {
    rank: usize,
    /// FNV-1a of the reduced gradient, one per completed step.
    hashes: Vec<u64>,
    trace: Vec<LiveStepRecord>,
    decreases: u64,
    increases: u64,
    final_ratio: f64,
    /// Died on schedule (partial trace is expected and legal).
    killed: bool,
    recoveries: u64,
    lost_intervals: u64,
    spans: Vec<SpanRecord>,
    spans_dropped: u64,
    journal: Vec<DecisionRecord>,
    journal_dropped: u64,
    /// Aborted mid-loop with this error (flight recorder: the fields
    /// above hold everything recorded up to the failure).
    error: Option<String>,
    /// Rank 0 only: the gathered telemetry (own + each live peer's).
    collected: Vec<RankTelemetry>,
    /// Rank 0 only: estimated per-peer clock offsets, indexed by rank.
    offsets_ns: Vec<i64>,
    collect_notes: Vec<String>,
}

/// Run a live training exchange; blocks until every worker finishes.
pub fn run_live(opts: &LiveOpts) -> Result<LiveReport> {
    assert!(opts.n_workers >= 1, "need at least one worker");
    if opts.faults.kill_step(0).is_some() {
        return Err(anyhow!(
            "rank 0 cannot be scheduled to die — it carries the report \
             (kill ranks 1..n_workers instead)"
        ));
    }
    if let Some(r) = opts.faults.max_rank() {
        if r >= opts.n_workers {
            return Err(anyhow!(
                "fault schedule names rank {r} but the group has {} workers",
                opts.n_workers
            ));
        }
    }
    if opts.poller_threads > 0 {
        crate::util::poller::configure_threads(opts.poller_threads);
    }
    let t0 = Instant::now();
    let outs = match &opts.backend {
        LiveBackend::Loopback => {
            let mesh = LoopbackTransport::mesh(opts.n_workers);
            spawn_and_join(
                mesh.into_iter()
                    .map(|t| {
                        let opts = opts.clone();
                        move || boxed(t, &opts)
                    })
                    .collect(),
                opts,
                t0,
            )?
        }
        LiveBackend::Tcp { bind } => {
            let listener = TcpTransport::bind_rendezvous(bind)?;
            let addr = listener.local_addr()?.to_string();
            let world = opts.n_workers;
            let mut builders: Vec<Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send>> =
                Vec::new();
            let opts0 = opts.clone();
            builders.push(Box::new(move || {
                Ok(boxed(TcpTransport::host(listener, world)?, &opts0))
            }));
            for rank in 1..world {
                let addr = addr.clone();
                let opts_r = opts.clone();
                builders.push(Box::new(move || {
                    Ok(boxed(TcpTransport::join(&addr, rank, world)?, &opts_r))
                }));
            }
            spawn_and_join_boxed(builders, opts, t0)?
        }
    };
    let wall_s = t0.elapsed().as_secs_f64();

    let rank0 = outs
        .iter()
        .find(|o| o.rank == 0)
        .ok_or_else(|| anyhow!("rank 0 produced no output"))?;
    // Survivors must match rank 0 bit-for-bit on every step; a killed or
    // aborted rank must match on the prefix it lived through.
    let consistent = outs.iter().all(|o| {
        let k = o.hashes.len().min(rank0.hashes.len());
        o.hashes[..k] == rank0.hashes[..k]
            && (o.killed || o.error.is_some() || o.hashes.len() == rank0.hashes.len())
    });
    // Merge every rank's span ring into one start-ordered timeline. The
    // joined worker outputs all share `t0` as their clock origin, so a
    // plain sort lines the ranks up; when the gather ran, rank 0's
    // collected telemetry (which, unlike joined outputs, survives
    // multi-process deployments) is merged through the estimated clock
    // offsets instead.
    let collected = !rank0.collected.is_empty();
    let clock_offsets_ns = if collected {
        rank0.offsets_ns.clone()
    } else {
        Vec::new()
    };
    let spans: Vec<SpanRecord> = if collected {
        let mut per_rank: Vec<Vec<SpanRecord>> = vec![Vec::new(); opts.n_workers];
        for tel in &rank0.collected {
            if let Some(slot) = per_rank.get_mut(tel.rank) {
                slot.extend(tel.spans.iter().copied());
            }
        }
        merge_aligned(&per_rank, &clock_offsets_ns)
    } else {
        let mut spans: Vec<SpanRecord> =
            outs.iter().flat_map(|o| o.spans.iter().copied()).collect();
        spans.sort_by_key(|s| (s.start_ns, s.rank, s.id));
        spans
    };
    if let Some(max_abs) = clock_offsets_ns.iter().map(|o| o.abs()).max() {
        obs::hot().clock_offset_ns.set(max_abs as f64);
    }
    let analysis = if collected && !spans.is_empty() {
        Some(analyze(
            &spans,
            &rank0.journal,
            opts.n_workers,
            (opts.n_params * 4) as u64,
        ))
    } else {
        None
    };
    let mut journal = rank0.journal.clone();
    if let Some(a) = &analysis {
        let verdicts = a.verdict_records(&journal);
        journal.extend(verdicts);
    }
    let mut journals: Vec<Vec<DecisionRecord>> = vec![Vec::new(); opts.n_workers];
    for o in &outs {
        if let Some(slot) = journals.get_mut(o.rank) {
            slot.clone_from(&o.journal);
        }
    }
    Ok(LiveReport {
        spans,
        spans_dropped: outs.iter().map(|o| o.spans_dropped).sum(),
        journal,
        journal_dropped: rank0.journal_dropped,
        journals,
        clock_offsets_ns,
        worker_errors: outs
            .iter()
            .filter_map(|o| o.error.as_ref().map(|e| format!("rank {}: {e}", o.rank)))
            .collect(),
        collect_notes: outs
            .iter()
            .flat_map(|o| o.collect_notes.iter().cloned())
            .collect(),
        analysis,
        consistent,
        final_ratio: rank0.final_ratio,
        controller_decreases: rank0.decreases,
        controller_increases: rank0.increases,
        wall_s,
        recoveries: rank0.recoveries,
        lost_intervals: rank0.lost_intervals,
        final_live: rank0
            .trace
            .last()
            .map(|r| r.live)
            .unwrap_or(opts.n_workers),
        steps: rank0.trace.clone(),
    })
}

/// Wrap an endpoint in the configured shaping (if any) and box it.
fn boxed<T: Transport + 'static>(t: T, opts: &LiveOpts) -> Box<dyn Transport> {
    match &opts.shaping {
        Some(cfg) => Box::new(ShapedTransport::new(t, cfg.clone())),
        None => Box::new(t),
    }
}

fn spawn_and_join(
    builders: Vec<impl FnOnce() -> Box<dyn Transport> + Send + 'static>,
    opts: &LiveOpts,
    origin: Instant,
) -> Result<Vec<WorkerOut>> {
    spawn_and_join_boxed(
        builders
            .into_iter()
            .map(|b| -> Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send> {
                Box::new(move || Ok(b()))
            })
            .collect(),
        opts,
        origin,
    )
}

fn spawn_and_join_boxed(
    builders: Vec<Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send>>,
    opts: &LiveOpts,
    origin: Instant,
) -> Result<Vec<WorkerOut>> {
    let handles: Vec<_> = builders
        .into_iter()
        .map(|b| {
            let opts = opts.clone();
            std::thread::spawn(move || -> Result<WorkerOut> { run_worker(b()?, &opts, origin) })
        })
        .collect();
    // Join every thread before surfacing any error — returning early
    // would leave the survivors detached, still holding sockets/ports
    // while they wait out their own timeouts.
    let mut outs = Vec::with_capacity(handles.len());
    let mut first_err = None;
    for h in handles {
        match h.join() {
            Ok(Ok(out)) => outs.push(out),
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => first_err = first_err.or_else(|| Some(anyhow!("worker thread panicked"))),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(outs),
    }
}

/// Decode one dense elastic block (raw little-endian f32s) into `acc`.
fn accumulate_dense(acc: &mut [f32], block: &[u8]) -> Result<()> {
    if block.len() != acc.len() * 4 {
        return Err(anyhow!(
            "dense block of {} bytes for a {}-element tensor",
            block.len(),
            acc.len()
        ));
    }
    for (a, b) in acc.iter_mut().zip(block.chunks_exact(4)) {
        *a += f32::from_le_bytes(b.try_into().unwrap());
    }
    Ok(())
}

/// One worker's whole run: the elastic training loop.
fn run_worker(t: Box<dyn Transport>, opts: &LiveOpts, origin: Instant) -> Result<WorkerOut> {
    let rank = t.rank();
    let np = opts.n_params;
    let started = Instant::now();

    // Telemetry: per-rank span ring (all ranks share `origin`, so the
    // merged timeline lines up), rank 0's decision journal, and the
    // always-on metric handles. Everything here is preallocated — the
    // training loop below stays allocation-free with telemetry enabled
    // (gated by the `obs` zero-alloc test).
    let mut tracer = if opts.obs.trace {
        Tracer::new(rank, opts.obs.trace_capacity, origin)
    } else {
        Tracer::disabled()
    };
    let mut journal = if opts.obs.journal {
        DecisionJournal::with_capacity(2 * opts.steps + 8)
    } else {
        DecisionJournal::disabled()
    };
    let om = obs::hot();

    // Fault layer: the injector executes this rank's chaos slice (a
    // pass-through when none is scheduled); membership + elastic exchange
    // carry the group through whatever it does to the others.
    let mut t = FaultInjector::new(t, opts.faults.specs_for(rank));
    t.set_recv_timeout(opts.fault.recv_timeout());
    let mut membership = Membership::new(rank, opts.n_workers);
    let mut exchange = ElasticExchange::new(&membership, opts.fault.clone());

    // Weights are replica-identical (stream independent of rank);
    // gradients drift per rank.
    let mut weights = vec![0f32; np];
    Pcg64::new(opts.seed, 0x77ee).fill_normal_f32(&mut weights, 0.0, 0.1);
    let mut grng = Pcg64::new(opts.seed, rank as u64);
    let mut grads = vec![0f32; np];
    grng.fill_normal_f32(&mut grads, 0.0, 1.0);

    let mut controller = opts.strategy.controller_config().map(RatioController::new);
    let mut compressor = opts
        .strategy
        .compression_config()
        .map(|c| NetSenseCompressor::new(np, c));
    // Fused-path scratch, wire buffer, and dense accumulator — all reused
    // across every step (§Perf: neither the steady-state send side nor
    // the decode-reduce side allocates per step; the exchange's round
    // buffers recycle too).
    let mut ws = Workspace::new();
    let mut wire: Vec<u8> = Vec::new();
    let mut mean = vec![0f32; np];

    let mut hashes = Vec::with_capacity(opts.steps);
    let mut trace = Vec::with_capacity(opts.steps);
    let mut killed = false;
    let mut worker_error: Option<String> = None;
    let mut recoveries = 0u64;
    let mut lost_intervals = 0u64;
    for step in 0..opts.steps {
        t.on_step(step);
        if t.is_killed() {
            killed = true;
            break;
        }
        if opts.compute_ms > 0 {
            std::thread::sleep(Duration::from_millis(opts.compute_ms));
        }
        // Drift the gradient a little each step (steady-state top-k).
        for x in grads.iter_mut() {
            *x += 0.05 * grng.normal() as f32;
        }
        let ratio = match (&controller, &opts.strategy) {
            (Some(c), _) => c.ratio(),
            (None, SyncStrategy::TopK(r)) => *r,
            (None, _) => 1.0,
        };
        let sp_step = tracer.start("step", step as u32);
        let sp_compress = tracer.start("compress", step as u32);
        let t_compress = Instant::now();
        wire.clear();
        match compressor.as_mut() {
            Some(comp) => {
                comp.compress_payload_into(&grads, &weights, ratio, &mut ws, &mut wire);
            }
            None => {
                // Dense baseline: the raw tensor as the elastic payload.
                // NOTE: this all-gathers the full tensor ((n−1)·4·np
                // bytes per rank) where the pre-elastic baseline ran a
                // ring all-reduce (2(n−1)/n·4·np — n/2× less wire) — the
                // price of fault tolerance on the dense path, stated
                // wherever dense round times are compared (EXPERIMENTS.md).
                for x in &grads {
                    wire.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        // Fused receive: the exchange hands every live rank's payload
        // (own included, rank order) as a borrowed slice; sparse payloads
        // scatter straight into the reused dense accumulator, dense
        // baselines accumulate raw f32 blocks. Same adds in the same
        // order as the old decode → sparse-sum path — bit-identical.
        om.compress_ns
            .observe(t_compress.elapsed().as_nanos() as u64);
        tracer.end(sp_compress);
        let mut max_payload = 0u64;
        let sparse = compressor.is_some();
        mean.iter_mut().for_each(|m| *m = 0.0);
        let sp_round = tracer.start("round", step as u32);
        let round = {
            let mean = &mut mean;
            let tr = &mut tracer;
            exchange.round_reduce(&mut t, &mut membership, step as u32, &wire, |_, b| {
                max_payload = max_payload.max(b.len() as u64);
                let sp_decode = tr.start("decode", step as u32);
                let t_decode = Instant::now();
                let r = if sparse {
                    decode_reduce_into(b, mean).map_err(|e| anyhow!("{e}"))
                } else {
                    accumulate_dense(mean, b)
                };
                om.decode_ns.observe(t_decode.elapsed().as_nanos() as u64);
                tr.end(sp_decode);
                r
            })
        };
        // The round's wire-blocked time (recv waits, send backpressure,
        // shaping/fault pacing) as a backdated child of `round` — the
        // trace's compute-vs-wire split per step.
        tracer.record_backdated("evloop", step as u32, t.take_wire_wait_ns());
        tracer.end(sp_round);
        let round = match round {
            // A rank killed mid-round (e.g. a torn partial write) can
            // still "complete" the round solo: its probe sends all fail,
            // it removes everyone, and replays alone. That round is a
            // dead rank's hallucination — discard it before it pollutes
            // the hash/trace and desyncs the netsim mirror.
            Ok(_) if t.is_killed() => {
                killed = true;
                break;
            }
            Ok(r) => r,
            Err(_) if t.is_killed() => {
                killed = true;
                break;
            }
            Err(e) => {
                // Flight recorder: don't throw the telemetry away with
                // the error — break out with everything recorded up to
                // the failure still in the rings, so the report (and the
                // gather, on the surviving side) can carry it.
                worker_error = Some(format!("{e:#}"));
                break;
            }
        };
        recoveries += round.recoveries;
        if round.lost {
            lost_intervals += 1;
        }
        if round.recoveries > 0 {
            // Zero-width marker: the recovery itself ran inside the round
            // span; its latency lands in the `recovery_us` histogram.
            let sp = tracer.start("recovery", step as u32);
            tracer.end(sp);
        }
        om.rtt_us.observe(round.elapsed.as_micros() as u64);
        let scale = 1.0 / round.n_blocks.max(1) as f32;
        for m in mean.iter_mut() {
            *m *= scale;
        }
        journal.push(DecisionRecord {
            kind: DecisionKind::Round,
            rank,
            step: step as u32,
            epoch: round.epoch as u32,
            live: membership.n_live(),
            rtt_us: round.elapsed.as_micros() as u64,
            payload_bytes: max_payload,
            lost: round.lost,
            recoveries: round.recoveries as u32,
            dropped_stale: round.dropped_stale as u32,
            dropped_garbage: round.dropped_garbage as u32,
            ..DecisionRecord::default()
        });
        if round.recoveries > 0 {
            journal.push(DecisionRecord {
                kind: DecisionKind::Membership,
                rank,
                step: step as u32,
                epoch: round.epoch as u32,
                live: membership.n_live(),
                recoveries: round.recoveries as u32,
                ..DecisionRecord::default()
            });
        }
        if let Some(ctl) = controller.as_mut() {
            // The paper's Algorithm 1 observation: this interval's data
            // size, its measured transfer-completion time, and whether
            // anything was lost (deadline abort / membership recovery) —
            // the live wiring of the controller's backoff trigger.
            let rtt = SimTime::from_secs_f64(round.elapsed.as_secs_f64().max(1e-6));
            ctl.on_interval(max_payload.max(1), rtt, round.lost);
            if let Some(tr) = ctl.last_transition() {
                match tr.branch {
                    Branch::Backoff => om.ctl_backoffs_total.inc(),
                    Branch::Increase | Branch::StartupRamp => om.ctl_increases_total.inc(),
                    Branch::Hold => {}
                }
                journal.push(DecisionRecord {
                    kind: DecisionKind::Ratio,
                    rank,
                    step: step as u32,
                    epoch: round.epoch as u32,
                    live: membership.n_live(),
                    rtt_us: (tr.rtt.as_secs_f64() * 1e6) as u64,
                    payload_bytes: tr.data_size_bytes,
                    lost: tr.lost,
                    phase_netsense: tr.phase_after == Phase::NetSense,
                    old_ratio: tr.old_ratio,
                    new_ratio: tr.new_ratio,
                    predicted_wire_bytes: compressor
                        .as_ref()
                        .map(|c| c.predict_wire_bytes(tr.new_ratio))
                        .unwrap_or(0),
                    ..DecisionRecord::default()
                });
            }
        }
        if rank == 0 {
            om.ratio.set(
                controller
                    .as_ref()
                    .map(|c| c.ratio())
                    .unwrap_or(ratio),
            );
            om.live_ranks.set(membership.n_live() as f64);
            om.epoch.set(round.epoch as f64);
        }
        hashes.push(hash_f32s(&mean));
        trace.push(LiveStepRecord {
            step,
            at_s: started.elapsed().as_secs_f64(),
            ratio,
            payload_bytes: max_payload,
            round_ms: round.elapsed.as_secs_f64() * 1e3,
            btlbw_mbps: controller
                .as_ref()
                .and_then(|c| c.estimate())
                .map(|e| e.btlbw_bytes_per_sec * 8.0 / 1e6),
            epoch: round.epoch,
            live: membership.n_live(),
            lost: round.lost,
        });
        tracer.end(sp_step);
    }
    let (decreases, increases, final_ratio) = match &controller {
        Some(c) => (c.n_decreases, c.n_increases, c.ratio()),
        None => (0, 0, trace.last().map(|r| r.ratio).unwrap_or(1.0)),
    };

    // Cluster gather — strictly after the training loop, so the hot path
    // (and its zero-alloc gates) never see any of this. Best-effort on
    // both sides: a dead or silent counterpart becomes a note.
    let mut collected: Vec<RankTelemetry> = Vec::new();
    let mut offsets_ns: Vec<i64> = Vec::new();
    let mut collect_notes: Vec<String> = Vec::new();
    if opts.obs.collect && !killed && worker_error.is_none() {
        let own = RankTelemetry {
            rank,
            clock_ns: origin.elapsed().as_nanos() as u64,
            spans: tracer.drain(),
            spans_dropped: tracer.dropped(),
            journal: journal.records().to_vec(),
            journal_dropped: journal.dropped(),
            final_ratio,
            recoveries: recoveries as u32,
            lost_intervals: lost_intervals as u32,
            decreases: decreases as u32,
            increases: increases as u32,
        };
        let timeout = opts.fault.probe_timeout().max(Duration::from_millis(500));
        if rank == 0 {
            let peers: Vec<usize> = (1..opts.n_workers)
                .filter(|&r| membership.is_live(r))
                .collect();
            let pc = gather_at_rank0(&mut t, origin, &peers, timeout);
            offsets_ns = pc.offsets_ns;
            collect_notes = pc.notes;
            collected.push(own);
            collected.extend(pc.telemetry);
        } else if membership.is_live(0) {
            if let Err(e) = respond_to_collector(&mut t, origin, &own, timeout) {
                collect_notes.push(format!("rank {rank}: telemetry hand-off failed: {e:#}"));
            }
        }
    }

    if let Err(e) = t.shutdown() {
        // An aborted worker's shutdown error is secondary — keep the
        // original failure as the story.
        if worker_error.is_none() {
            return Err(e);
        }
    }
    let spans_dropped = tracer.dropped();
    let journal_dropped = journal.dropped();
    Ok(WorkerOut {
        rank,
        hashes,
        trace,
        decreases,
        increases,
        final_ratio,
        killed,
        recoveries,
        lost_intervals,
        spans: tracer.drain(),
        spans_dropped,
        journal: journal.records().to_vec(),
        journal_dropped,
        error: worker_error,
        collected,
        offsets_ns,
        collect_notes,
    })
}

/// FNV-1a over the f32 bit patterns — the cross-rank consistency probe.
fn hash_f32s(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::sim_trajectory;
    use crate::util::json::Json;

    /// THE observability acceptance check (ISSUE): a 4-worker live run
    /// with telemetry on emits (1) a Perfetto-loadable trace with spans
    /// from every rank, (2) a decision journal whose Ratio chain equals
    /// the run's per-step ratio trajectory and whose Round records walk
    /// the run's epoch/live trajectory, and (3) a Prometheus snapshot
    /// carrying the run's counters.
    #[test]
    fn obs_live_run_emits_trace_journal_and_metrics() {
        let opts = LiveOpts {
            n_workers: 4,
            steps: 10,
            n_params: 20_000,
            obs: ObsOpts::all(),
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent);

        // Spans: every rank traced, nothing dropped, all labels present,
        // no negative durations, one "step" span per step on rank 0.
        assert_eq!(report.spans_dropped, 0);
        for rank in 0..4usize {
            assert!(
                report.spans.iter().any(|s| s.rank == rank),
                "rank {rank} produced no spans"
            );
        }
        for label in ["step", "compress", "round", "decode"] {
            assert!(
                report.spans.iter().any(|s| s.label == label),
                "no {label} spans"
            );
        }
        assert!(report.spans.iter().all(|s| s.end_ns >= s.start_ns));
        assert_eq!(
            report
                .spans
                .iter()
                .filter(|s| s.rank == 0 && s.label == "step")
                .count(),
            10
        );
        // The Chrome trace parses and carries every span.
        let doc = Json::parse(&report.trace_json()).expect("trace JSON parses");
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        assert_eq!(events.len(), report.spans.len());

        // Journal: one Ratio record per step; its old→new chain must be
        // exactly the per-step ratio trajectory the run reported.
        let ratios: Vec<&DecisionRecord> = report
            .journal
            .iter()
            .filter(|r| r.kind == DecisionKind::Ratio)
            .collect();
        assert_eq!(ratios.len(), report.steps.len());
        for (s, r) in report.steps.iter().zip(&ratios) {
            assert_eq!(r.old_ratio, s.ratio, "old_ratio mismatch at step {}", s.step);
            assert!(r.predicted_wire_bytes > 0);
            assert!(r.rtt_us > 0);
        }
        for (next, r) in report.steps.iter().skip(1).zip(&ratios) {
            assert_eq!(
                r.new_ratio, next.ratio,
                "new_ratio mismatch before step {}",
                next.step
            );
        }

        // Round records walk the same (epoch, live) trajectory as the
        // run report — i.e. the same story the netsim mirror tells.
        let jt = obs::journal::epoch_trajectory_of(&report.journal);
        let mut st: Vec<(u32, usize)> = Vec::new();
        for s in &report.steps {
            if st.last() != Some(&(s.epoch as u32, s.live)) {
                st.push((s.epoch as u32, s.live));
            }
        }
        assert_eq!(jt, st);
        assert_eq!(report.journal_dropped, 0);
        let jdoc = Json::parse(&report.journal_json()).expect("journal JSON parses");
        assert_eq!(
            jdoc.get("records").and_then(|r| r.as_arr()).unwrap().len(),
            report.journal.len()
        );

        // The registry saw the run.
        let snap = crate::obs::registry().prometheus();
        for name in [
            "netsense_rounds_total",
            "netsense_rtt_us",
            "netsense_round_us",
            "netsense_compress_ns",
            "netsense_decode_ns",
            "netsense_frame_bytes",
            "netsense_ratio",
        ] {
            assert!(snap.contains(name), "{name} missing from snapshot");
        }
    }

    /// The cluster-plane acceptance check (ISSUE): a 4-worker run with
    /// collection on gathers every rank's telemetry to rank 0, estimates
    /// per-peer clock offsets, runs the critical-path analyzer, and the
    /// per-rank journals tell one consistent story: every rank walked
    /// the same epoch/live trajectory (Ratio records are rank-local).
    #[test]
    fn obs_collect_aligns_ranks_and_keeps_journals_consistent() {
        let opts = LiveOpts {
            n_workers: 4,
            steps: 10,
            n_params: 20_000,
            obs: ObsOpts::all(),
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent);
        assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
        assert!(report.collect_notes.is_empty(), "{:?}", report.collect_notes);

        // The gather reached every peer: offsets indexed by rank, rank
        // 0's own entry pinned at zero, and — same process, same clock —
        // every estimate small.
        assert_eq!(report.clock_offsets_ns.len(), 4);
        assert_eq!(report.clock_offsets_ns[0], 0);
        for (r, off) in report.clock_offsets_ns.iter().enumerate() {
            assert!(
                off.abs() < 100_000_000,
                "rank {r} offset {off} ns is implausible for one process"
            );
        }
        // Aligned merge carries all four ranks and stays start-ordered.
        for rank in 0..4usize {
            assert!(
                report.spans.iter().any(|s| s.rank == rank),
                "rank {rank} missing from the merged timeline"
            );
        }
        assert!(report.spans.windows(2).all(|w| w[0].start_ns <= w[1].start_ns));

        // Cross-rank journal consistency: all four journals recorded,
        // and every rank's Round records walk the identical (epoch, live)
        // trajectory — the rank-local records (Ratio) differ, the shared
        // membership story must not.
        assert_eq!(report.journals.len(), 4);
        let t0 = obs::journal::epoch_trajectory_of(&report.journals[0]);
        assert!(!t0.is_empty());
        for (r, j) in report.journals.iter().enumerate() {
            assert!(!j.is_empty(), "rank {r} journal is empty");
            assert_eq!(
                obs::journal::epoch_trajectory_of(j),
                t0,
                "rank {r} walked a different epoch/live trajectory"
            );
            assert_eq!(
                j.iter().filter(|rec| rec.kind == DecisionKind::Round).count(),
                10,
                "rank {r} journaled a different round count"
            );
        }

        // The analyzer ran and its books balance: every step's parts sum
        // to the step's wall time exactly, critical ranks are in range,
        // and the straggler tally counts every attributed round.
        let analysis = report.analysis.as_ref().expect("analysis present");
        assert_eq!(analysis.n_ranks, 4);
        assert_eq!(analysis.steps.len(), 10);
        for b in &analysis.steps {
            assert_eq!(
                b.compute_ns + b.compress_ns + b.wire_ns + b.decode_ns + b.recovery_ns,
                b.wall_ns,
                "step {} attribution does not sum to wall time",
                b.step
            );
            if let Some(r) = b.critical_rank {
                assert!(r < 4);
            }
        }
        let attributed: u64 = analysis.straggler_counts.iter().sum();
        assert_eq!(
            attributed,
            analysis.steps.iter().filter(|b| b.critical_rank.is_some()).count() as u64
        );
        // ANALYSIS.json parses and matches the documented schema.
        let doc = Json::parse(&report.analysis_json().unwrap()).expect("ANALYSIS.json parses");
        assert_eq!(doc.get("schema_version").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(
            doc.get("steps").and_then(|s| s.as_arr()).map(|s| s.len()),
            Some(10)
        );
    }

    /// Two tracers with deliberately skewed clock origins merge into a
    /// monotonic timeline end-to-end through the report path: the offsets
    /// the gather estimated land in the trace metadata.
    #[test]
    fn obs_collect_embeds_offsets_in_trace_metadata() {
        let opts = LiveOpts {
            n_workers: 2,
            steps: 4,
            n_params: 5_000,
            strategy: SyncStrategy::TopK(0.2),
            obs: ObsOpts::all(),
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        let doc = Json::parse(&report.trace_json()).expect("trace parses");
        let offs = doc
            .get("clockOffsetsNs")
            .and_then(|o| o.as_obj())
            .expect("collection runs must embed clockOffsetsNs");
        assert_eq!(offs.len(), 2);
        assert_eq!(offs.get("0").and_then(|v| v.as_f64()), Some(0.0));
        assert!(offs.contains_key("1"));
    }

    #[test]
    fn loopback_netsense_run_is_consistent_and_senses() {
        let opts = LiveOpts {
            n_workers: 4,
            steps: 12,
            n_params: 20_000,
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert_eq!(report.steps.len(), 12);
        assert!(report.consistent, "ranks diverged");
        // The controller ran on measured observables.
        assert!(report.controller_decreases + report.controller_increases >= 12);
        assert!(report.steps.last().unwrap().btlbw_mbps.unwrap() > 0.0);
        // The first adjustment moved the ratio off its initial 0.01.
        assert!(report.steps.iter().any(|r| r.ratio != 0.01));
        // Healthy run: one epoch, everyone alive, nothing lost.
        assert_eq!(report.recoveries, 0);
        assert_eq!(report.lost_intervals, 0);
        assert_eq!(report.final_live, 4);
        assert!(report.steps.iter().all(|r| r.epoch == 0 && r.live == 4));
    }

    #[test]
    fn loopback_dense_and_topk_baselines_run() {
        for strategy in [SyncStrategy::AllReduce, SyncStrategy::TopK(0.1)] {
            let opts = LiveOpts {
                n_workers: 3,
                steps: 5,
                n_params: 9_999,
                strategy: strategy.clone(),
                ..Default::default()
            };
            let report = run_live(&opts).unwrap();
            assert!(report.consistent, "{strategy:?} ranks diverged");
            assert_eq!(report.final_ratio, if strategy == SyncStrategy::AllReduce { 1.0 } else { 0.1 });
        }
    }

    #[test]
    fn tcp_live_run_matches_loopback_payloads() {
        // Same seed and strategy: the reduced gradients must be
        // bit-identical whether bytes moved over channels or sockets.
        let base = LiveOpts {
            n_workers: 2,
            steps: 4,
            n_params: 15_000,
            strategy: SyncStrategy::TopK(0.25),
            ..Default::default()
        };
        let via_loopback = run_live(&base).unwrap();
        let via_tcp = run_live(&LiveOpts {
            backend: LiveBackend::Tcp {
                bind: "127.0.0.1:0".to_string(),
            },
            ..base
        })
        .unwrap();
        assert!(via_loopback.consistent && via_tcp.consistent);
        // Ratios are static (TopK), so the per-step payloads must agree.
        let lp: Vec<u64> = via_loopback.steps.iter().map(|r| r.payload_bytes).collect();
        let tp: Vec<u64> = via_tcp.steps.iter().map(|r| r.payload_bytes).collect();
        assert_eq!(lp, tp);
    }

    /// The ISSUE acceptance check: a shaped live run must show the
    /// controller's ratio dropping after a bandwidth step-down — asserted
    /// purely on measured observables (the shaped wire), never on the
    /// configured rates.
    #[test]
    fn shaped_step_down_drops_the_ratio() {
        let step_at = 0.5;
        let opts = LiveOpts {
            n_workers: 2,
            // Enough steps to straddle the step generously: pre-step
            // rounds run ≥ 6 ms (2 ms compute + 4 ms prop floor), so the
            // step lands near step 60 of 140.
            steps: 140,
            n_params: 50_000,
            strategy: SyncStrategy::NetSense,
            backend: LiveBackend::Loopback,
            shaping: Some(ShapingConfig {
                rate_bytes_per_sec: 8e6,
                burst_bytes: 2_000.0,
                schedule: vec![(step_at, 0.5e6)], // 8 MB/s → 0.5 MB/s
                prop_delay_s: 0.004,
            }),
            compute_ms: 2,
            seed: 7,
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent);
        // Settled ratio on the fast link vs the last steps on the slow
        // one: 16× less measured bandwidth must pull the ratio well down.
        let before = report.mean_ratio_between(0.25, step_at);
        let after = report.mean_ratio_last(5);
        let last = report.steps.last().unwrap();
        assert!(
            last.at_s > step_at + 0.1,
            "run never got past the step-down ({:.2}s)",
            last.at_s
        );
        assert!(
            report.controller_decreases > 0,
            "controller never decreased: {report:?}"
        );
        assert!(
            after < 0.6 * before,
            "ratio did not drop after step-down: {before:.4} → {after:.4}"
        );
    }

    /// THE chaos acceptance check (ISSUE): an N=4 loopback run where the
    /// FaultInjector kills one rank mid-training completes on the 3
    /// survivors, the epoch bump and ring rebuild are asserted on
    /// observables, and the equivalent netsim failure schedule reproduces
    /// the same sync-count trajectory.
    #[test]
    fn chaos_kill_one_rank_mid_training_completes_on_survivors() {
        let kill_step = 6;
        let opts = LiveOpts {
            n_workers: 4,
            steps: 14,
            n_params: 20_000,
            strategy: SyncStrategy::NetSense,
            faults: FaultSchedule {
                kills: vec![(2, kill_step)],
                ..Default::default()
            },
            fault: FaultConfig {
                recv_timeout_ms: 150,
                probe_timeout_ms: 800,
            },
            obs: ObsOpts::all(),
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        // Survivors completed every step, bit-identically.
        assert!(report.consistent, "survivors diverged");
        assert_eq!(report.steps.len(), 14);
        // Exactly one recovery: the epoch bumps at the kill step and the
        // ring rebuilds over the 3 survivors.
        assert_eq!(report.recoveries, 1);
        assert_eq!(report.steps[kill_step - 1].epoch, 0);
        assert_eq!(report.steps[kill_step - 1].live, 4);
        assert_eq!(report.steps[kill_step].epoch, 1);
        assert_eq!(report.steps[kill_step].live, 3);
        assert!(report.steps[kill_step].lost);
        assert_eq!(report.final_live, 3);
        // The lost interval reached the controller (backoff wiring).
        assert_eq!(report.lost_intervals, 1);
        assert!(report.controller_decreases >= 1);
        // Determinism contract: the netsim mirror of the same failure
        // schedule walks the exact same epoch/live-set trajectory.
        let mirror = sim_trajectory(4, 14, &opts.faults, &opts.fault, 20_000);
        assert_eq!(report.trajectory().segments, mirror.segments);
        assert!(mirror.vtime_s > 0.0);
        // The decision journal tells the same story: a Membership record
        // at the kill step and the identical epoch/live walk, plus a
        // zero-width "recovery" marker span on the trace.
        let membership_recs: Vec<&DecisionRecord> = report
            .journal
            .iter()
            .filter(|r| r.kind == DecisionKind::Membership)
            .collect();
        assert_eq!(membership_recs.len(), 1);
        assert_eq!(membership_recs[0].step, kill_step as u32);
        assert_eq!(membership_recs[0].epoch, 1);
        assert_eq!(membership_recs[0].live, 3);
        assert_eq!(
            obs::journal::epoch_trajectory_of(&report.journal),
            vec![(0, 4), (1, 3)]
        );
        assert!(report.spans.iter().any(|s| s.label == "recovery"));
    }

    /// A flapping link long enough to blow the recv deadline: the group
    /// recovers (epoch bump) but the probe round finds everyone alive —
    /// nobody is removed, the round replays, and the run stays
    /// bit-consistent.
    #[test]
    fn chaos_flapping_link_recovers_without_deaths() {
        let opts = LiveOpts {
            n_workers: 3,
            steps: 9,
            n_params: 10_000,
            strategy: SyncStrategy::TopK(0.2),
            faults: FaultSchedule {
                flaps: vec![(1, 4, 500)],
                ..Default::default()
            },
            fault: FaultConfig {
                recv_timeout_ms: 120,
                probe_timeout_ms: 3_000,
            },
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent, "flap broke consistency");
        assert_eq!(report.steps.len(), 9);
        assert_eq!(report.final_live, 3, "flap must not kill anyone");
        assert!(report.recoveries >= 1, "deadline never fired: {report:?}");
        assert!(report.lost_intervals >= 1);
        assert_eq!(report.steps[3].epoch, 0);
        assert!(report.steps[4].epoch >= 1, "epoch must bump at the flap");
        let mirror = sim_trajectory(3, 9, &opts.faults, &opts.fault, 10_000);
        assert_eq!(report.trajectory().segments, mirror.segments);
    }

    /// A straggler below the recv deadline is absorbed as a slow round:
    /// no recovery, no epoch bump, full consistency.
    #[test]
    fn chaos_short_stall_is_absorbed() {
        let opts = LiveOpts {
            n_workers: 3,
            steps: 6,
            n_params: 10_000,
            strategy: SyncStrategy::TopK(0.2),
            faults: FaultSchedule {
                stalls: vec![(1, 3, 50)],
                ..Default::default()
            },
            fault: FaultConfig {
                recv_timeout_ms: 2_000,
                probe_timeout_ms: 2_000,
            },
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent);
        assert_eq!(report.recoveries, 0);
        assert_eq!(report.lost_intervals, 0);
        assert!(report.steps.iter().all(|r| r.epoch == 0 && r.live == 3));
        let mirror = sim_trajectory(3, 6, &opts.faults, &opts.fault, 10_000);
        assert_eq!(report.trajectory().segments, mirror.segments);
    }

    /// The Byzantine schedule end-to-end: a duplicated-frame replay
    /// (absorbed by the epoch/step fencing, no disruption), a reordered
    /// round (recovery without deaths), and a torn partial write followed
    /// by death (rank removed; its garbage fragment rejected by envelope
    /// parse) — and the live `SyncTrajectory` still equals the netsim
    /// mirror segment-for-segment.
    #[test]
    fn chaos_byzantine_schedules_match_netsim_mirror() {
        let opts = LiveOpts {
            n_workers: 4,
            steps: 12,
            n_params: 20_000,
            strategy: SyncStrategy::NetSense,
            faults: FaultSchedule {
                duplicates: vec![(1, 2)],
                reorders: vec![(3, 5)],
                // 5 bytes < the 9-byte envelope: a garbage fragment.
                partial_kills: vec![(2, 8, 5)],
                ..Default::default()
            },
            fault: FaultConfig {
                recv_timeout_ms: 150,
                probe_timeout_ms: 2_000,
            },
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent, "Byzantine chaos broke bit-consistency");
        assert_eq!(report.steps.len(), 12);
        // The duplicate is absorbed: no epoch bump at step 2.
        assert_eq!(report.steps[2].epoch, 0);
        assert_eq!(report.steps[2].live, 4);
        // The reorder forces one recovery but kills nobody.
        assert_eq!(report.steps[5].epoch, 1);
        assert_eq!(report.steps[5].live, 4);
        // The torn write kills rank 2 — and only rank 2.
        assert_eq!(report.steps[8].epoch, 2);
        assert_eq!(report.steps[8].live, 3);
        assert_eq!(report.final_live, 3);
        assert_eq!(report.recoveries, 2);
        // Determinism contract, extended to the Byzantine classes: the
        // netsim replay walks the identical trajectory.
        let mirror = sim_trajectory(4, 12, &opts.faults, &opts.fault, 20_000);
        assert_eq!(report.trajectory().segments, mirror.segments);
        use crate::fault::TrajectorySegment as Seg;
        assert_eq!(
            mirror.segments,
            vec![
                Seg { epoch: 0, group_size: 4, syncs: 5 },
                Seg { epoch: 1, group_size: 4, syncs: 3 },
                Seg { epoch: 2, group_size: 3, syncs: 4 },
            ]
        );
    }

    /// The same kill scenario over real sockets: the reader-thread
    /// disconnect observation (not a timeout cascade) drives the
    /// recovery, and survivors stay bit-identical.
    #[test]
    fn chaos_kill_over_tcp_mesh() {
        let opts = LiveOpts {
            n_workers: 3,
            steps: 8,
            n_params: 8_000,
            strategy: SyncStrategy::TopK(0.25),
            backend: LiveBackend::Tcp {
                bind: "127.0.0.1:0".to_string(),
            },
            faults: FaultSchedule {
                kills: vec![(2, 3)],
                ..Default::default()
            },
            fault: FaultConfig {
                recv_timeout_ms: 400,
                probe_timeout_ms: 1_500,
            },
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent, "tcp survivors diverged");
        assert_eq!(report.steps.len(), 8);
        assert_eq!(report.recoveries, 1);
        assert_eq!(report.final_live, 2);
        assert_eq!(report.steps[3].epoch, 1);
    }

    /// This process's current thread count, from `/proc/self/status`.
    fn thread_count() -> usize {
        std::fs::read_to_string("/proc/self/status")
            .ok()
            .and_then(|s| {
                s.lines()
                    .find_map(|l| l.strip_prefix("Threads:"))
                    .and_then(|v| v.trim().parse().ok())
            })
            .expect("parse Threads: from /proc/self/status")
    }

    /// THE scale acceptance check (ISSUE): 16 workers over real TCP — 120
    /// socket pairs — multiplexed on the shared event-loop pool instead
    /// of thread-per-peer readers. Asserts (a) the run's peak thread
    /// count stays within workers + pool (the old design spawned one
    /// reader thread per connection end: 16·15 = 240 extra), and (b) the
    /// epoch/live trajectory and per-step payloads are bit-identical to
    /// the same run over loopback. The steady-state zero-alloc gate for
    /// this path is `steady_state_send_recv_is_alloc_free_on_caller_thread`
    /// in `transport::tcp`.
    #[test]
    fn scale_16_workers_bounded_threads_and_loopback_identical() {
        let base = LiveOpts {
            n_workers: 16,
            steps: 4,
            n_params: 4_000,
            strategy: SyncStrategy::TopK(0.25),
            ..Default::default()
        };
        let via_loopback = run_live(&base).unwrap();

        // Sample the process's peak thread count while the TCP run is in
        // flight (the 16 worker threads live for the whole run, so a
        // coarse cadence cannot miss them).
        use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let peak = std::sync::Arc::new(AtomicUsize::new(0));
        let sampler = {
            let stop = stop.clone();
            let peak = peak.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    peak.fetch_max(thread_count(), Ordering::Relaxed);
                    std::thread::sleep(Duration::from_micros(200));
                }
            })
        };
        let before = thread_count();
        let via_tcp = run_live(&LiveOpts {
            backend: LiveBackend::Tcp {
                bind: "127.0.0.1:0".to_string(),
            },
            ..base.clone()
        })
        .unwrap();
        stop.store(true, Ordering::Relaxed);
        sampler.join().unwrap();
        let peak = peak.load(Ordering::Relaxed);

        // (a) Thread budget: the 16 worker threads plus the (process-
        // global, possibly already-running) event-loop pool, over the
        // pre-run baseline, with slack for whatever other tests in this
        // binary spawn concurrently. Thread-per-peer readers would blow
        // this bound by an order of magnitude.
        let pool = crate::util::poller::Poller::global().pool_size();
        let budget = before + base.n_workers + pool + 16;
        assert!(
            peak <= budget,
            "peak {peak} threads > budget {budget} \
             (baseline {before}, pool {pool}, workers {})",
            base.n_workers
        );

        // (b) Same story over sockets as over channels, bit for bit.
        assert!(via_loopback.consistent && via_tcp.consistent);
        assert_eq!(
            via_tcp.trajectory().segments,
            via_loopback.trajectory().segments
        );
        let lp: Vec<u64> = via_loopback.steps.iter().map(|r| r.payload_bytes).collect();
        let tp: Vec<u64> = via_tcp.steps.iter().map(|r| r.payload_bytes).collect();
        assert_eq!(lp, tp);
    }

    #[test]
    fn fault_schedule_validation_fails_loudly() {
        // Rank 0 carries the report: killing it is a config error.
        let e = run_live(&LiveOpts {
            faults: FaultSchedule {
                kills: vec![(0, 1)],
                ..Default::default()
            },
            steps: 2,
            n_params: 10,
            ..Default::default()
        })
        .unwrap_err();
        assert!(format!("{e}").contains("rank 0"), "{e}");
        // Out-of-range ranks too.
        let e = run_live(&LiveOpts {
            n_workers: 2,
            faults: FaultSchedule {
                stalls: vec![(5, 1, 10)],
                ..Default::default()
            },
            steps: 2,
            n_params: 10,
            ..Default::default()
        })
        .unwrap_err();
        assert!(format!("{e}").contains("rank 5"), "{e}");
    }
}
