//! Live multi-worker training over the real transport layer — the
//! counterpart of the paper's physical testbed runs (§5 setup), with the
//! simulator nowhere in the loop.
//!
//! Every worker runs in its own thread with its own rank-level
//! [`Transport`] endpoint, its own Algorithm-2 compressor, and its own
//! Algorithm-1 [`RatioController`] fed exclusively by *measured*
//! observables: the bytes it saw move and the wall-clock time its ring
//! round took. Nothing in this module reads configured rates — shaped
//! runs demonstrate that the controller reacts to what the wire actually
//! does, which is the paper's central claim.
//!
//! Per step, per worker (sparse strategies): drifting synthetic gradients
//! → fused Algorithm 2 straight into a reused wire buffer
//! ([`NetSenseCompressor::compress_payload_into`] — the send side never
//! materializes a [`SparseGradient`] and allocates nothing in steady
//! state) → framed ring all-gather ([`ring_allgather_frames`]) → decode +
//! sparse-sum → controller observation. The dense baseline uses the real
//! [`ring_allreduce_f32`] instead. Reduced gradients are hashed per step
//! and compared across ranks at the end — a live run must stay
//! bit-identical across workers.

use crate::compress::{NetSenseCompressor, SparseGradient, Workspace};
use crate::collectives::sum_sparse;
use crate::coordinator::SyncStrategy;
use crate::netsim::SimTime;
use crate::sensing::RatioController;
use crate::transport::{
    ring_allgather_frames, ring_allreduce_f32, LoopbackTransport, ShapedTransport, ShapingConfig,
    TcpTransport, Transport,
};
use crate::util::error::{anyhow, Result};
use crate::util::rng::Pcg64;
use std::time::{Duration, Instant};

/// Which sockets a live run uses.
#[derive(Clone, Debug, PartialEq)]
pub enum LiveBackend {
    /// In-process channels (deterministic; the default for tests).
    Loopback,
    /// Localhost TCP mesh with a rank-0 rendezvous at `bind`.
    Tcp { bind: String },
}

/// Configuration of one live run.
#[derive(Clone, Debug)]
pub struct LiveOpts {
    pub n_workers: usize,
    pub steps: usize,
    /// Flat gradient length per worker.
    pub n_params: usize,
    pub strategy: SyncStrategy,
    pub backend: LiveBackend,
    /// Token-bucket shaping applied to every worker's endpoint (None =
    /// unshaped).
    pub shaping: Option<ShapingConfig>,
    /// Simulated local fwd+bwd time per step (thread sleep).
    pub compute_ms: u64,
    pub seed: u64,
}

impl Default for LiveOpts {
    fn default() -> Self {
        LiveOpts {
            n_workers: 2,
            steps: 30,
            n_params: 100_000,
            strategy: SyncStrategy::NetSense,
            backend: LiveBackend::Loopback,
            shaping: None,
            compute_ms: 0,
            seed: 42,
        }
    }
}

/// One step of rank 0's telemetry.
#[derive(Clone, Debug)]
pub struct LiveStepRecord {
    pub step: usize,
    /// Wall-clock offset since the worker started, seconds.
    pub at_s: f64,
    /// Compression ratio used this step (1.0 = dense).
    pub ratio: f64,
    /// Largest payload any rank contributed (bytes).
    pub payload_bytes: u64,
    /// Measured ring-round time, milliseconds.
    pub round_ms: f64,
    /// Sensed bottleneck bandwidth, Mbps (None before first estimate).
    pub btlbw_mbps: Option<f64>,
}

/// What one live run produced.
#[derive(Clone, Debug)]
pub struct LiveReport {
    /// Rank 0's per-step trace.
    pub steps: Vec<LiveStepRecord>,
    /// Did every rank's reduced gradient match bit-for-bit, every step?
    pub consistent: bool,
    pub final_ratio: f64,
    pub controller_decreases: u64,
    pub controller_increases: u64,
    pub wall_s: f64,
}

impl LiveReport {
    /// Mean ratio of the last `n` steps.
    pub fn mean_ratio_last(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(|r| r.ratio).sum::<f64>() / tail.len() as f64
    }

    /// Mean ratio of the steps whose wall offset falls in `[t0_s, t1_s)`.
    pub fn mean_ratio_between(&self, t0_s: f64, t1_s: f64) -> f64 {
        let window: Vec<f64> = self
            .steps
            .iter()
            .filter(|r| r.at_s >= t0_s && r.at_s < t1_s)
            .map(|r| r.ratio)
            .collect();
        if window.is_empty() {
            return 0.0;
        }
        window.iter().sum::<f64>() / window.len() as f64
    }
}

struct WorkerOut {
    rank: usize,
    /// FNV-1a of the reduced gradient, one per step.
    hashes: Vec<u64>,
    trace: Vec<LiveStepRecord>,
    decreases: u64,
    increases: u64,
    final_ratio: f64,
}

/// Run a live training exchange; blocks until every worker finishes.
pub fn run_live(opts: &LiveOpts) -> Result<LiveReport> {
    assert!(opts.n_workers >= 1, "need at least one worker");
    let t0 = Instant::now();
    let outs = match &opts.backend {
        LiveBackend::Loopback => {
            let mesh = LoopbackTransport::mesh(opts.n_workers);
            spawn_and_join(
                mesh.into_iter()
                    .map(|t| {
                        let opts = opts.clone();
                        move || boxed(t, &opts)
                    })
                    .collect(),
                opts,
            )?
        }
        LiveBackend::Tcp { bind } => {
            let listener = TcpTransport::bind_rendezvous(bind)?;
            let addr = listener.local_addr()?.to_string();
            let world = opts.n_workers;
            let mut builders: Vec<Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send>> =
                Vec::new();
            let opts0 = opts.clone();
            builders.push(Box::new(move || {
                Ok(boxed(TcpTransport::host(listener, world)?, &opts0))
            }));
            for rank in 1..world {
                let addr = addr.clone();
                let opts_r = opts.clone();
                builders.push(Box::new(move || {
                    Ok(boxed(TcpTransport::join(&addr, rank, world)?, &opts_r))
                }));
            }
            spawn_and_join_boxed(builders, opts)?
        }
    };
    let wall_s = t0.elapsed().as_secs_f64();

    let rank0 = outs
        .iter()
        .find(|o| o.rank == 0)
        .ok_or_else(|| anyhow!("rank 0 produced no output"))?;
    let consistent = outs.iter().all(|o| o.hashes == rank0.hashes);
    Ok(LiveReport {
        steps: rank0.trace.clone(),
        consistent,
        final_ratio: rank0.final_ratio,
        controller_decreases: rank0.decreases,
        controller_increases: rank0.increases,
        wall_s,
    })
}

/// Wrap an endpoint in the configured shaping (if any) and box it.
fn boxed<T: Transport + 'static>(t: T, opts: &LiveOpts) -> Box<dyn Transport> {
    match &opts.shaping {
        Some(cfg) => Box::new(ShapedTransport::new(t, cfg.clone())),
        None => Box::new(t),
    }
}

fn spawn_and_join(
    builders: Vec<impl FnOnce() -> Box<dyn Transport> + Send + 'static>,
    opts: &LiveOpts,
) -> Result<Vec<WorkerOut>> {
    spawn_and_join_boxed(
        builders
            .into_iter()
            .map(|b| -> Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send> {
                Box::new(move || Ok(b()))
            })
            .collect(),
        opts,
    )
}

fn spawn_and_join_boxed(
    builders: Vec<Box<dyn FnOnce() -> Result<Box<dyn Transport>> + Send>>,
    opts: &LiveOpts,
) -> Result<Vec<WorkerOut>> {
    let handles: Vec<_> = builders
        .into_iter()
        .map(|b| {
            let opts = opts.clone();
            std::thread::spawn(move || -> Result<WorkerOut> {
                let mut t = b()?;
                let out = run_worker(t.as_mut(), &opts);
                t.shutdown()?;
                out
            })
        })
        .collect();
    // Join every thread before surfacing any error — returning early
    // would leave the survivors detached, still holding sockets/ports
    // while they wait out their own timeouts.
    let mut outs = Vec::with_capacity(handles.len());
    let mut first_err = None;
    for h in handles {
        match h.join() {
            Ok(Ok(out)) => outs.push(out),
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => first_err = first_err.or_else(|| Some(anyhow!("worker thread panicked"))),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(outs),
    }
}

/// One worker's whole run (generic over the transport object).
fn run_worker(t: &mut dyn Transport, opts: &LiveOpts) -> Result<WorkerOut> {
    let rank = t.rank();
    let n = t.group_size();
    let np = opts.n_params;
    let started = Instant::now();

    // Weights are replica-identical (stream independent of rank);
    // gradients drift per rank.
    let mut weights = vec![0f32; np];
    Pcg64::new(opts.seed, 0x77ee).fill_normal_f32(&mut weights, 0.0, 0.1);
    let mut grng = Pcg64::new(opts.seed, rank as u64);
    let mut grads = vec![0f32; np];
    grng.fill_normal_f32(&mut grads, 0.0, 1.0);

    let mut controller = opts.strategy.controller_config().map(RatioController::new);
    let mut compressor = opts
        .strategy
        .compression_config()
        .map(|c| NetSenseCompressor::new(np, c));
    // Fused-path scratch + wire buffer, reused across every step (§Perf:
    // the steady-state send side allocates nothing).
    let mut ws = Workspace::new();
    let mut wire: Vec<u8> = Vec::new();

    let mut hashes = Vec::with_capacity(opts.steps);
    let mut trace = Vec::with_capacity(opts.steps);
    for step in 0..opts.steps {
        if opts.compute_ms > 0 {
            std::thread::sleep(Duration::from_millis(opts.compute_ms));
        }
        // Drift the gradient a little each step (steady-state top-k).
        for x in grads.iter_mut() {
            *x += 0.05 * grng.normal() as f32;
        }
        let (mean, ratio, payload_bytes, elapsed) = match compressor.as_mut() {
            Some(comp) => {
                let ratio = match (&controller, &opts.strategy) {
                    (Some(c), _) => c.ratio(),
                    (None, SyncStrategy::TopK(r)) => *r,
                    (None, _) => 1.0,
                };
                wire.clear();
                comp.compress_payload_into(&grads, &weights, ratio, &mut ws, &mut wire);
                let (blocks, timing) = ring_allgather_frames(t, &wire)?;
                let mut payloads = Vec::with_capacity(n);
                let mut max_payload = 0u64;
                for b in &blocks {
                    max_payload = max_payload.max(b.len() as u64);
                    payloads.push(SparseGradient::decode(b).map_err(|e| anyhow!("{e}"))?);
                }
                let mut mean = sum_sparse(np, &payloads);
                let scale = 1.0 / n as f32;
                for m in mean.iter_mut() {
                    *m *= scale;
                }
                (mean, ratio, max_payload, timing.elapsed)
            }
            None => {
                // Dense baseline: a real ring all-reduce of the raw tensor.
                let mut data = grads.clone();
                let timing = ring_allreduce_f32(t, &mut data)?;
                let scale = 1.0 / n as f32;
                for d in data.iter_mut() {
                    *d *= scale;
                }
                (data, 1.0, 4 * np as u64, timing.elapsed)
            }
        };
        if let Some(ctl) = controller.as_mut() {
            // The paper's Algorithm 1 observation: this interval's data
            // size and its measured transfer-completion time.
            let rtt = SimTime::from_secs_f64(elapsed.as_secs_f64().max(1e-6));
            ctl.on_interval(payload_bytes.max(1), rtt, false);
        }
        hashes.push(hash_f32s(&mean));
        trace.push(LiveStepRecord {
            step,
            at_s: started.elapsed().as_secs_f64(),
            ratio,
            payload_bytes,
            round_ms: elapsed.as_secs_f64() * 1e3,
            btlbw_mbps: controller
                .as_ref()
                .and_then(|c| c.estimate())
                .map(|e| e.btlbw_bytes_per_sec * 8.0 / 1e6),
        });
    }
    let (decreases, increases, final_ratio) = match &controller {
        Some(c) => (c.n_decreases, c.n_increases, c.ratio()),
        None => (0, 0, trace.last().map(|r| r.ratio).unwrap_or(1.0)),
    };
    Ok(WorkerOut {
        rank,
        hashes,
        trace,
        decreases,
        increases,
        final_ratio,
    })
}

/// FNV-1a over the f32 bit patterns — the cross-rank consistency probe.
fn hash_f32s(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_netsense_run_is_consistent_and_senses() {
        let opts = LiveOpts {
            n_workers: 4,
            steps: 12,
            n_params: 20_000,
            ..Default::default()
        };
        let report = run_live(&opts).unwrap();
        assert_eq!(report.steps.len(), 12);
        assert!(report.consistent, "ranks diverged");
        // The controller ran on measured observables.
        assert!(report.controller_decreases + report.controller_increases >= 12);
        assert!(report.steps.last().unwrap().btlbw_mbps.unwrap() > 0.0);
        // The first adjustment moved the ratio off its initial 0.01.
        assert!(report.steps.iter().any(|r| r.ratio != 0.01));
    }

    #[test]
    fn loopback_dense_and_topk_baselines_run() {
        for strategy in [SyncStrategy::AllReduce, SyncStrategy::TopK(0.1)] {
            let opts = LiveOpts {
                n_workers: 3,
                steps: 5,
                n_params: 9_999,
                strategy: strategy.clone(),
                ..Default::default()
            };
            let report = run_live(&opts).unwrap();
            assert!(report.consistent, "{strategy:?} ranks diverged");
            assert_eq!(report.final_ratio, if strategy == SyncStrategy::AllReduce { 1.0 } else { 0.1 });
        }
    }

    #[test]
    fn tcp_live_run_matches_loopback_payloads() {
        // Same seed and strategy: the reduced gradients must be
        // bit-identical whether bytes moved over channels or sockets.
        let base = LiveOpts {
            n_workers: 2,
            steps: 4,
            n_params: 15_000,
            strategy: SyncStrategy::TopK(0.25),
            ..Default::default()
        };
        let via_loopback = run_live(&base).unwrap();
        let via_tcp = run_live(&LiveOpts {
            backend: LiveBackend::Tcp {
                bind: "127.0.0.1:0".to_string(),
            },
            ..base
        })
        .unwrap();
        assert!(via_loopback.consistent && via_tcp.consistent);
        // Ratios are static (TopK), so the per-step payloads must agree.
        let lp: Vec<u64> = via_loopback.steps.iter().map(|r| r.payload_bytes).collect();
        let tp: Vec<u64> = via_tcp.steps.iter().map(|r| r.payload_bytes).collect();
        assert_eq!(lp, tp);
    }

    /// The ISSUE acceptance check: a shaped live run must show the
    /// controller's ratio dropping after a bandwidth step-down — asserted
    /// purely on measured observables (the shaped wire), never on the
    /// configured rates.
    #[test]
    fn shaped_step_down_drops_the_ratio() {
        let step_at = 0.5;
        let opts = LiveOpts {
            n_workers: 2,
            // Enough steps to straddle the step generously: pre-step
            // rounds run ≥ 6 ms (2 ms compute + 4 ms prop floor), so the
            // step lands near step 60 of 140.
            steps: 140,
            n_params: 50_000,
            strategy: SyncStrategy::NetSense,
            backend: LiveBackend::Loopback,
            shaping: Some(ShapingConfig {
                rate_bytes_per_sec: 8e6,
                burst_bytes: 2_000.0,
                schedule: vec![(step_at, 0.5e6)], // 8 MB/s → 0.5 MB/s
                prop_delay_s: 0.004,
            }),
            compute_ms: 2,
            seed: 7,
        };
        let report = run_live(&opts).unwrap();
        assert!(report.consistent);
        // Settled ratio on the fast link vs the last steps on the slow
        // one: 16× less measured bandwidth must pull the ratio well down.
        let before = report.mean_ratio_between(0.25, step_at);
        let after = report.mean_ratio_last(5);
        let last = report.steps.last().unwrap();
        assert!(
            last.at_s > step_at + 0.1,
            "run never got past the step-down ({:.2}s)",
            last.at_s
        );
        assert!(
            report.controller_decreases > 0,
            "controller never decreased: {report:?}"
        );
        assert!(
            after < 0.6 * before,
            "ratio did not drop after step-down: {before:.4} → {after:.4}"
        );
    }
}
